"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV.

    Table 1 (Helmholtz)      -> bench_helmholtz
    Table 2 (Sobel stream)   -> bench_sobel
    Table 3 (restoration)    -> bench_restoration
    §Roofline (TPU target)   -> bench_roofline (reads runs/dryrun)

``--quick`` shrinks sizes for CI-speed runs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: helmholtz,sobel,restoration,roofline")
    args = ap.parse_args()

    from . import (bench_helmholtz, bench_restoration, bench_roofline,
                   bench_sobel)

    suites = {
        "helmholtz": lambda: bench_helmholtz.run(
            sizes=(256, 512) if args.quick else (512, 1024, 2048)),
        "sobel": lambda: bench_sobel.run(
            sizes=(256, 512) if args.quick else (512, 1024, 2048),
            stream_n=20 if args.quick else 100),
        "restoration": lambda: bench_restoration.run(
            resolutions=("vga",) if args.quick else ("vga", "720p"),
            frames=2 if args.quick else 8),
        "roofline": bench_roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # keep the harness running
            traceback.print_exc(file=sys.stderr)
            print(f"{name}_suite,-1,ERROR:{type(e).__name__}")


if __name__ == "__main__":
    main()
