"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV for eyeballing AND writes one
machine-readable ``BENCH_<suite>.json`` per suite (schema: name, backend,
unroll, median seconds, derived GB/s) so the perf trajectory is tracked
across PRs — diff the JSON, not the stdout.

    Table 1 (Helmholtz)      -> bench_helmholtz   (backend/unroll axis)
    Table 2 (Sobel stream)   -> bench_sobel
    Table 3 (restoration)    -> bench_restoration (backend/unroll axis)
    §Roofline (TPU target)   -> bench_roofline (reads runs/dryrun)

``--quick`` shrinks sizes for CI-speed runs; ``--out-dir`` relocates the
JSON files (default: current directory).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: helmholtz,sobel,restoration,roofline")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<suite>.json files are written")
    args = ap.parse_args()

    from . import (bench_helmholtz, bench_restoration, bench_roofline,
                   bench_sobel)
    from .common import csv_row, write_json

    suites = {
        "helmholtz": lambda: bench_helmholtz.run(
            sizes=(256, 512) if args.quick else (512, 1024, 2048)),
        "sobel": lambda: bench_sobel.run(
            sizes=(256, 512) if args.quick else (512, 1024, 2048),
            stream_n=20 if args.quick else 100),
        "restoration": lambda: bench_restoration.run(
            resolutions=("vga",) if args.quick else ("vga", "720p"),
            frames=2 if args.quick else 8),
        "roofline": bench_roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            rows = list(fn())
            for row in rows:
                print(csv_row(row), flush=True)
            path = write_json(name, rows, args.out_dir)
            print(f"# wrote {path}", file=sys.stderr)
        except Exception as e:  # keep the harness running
            traceback.print_exc(file=sys.stderr)
            print(f"{name}_suite,-1,ERROR:{type(e).__name__}")


if __name__ == "__main__":
    main()
