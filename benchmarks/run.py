"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV for eyeballing AND merges every
suite's records into ONE machine-readable ``BENCH_summary.json``
(schema: suite, name, backend, mesh, unroll, median seconds, derived
GB/s) so the perf trajectory is tracked across PRs — diff that single
file, not the stdout.  The committed repo-root BENCH_summary.json is the
current baseline.

    Table 1 (Helmholtz)      -> bench_helmholtz   (backend/unroll axis)
    Table 2 (Sobel stream)   -> bench_sobel
    Table 3 (restoration)    -> bench_restoration (backend/unroll axis)
    1:n sharded (§3.4 + CA)  -> bench_sharded (8-device mesh subprocess,
                                per-iteration time + ppermute rounds)
    1:1 streaming (§4.2/4.3) -> bench_streaming (lane-slot reuse vs the
                                per-batch sharded_farm path; items/sec +
                                host-transfer bytes/item; round vs
                                continuous incl. the composed
                                lanes × spatial deployment)
    serve (DESIGN.md §Serve) -> bench_serve (ragged-queue continuous
                                batching: single pool vs exact-length
                                groups; tok/s + idle_slot_steps)
    §Roofline (TPU target)   -> bench_roofline (reads runs/dryrun)

``--quick`` shrinks sizes for CI-speed runs; ``--out-dir`` relocates the
JSON file (default: current directory).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: helmholtz,sobel,restoration,"
                         "sharded,streaming,serve,roofline")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_summary.json is written")
    args = ap.parse_args()

    from . import (bench_helmholtz, bench_restoration, bench_roofline,
                   bench_serve, bench_sharded, bench_sobel,
                   bench_streaming)
    from .common import csv_row, record, write_summary

    suites = {
        "helmholtz": lambda: bench_helmholtz.run(
            sizes=(256, 512) if args.quick else (512, 1024, 2048)),
        "sobel": lambda: bench_sobel.run(
            sizes=(256, 512) if args.quick else (512, 1024, 2048),
            stream_n=20 if args.quick else 100),
        "restoration": lambda: bench_restoration.run(
            resolutions=("vga",) if args.quick else ("vga", "720p"),
            frames=2 if args.quick else 8),
        "sharded": lambda: bench_sharded.run(
            sizes=(256,) if args.quick else (256, 512)),
        "streaming": lambda: bench_streaming.run(
            sizes=(64,) if args.quick else (64, 128),
            stream_n=16 if args.quick else 32,
            iters=9),
        "serve": lambda: bench_serve.run(
            n_requests=8 if args.quick else 12,
            iters=2 if args.quick else 3),
        "roofline": bench_roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)

    all_rows: dict[str, list] = {}
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            rows = list(fn())
        except Exception as e:  # keep the harness running
            traceback.print_exc(file=sys.stderr)
            print(f"{name}_suite,-1,ERROR:{type(e).__name__}")
            rows = [record(f"{name}_suite", -1.0,
                           derived=f"ERROR:{type(e).__name__}")]
        for row in rows:
            print(csv_row(row), flush=True)
        all_rows[name] = rows
    path = write_summary(all_rows, args.out_dir)
    print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
