"""Benchmark utilities: timing + the paper's deployment comparisons.

The paper's GPU-vs-CPU columns become structure-vs-structure comparisons
on this host: the *naïve* deployment (host-driven loop, full D2H+H2D
round-trip per iteration — the strawman of §3.3) against the *persistent*
deployment (the Loop-of-stencil-reduce while_loop, device memory
persistence), and 1-device vs 1:n (subprocess with placeholder devices).
Wall-clock ratios, not absolute times, carry the claims.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall-time in seconds (blocking on the result)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
