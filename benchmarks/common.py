"""Benchmark utilities: timing + the paper's deployment comparisons.

The paper's GPU-vs-CPU columns become structure-vs-structure comparisons
on this host: the *naïve* deployment (host-driven loop, full D2H+H2D
round-trip per iteration — the strawman of §3.3) against the *persistent*
deployment (the Loop-of-stencil-reduce while_loop, device memory
persistence) across the engine's backend axis, and 1-device vs 1:n
(subprocess with placeholder devices).  Wall-clock ratios, not absolute
times, carry the claims.

Every suite emits ``record`` dicts — one per configuration — which the
harness (:mod:`benchmarks.run`) prints as CSV *and* dumps as
machine-readable ``BENCH_<suite>.json`` so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall-time in seconds (blocking on the result)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def record(name: str, seconds: float, *, backend: str = "", unroll: int = 1,
           mesh: str = "1", gbps: float | None = None,
           derived: str = "") -> dict:
    """One benchmark result row (the BENCH_summary.json record schema).

    ``mesh`` is the device-mesh axis ("1" = single device, "8x1" = an
    8-way 1-D decomposition, ...) so the perf trajectory distinguishes
    deployments, not just backends.
    """
    return {"name": name, "backend": backend, "unroll": unroll,
            "mesh": mesh, "seconds": seconds,
            "gbps": None if gbps is None else round(gbps, 3),
            "derived": derived}


def stencil_gbps(size: int, iters: int, seconds: float,
                 arrays_per_iter: int = 3, bytes_per_cell: int = 4) -> float:
    """Effective (algorithmic) bandwidth of an iterated 2-D stencil:
    ``arrays_per_iter`` full-grid HBM streams per iteration (read + write
    + env by default), regardless of what the backend actually moved —
    so temporal blocking shows up as *higher* effective GB/s."""
    return arrays_per_iter * bytes_per_cell * size * size * iters \
        / max(seconds, 1e-12) / 1e9


def csv_row(rec: dict) -> str:
    """CSV line (``name,us_per_call,derived``) for a record dict."""
    tags = [t for t in (rec["backend"],
                        f"T={rec['unroll']}" if rec["unroll"] > 1 else "",
                        f"mesh={rec['mesh']}"
                        if rec.get("mesh", "1") != "1" else "",
                        f"{rec['gbps']}GB/s" if rec["gbps"] else "",
                        rec["derived"]) if t]
    # negative seconds is the failure sentinel: keep the literal '-1'
    # the CSV contract (and run.py's own suite-error line) uses
    us = "-1" if rec["seconds"] < 0 else f"{rec['seconds'] * 1e6:.1f}"
    return f"{rec['name']},{us},{';'.join(tags)}"


SUMMARY_SCHEMA = 1


def write_summary(suite_rows: dict, out_dir: str = ".") -> str:
    """Merge every suite's records into ONE schema-stable
    BENCH_summary.json (replaces the per-suite BENCH_<suite>.json
    scatter) — diff this single file across PRs to read the perf
    trajectory.  ``suite_rows`` maps suite name -> list of record dicts.
    """
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_summary.json")
    records = []
    for suite, recs in suite_rows.items():
        for r in recs:
            records.append({"suite": suite, **r})
    payload = {"schema": SUMMARY_SCHEMA,
               "jax_backend": jax.default_backend(),
               "records": records}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
        fh.write("\n")
    return path
