"""§Roofline table: per (arch × shape × mesh) terms from the dry-run
artifacts (runs/dryrun/*.json).  Not a wall-clock bench — this is the
perf report for the TPU target derived from the compiled HLO."""
from __future__ import annotations

import glob
import json
import os

from .common import record

RUNS = os.environ.get("DRYRUN_DIR", "runs/dryrun")


def load(runs_dir=RUNS):
    recs = []
    for p in sorted(glob.glob(os.path.join(runs_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(runs_dir=RUNS) -> list[dict]:
    rows = []
    recs = load(runs_dir)
    if not recs:
        return [record("roofline_missing", -1.0,
                       derived=f"(run python -m repro.launch.dryrun "
                       f"--all --mesh both --out {runs_dir})")]
    for r in recs:
        if "app" in r:                    # stencil-app dry-run artifact
            bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
            rows.append(record(
                f"roofline_stencil_{r['grid']}", bound,
                derived=f"tc={r['t_compute'] * 1e3:.3f}ms;"
                f"tm={r['t_memory'] * 1e3:.3f}ms;"
                f"tx={r['t_collective'] * 1e3:.3f}ms;iters={r['iters']}"))
            continue
        if "arch" not in r:
            continue
        tag = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        if r.get("skipped"):
            rows.append(record(f"roofline_{tag}", 0.0,
                               derived=f"SKIP:{r['reason'][:60]}"))
            continue
        if not r.get("ok"):
            rows.append(record(f"roofline_{tag}", -1.0, derived="FAILED"))
            continue
        rf = r["roofline"]
        bound = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        rows.append(record(
            f"roofline_{tag}", bound,
            derived=f"dom={rf['dominant']};tc={rf['t_compute'] * 1e3:.2f}ms;"
            f"tm={rf['t_memory'] * 1e3:.2f}ms;"
            f"tx={rf['t_collective'] * 1e3:.2f}ms;"
            f"useful={rf['useful_ratio']:.2f};frac={rf['fraction']:.4f}"))
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
