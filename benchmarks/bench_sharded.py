"""Sharded persistent-halo deployment — the communication-avoiding rows.

Runs the ``pallas-sharded`` backend on an 8-virtual-device CPU mesh
(subprocess with ``--xla_force_host_platform_device_count=8``) and
reports:

* per-iteration wall time of the distributed loop at unroll 1 and 4
  (the deep-halo temporal-blocking schedule checks the condition — and
  exchanges ghosts — once per 4 fused sweeps);
* the ppermute rounds per while-body counted from the jaxpr, so the
  ≈T× ICI-message reduction of ``unroll=T`` is pinned by structure, not
  just wall time (CPU interpret-mode timings only carry ratios);
* the jnp 1:n deployment as the non-persistent reference.

Absolute numbers are only meaningful on TPU; the recorded ratios
(exchange rounds per sweep, sharded vs jnp-distributed wall time) carry
the claims across PRs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import record

ITERS = 8


def _worker_code(size: int, iters: int) -> str:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    return textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, time, json
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import LoopOfStencilReduce, GridPartition
        from repro.core import distributed_loop_of_stencil_reduce
        from repro.kernels import ref as R

        SIZE, ITERS = %d, %d
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(SIZE, SIZE)), jnp.float32)
        part = GridPartition(mesh=jax.make_mesh((8,), ("data",)),
                             axis_names=("data",), array_axes=(0,))
        heat = R.heat_taps(0.1)

        def sharded(unroll):
            return LoopOfStencilReduce(
                f=heat, k=1, combine="max", cond=lambda r: False,
                delta=R.abs_delta, boundary="zero", max_iters=ITERS,
                unroll=unroll, backend="pallas-sharded", partition=part,
                interpret=True, block=(32, 128))

        def time_run(runner):
            # ONE jit wrapper per config: the warmup compiles it, the
            # timed calls hit the cache and measure the loop itself
            r = runner(a); jax.block_until_ready(r.a)
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                r = runner(a); jax.block_until_ready(r.a)
                ts.append(time.perf_counter() - t0)
            return float(np.median(ts))

        from repro.core.introspect import count_primitive, while_body_eqns

        out = []
        for unroll in (1, 4):
            loop = sharded(unroll)
            t = time_run(jax.jit(loop.run))
            ppb = count_primitive(
                while_body_eqns(lambda x: loop.run(x).a, a), "ppermute")
            out.append({"kind": "sharded", "unroll": unroll,
                        "seconds": t, "per_iter": t / ITERS,
                        "ppermute_per_body": ppb})

        dist = lambda x: distributed_loop_of_stencil_reduce(
            heat, "max", lambda r: False, x, k=1, part=part,
            delta=R.abs_delta, max_iters=ITERS)
        t = time_run(jax.jit(dist))
        out.append({"kind": "jnp-dist", "unroll": 1, "seconds": t,
                    "per_iter": t / ITERS, "ppermute_per_body": None})
        print(json.dumps(out))
    """) % (src, size, iters)


def run(sizes=(256,)) -> list[dict]:
    rows = []
    for size in sizes:
        try:
            out = subprocess.run(
                [sys.executable, "-c", _worker_code(size, ITERS)],
                capture_output=True, text=True, timeout=900)
            if out.returncode != 0:
                raise RuntimeError(out.stderr[-1500:])
            results = json.loads(out.stdout.strip().splitlines()[-1])
        except Exception as e:
            rows.append(record(f"sharded_{size}", -1.0, mesh="8x1",
                               derived=f"ERROR:{type(e).__name__}"))
            continue
        ppb = {r["unroll"]: r["ppermute_per_body"]
               for r in results if r["kind"] == "sharded"}
        for r in results:
            if r["kind"] == "sharded":
                u = r["unroll"]
                # exchange rounds per SWEEP: body rounds / sweeps-per-body
                per_sweep = ppb[u] / u
                rows.append(record(
                    f"sharded_{size}_persistent", r["seconds"],
                    backend="pallas-sharded", unroll=u, mesh="8x1",
                    derived=(f"per_iter={r['per_iter'] * 1e6:.1f}us;"
                             f"ppermute_per_body={ppb[u]};"
                             f"ppermute_per_sweep={per_sweep:g}")))
            else:
                rows.append(record(
                    f"sharded_{size}_jnp_dist", r["seconds"],
                    backend="jnp", mesh="8x1",
                    derived=f"per_iter={r['per_iter'] * 1e6:.1f}us"))
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
