"""Serving tier — ragged-prompt continuous batching vs exact grouping.

A ragged queue (mixed prompt lengths AND token budgets) drains through
the continuous engine two ways:

    single_pool   ONE ``ContinuousEngine`` binding at the queue's max
                  prompt length (padded per-slot prefill with a
                  prompt-length mask) — the PR-5 default of
                  ``Batcher.run_continuous``
    exact_groups  the old one-engine-per-exact-prompt-length scheme,
                  which idles a whole cohort at every group tail (and
                  compiles once per distinct length)

One engine (set) per mode serves every timing sample — the slots and
the single compilation behind them are reused across runs, exactly as a
long-running server would; the idle counters accumulate, so the
per-stream average is reported.  Reported per mode: median wall time,
tok/s, and ``idle_slot_steps`` (slot-steps burned on retired or
done-masked slots — the serve twin of the farm tier's
``wasted_lane_steps``).  The idle ratio is hardware-independent and
carries the single-pool claim on CPU CI, where wall time is dominated
by the tiny reduced model; no-pad-leak and parity are pinned in
tests/train/test_serve.py and the hypothesis suite.
"""
from __future__ import annotations

import time

import numpy as np

from .common import record


def run(arch: str = "qwen3-1.7b", n_requests: int = 10, slots: int = 2,
        max_new: int = 8, iters: int = 3) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import GenerateConfig
    from repro.serve.batcher import Request
    from repro.serve.engine import ContinuousEngine

    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    gcfg = GenerateConfig(max_new_tokens=max_new, eos_id=1,
                          temperature=0.0)
    rng = np.random.default_rng(0)
    lens = [4 + 3 * (i % 3) for i in range(n_requests)]      # 4/7/10
    budgets = [max_new if i % 4 == 3 else 2 for i in range(n_requests)]
    requests = [Request(rid=i, max_new_tokens=budgets[i],
                        prompt=np.asarray(
                            rng.integers(2, cfg.vocab_size, lens[i]),
                            np.int32))
                for i in range(n_requests)]

    def mk_engine(max_prompt_len):
        return ContinuousEngine(cfg, params, gcfg, slots=slots,
                                cache_dtype=jnp.float32,
                                max_prompt_len=max_prompt_len)

    # single pool: one engine, the whole ragged queue
    pool = mk_engine(max(lens))
    # exact groups: one engine per distinct prompt length (built once —
    # a real deployment would cache them, but each still compiles its
    # own prefill/segment pair)
    groups = {}
    for r in requests:
        groups.setdefault(len(r.prompt), []).append(r)
    group_engines = {L: mk_engine(L) for L in groups}

    def single_pool():
        toks = []
        pool.run(requests, lambda rid, t, status: toks.append(len(t)))
        return sum(toks)

    def exact_groups():
        toks = []
        for L, group in groups.items():
            group_engines[L].run(
                group, lambda rid, t, status: toks.append(len(t)))
        return sum(toks)

    modes = {"single_pool": (single_pool, [pool]),
             "exact_groups": (exact_groups,
                              list(group_engines.values()))}
    rows = []
    for name, (fn, engines) in modes.items():
        ntok = fn()                               # warmup/compile
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        runs = iters + 1
        t = float(np.median(ts))
        idle = sum(e.stats["idle_slot_steps"] for e in engines) // runs
        total = sum(e.stats["slot_steps"] for e in engines) // runs
        rows.append(record(
            f"serve_ragged_{name}", t, backend="continuous",
            derived=(f"tok_per_s={ntok / t:.1f};"
                     f"idle_slot_steps={idle};slot_steps={total};"
                     f"engines={len(engines)}")))

    # degraded mode: the same ragged queue with ~10% of requests
    # deadline-doomed — some expired before admission (shed at the
    # door), some expiring mid-decode (slot evicted, KV freed through
    # the refill path).  A deterministic counting clock (one tick per
    # engine clock read) stands in for wall time so the record is
    # machine-independent; wall time itself is still perf_counter.
    # Healthy requests must finish ok at full length — the record
    # carries tok/s under faults next to the shed/evicted counts.
    degraded_reqs = []
    for r in requests:
        dl = None
        if r.rid % 10 == 7:
            dl = -1.0                  # expired before admission
        elif r.rid % 10 == 3:
            dl = float(len(requests))  # big-budget request admitted
                                       # early: expires mid-decode
        degraded_reqs.append(Request(
            rid=r.rid, prompt=r.prompt,
            max_new_tokens=r.max_new_tokens, deadline=dl))
    # one engine, one compilation — reused across samples like the
    # modes above; segment=2 so a full-budget decode spans several
    # deadline checks (default segment=8 would outrun any deadline)
    deg_eng = ContinuousEngine(cfg, params, gcfg, slots=slots,
                               cache_dtype=jnp.float32,
                               max_prompt_len=max(lens), segment=2)

    def degraded():
        ticks = [0]

        def clock():
            ticks[0] += 1
            return float(ticks[0])

        got = {"ok_toks": 0, "ok": 0}

        def sink(rid, t, status):
            if status == "ok":
                got["ok"] += 1
                got["ok_toks"] += len(t)
        deg_eng.run(degraded_reqs, sink, clock=clock)
        return got

    got = degraded()                              # warmup/compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        got = degraded()
        ts.append(time.perf_counter() - t0)
    runs = iters + 1
    t = float(np.median(ts))
    rows.append(record(
        "serve_degraded_single_pool", t, backend="continuous",
        derived=(f"tok_per_s={got['ok_toks'] / t:.1f};"
                 f"ok={got['ok']};"
                 f"shed={deg_eng.stats['shed'] // runs};"
                 f"evicted={deg_eng.stats['evicted'] // runs};"
                 f"requests={len(requests)}")))
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
