"""Paper Table 2 — streaming Sobel edge detector.

Single-image rows (the paper's worst case for accelerators: one
iteration, copy-bound) + the 100-image streaming row where the farm
(batched dispatch + async prefetch) amortises the per-item overhead.

Deployments:
    per_item   one dispatch per image, host sync between items
    stream     StreamRunner farm: batched, double-buffered (1:1 mode)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import StreamRunner
from repro.kernels import ops
from .common import record, time_fn


def run(sizes=(512, 1024, 2048), stream_n=100) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    jit_sobel = jax.jit(lambda im: ops.sobel(im)[0])
    for size in sizes:
        img = jnp.asarray(rng.uniform(size=(size, size)), jnp.float32)
        t = time_fn(jit_sobel, img)
        rows.append(record(f"sobel_{size}_single", t, backend="jnp"))

    # streaming variant: 100 random images from the size set (paper §4.2)
    imgs = [np.asarray(rng.uniform(size=(512, 512)), np.float32)
            for _ in range(stream_n)]

    def per_item():
        outs = []
        for im in imgs:
            outs.append(np.asarray(jit_sobel(jnp.asarray(im))))
        return outs[-1]

    batched = jax.jit(jax.vmap(lambda im: ops.sobel(im)[0]))

    def stream():
        sink: list = []
        StreamRunner(worker=batched, source=lambda: iter(imgs),
                     sink=lambda o: sink.append(o), batch=10).run()
        return sink[-1]

    t_item = time_fn(per_item, warmup=1, iters=2)
    t_stream = time_fn(stream, warmup=1, iters=2)
    rows.append(record(f"sobel_stream{stream_n}_per_item", t_item,
                       backend="jnp"))
    rows.append(record(
        f"sobel_stream{stream_n}_farm", t_stream, backend="jnp",
        derived=f"speedup_vs_per_item={t_item / t_stream:.2f}x"))
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
