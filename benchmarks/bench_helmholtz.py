"""Paper Table 1 — Helmholtz equation solver, across the backend axis.

Deployments compared (the paper's CPU / 1×GPU / 2×GPU 1:2 columns mapped
to this host):
    naive            host-driven loop, device_get of the full grid +
                     re-upload each iteration (the §3.3 strawman)
    pallas_per_iter  on-device while_loop, but the seed's pad-per-
                     iteration kernel staging: jnp.pad + full-grid slice
                     inside the loop body (what this engine retires)
    persistent       the Loop-of-stencil-reduce through the engine's
                     backend axis — jnp (shift algebra), pallas
                     (persistent halo frame, zero-copy body), and
                     pallas-multistep at several unroll depths T
                     (÷T HBM traffic per sweep)
    1:n              the persistent loop under an n-way halo-exchange
                     decomposition (subprocess with placeholder devices)

Fixed 10 iterations ("convergence is reached after 10 iterations",
Table 1 caption) so rows are comparable across sizes; the multistep rows
use unroll values that divide 10 exactly.  Derived GB/s is *algorithmic*
bandwidth (3 full-grid streams × iterations / wall-time), so the
pad-hoist and the ÷T traffic win surface as higher effective GB/s.
"""
from __future__ import annotations

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pattern import LoopOfStencilReduce
from repro.kernels import ref as R
from repro.kernels.ops import fused_sweep
from .common import record, stencil_gbps, time_fn

ITERS = 10
ALPHA, DX = 0.5, 1.0 / 512
BACKENDS = (("jnp", 1), ("pallas", 1), ("pallas-multistep", 2),
            ("pallas-multistep", 5))


def naive_loop(u0, fxy):
    """Full D2H + H2D round trip per iteration (paper's naïve schema)."""
    f = R.helmholtz_jacobi_taps(ALPHA, DX)
    step = jax.jit(lambda u, e: fused_sweep(
        u, f, env=(e,), k=1, combine="max", identity=-jnp.inf,
        measure=R.abs_delta, use_pallas=False))
    u = u0
    for _ in range(ITERS):
        u, delta = step(u, fxy)
        u_host = np.asarray(jax.device_get(u))        # D2H (full grid)
        float(delta)                                  # host-side condition
        u = jax.device_put(jnp.asarray(u_host))       # H2D (full grid)
    return u


@jax.jit
def pallas_per_iter_loop(u0, fxy):
    """ONE while_loop, but framing/unframing the grid EVERY iteration —
    the seed's kernel staging, kept as the pad-hoist baseline."""
    f = R.helmholtz_jacobi_taps(ALPHA, DX)

    def body(carry):
        u, it = carry
        u, _ = fused_sweep(u, f, env=(fxy,), k=1, combine="max",
                           identity=-jnp.inf, measure=R.abs_delta,
                           backend="pallas")
        return u, it + 1

    u, _ = jax.lax.while_loop(lambda c: c[1] < ITERS, body,
                              (u0, jnp.asarray(0)))
    return u


@functools.partial(jax.jit, static_argnames=("backend", "unroll"))
def persistent_loop(u0, fxy, *, backend="jnp", unroll=1):
    """ONE while_loop: grids never leave the device (the pattern).  On
    the pallas backends the halo frame is the carry — no pad/slice in
    the body."""
    loop = LoopOfStencilReduce(
        f=R.helmholtz_jacobi_taps(ALPHA, DX), k=1, combine="max",
        cond=lambda r: False, delta=R.abs_delta, boundary="zero",
        max_iters=ITERS, unroll=unroll, backend=backend)
    return loop.run(u0, env=(fxy,)).a


def one_to_n(size: int, n: int = 8) -> float:
    """1:n halo-exchange deployment in a subprocess with n host devices."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import sys, time
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import GridPartition, distributed_loop_of_stencil_reduce
        from repro.kernels import ref as R
        rng = np.random.default_rng(0)
        u0 = jnp.zeros((%d, %d), jnp.float32)
        fxy = jnp.asarray(rng.normal(size=(%d, %d)), jnp.float32)
        mesh = jax.make_mesh((%d,), ("data",))
        part = GridPartition(mesh=mesh, axis_names=("data",), array_axes=(0,))
        taps = R.helmholtz_jacobi_taps(%f, %f)
        f = lambda get: taps(get, 0.0)   # forcing folded out for timing
        def run():
            return distributed_loop_of_stencil_reduce(
                f, "max", lambda r: False, u0, k=1, part=part,
                identity=-jnp.inf, max_iters=%d)
        r = run(); jax.block_until_ready(r.a)        # compile+warm
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            r = run(); jax.block_until_ready(r.a)
            ts.append(time.perf_counter() - t0)
        print(float(np.median(ts)))
    """ % (n, src, size, size, size, size, n, ALPHA, DX, ITERS))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-1500:])
    return float(out.stdout.strip().splitlines()[-1])


def run(sizes=(512, 1024, 2048)) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for size in sizes:
        u0 = jnp.zeros((size, size), jnp.float32)
        fxy = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        gbps = lambda t: stencil_gbps(size, ITERS, t)

        t_naive = time_fn(naive_loop, u0, fxy)
        rows.append(record(f"helmholtz_{size}_naive", t_naive,
                           backend="jnp", gbps=gbps(t_naive),
                           derived=f"{ITERS}it"))
        t_ppi = time_fn(pallas_per_iter_loop, u0, fxy)
        rows.append(record(
            f"helmholtz_{size}_pallas_per_iter", t_ppi, backend="pallas",
            gbps=gbps(t_ppi), derived="pad-per-iteration baseline"))
        for backend, unroll in BACKENDS:
            t = time_fn(persistent_loop, u0, fxy, backend=backend,
                        unroll=unroll)
            extra = (f"speedup_vs_pad_per_iter={t_ppi / t:.2f}x"
                     if backend.startswith("pallas") else
                     f"speedup_vs_naive={t_naive / t:.2f}x")
            rows.append(record(f"helmholtz_{size}_persistent", t,
                               backend=backend, unroll=unroll,
                               gbps=gbps(t), derived=extra))
        try:
            t_1n = one_to_n(size)
            rows.append(record(
                f"helmholtz_{size}_1to8", t_1n, backend="jnp",
                mesh="8x1", gbps=gbps(t_1n),
                derived=f"speedup_vs_naive={t_naive / t_1n:.2f}x"))
        except Exception as e:   # 1:n needs host-device emulation support
            rows.append(record(f"helmholtz_{size}_1to8", -1.0, mesh="8x1",
                               derived=f"ERROR:{type(e).__name__}"))
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
