"""Paper Table 1 — Helmholtz equation solver.

Deployments compared (the paper's CPU / 1×GPU / 2×GPU 1:2 columns mapped
to this host):
    naive       host-driven loop, device_get of the full grid + re-upload
                each iteration (the §3.3 strawman)
    persistent  the Loop-of-stencil-reduce: one on-device while_loop with
                the fused sweep+delta-reduce (buffer swap in HBM)
    1:n         the persistent loop under an n-way halo-exchange
                decomposition (subprocess with placeholder devices)

Fixed 10 iterations ("convergence is reached after 10 iterations",
Table 1 caption) so rows are comparable across sizes.
"""
from __future__ import annotations

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as R
from repro.kernels.ops import fused_sweep
from .common import csv_row, time_fn

ITERS = 10
ALPHA, DX = 0.5, 1.0 / 512


def naive_loop(u0, fxy):
    """Full D2H + H2D round trip per iteration (paper's naïve schema)."""
    f = R.helmholtz_jacobi_taps(ALPHA, DX)
    step = jax.jit(lambda u, e: fused_sweep(
        u, f, env=(e,), k=1, combine="max", identity=-jnp.inf,
        measure=R.abs_delta, use_pallas=False))
    u = u0
    for _ in range(ITERS):
        u, delta = step(u, fxy)
        u_host = np.asarray(jax.device_get(u))        # D2H (full grid)
        float(delta)                                  # host-side condition
        u = jax.device_put(jnp.asarray(u_host))       # H2D (full grid)
    return u


@functools.partial(jax.jit, static_argnames=())
def persistent_loop(u0, fxy):
    """ONE while_loop: grids never leave the device (the pattern)."""
    f = R.helmholtz_jacobi_taps(ALPHA, DX)

    def body(carry):
        u, it = carry
        u, _ = fused_sweep(u, f, env=(fxy,), k=1, combine="max",
                           identity=-jnp.inf, measure=R.abs_delta,
                           use_pallas=False)
        return u, it + 1

    u, _ = jax.lax.while_loop(lambda c: c[1] < ITERS, body,
                              (u0, jnp.asarray(0)))
    return u


def one_to_n(size: int, n: int = 8) -> float:
    """1:n halo-exchange deployment in a subprocess with n host devices."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import sys, time
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.core import GridPartition, distributed_loop_of_stencil_reduce
        from repro.kernels import ref as R
        rng = np.random.default_rng(0)
        u0 = jnp.zeros((%d, %d), jnp.float32)
        fxy = jnp.asarray(rng.normal(size=(%d, %d)), jnp.float32)
        mesh = jax.make_mesh((%d,), ("data",), axis_types=(AxisType.Auto,))
        part = GridPartition(mesh=mesh, axis_names=("data",), array_axes=(0,))
        taps = R.helmholtz_jacobi_taps(%f, %f)
        f = lambda get: taps(get, 0.0)   # forcing folded out for timing
        def run():
            return distributed_loop_of_stencil_reduce(
                f, "max", lambda r: False, u0, k=1, part=part,
                identity=-jnp.inf, max_iters=%d)
        r = run(); jax.block_until_ready(r.a)        # compile+warm
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            r = run(); jax.block_until_ready(r.a)
            ts.append(time.perf_counter() - t0)
        print(float(np.median(ts)))
    """ % (n, src, size, size, size, size, n, ALPHA, DX, ITERS))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-1500:])
    return float(out.stdout.strip().splitlines()[-1])


def run(sizes=(512, 1024, 2048)) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for size in sizes:
        u0 = jnp.zeros((size, size), jnp.float32)
        fxy = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        t_naive = time_fn(naive_loop, u0, fxy)
        t_pers = time_fn(persistent_loop, u0, fxy)
        t_1n = one_to_n(size)
        rows.append(csv_row(f"helmholtz_{size}_naive", t_naive,
                            f"{ITERS}it"))
        rows.append(csv_row(f"helmholtz_{size}_persistent", t_pers,
                            f"speedup_vs_naive={t_naive / t_pers:.2f}x"))
        rows.append(csv_row(f"helmholtz_{size}_1to8", t_1n,
                            f"speedup_vs_naive={t_naive / t_1n:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
