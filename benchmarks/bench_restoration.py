"""Paper Table 3 — two-phase video restoration over frame streams.

pipe(read, detect, ofarm(restore), write) at VGA/720p with 30%/70%
impulse noise; the multi-iteration restoration is where device-memory
persistence pays (the paper's best case: 12–20× on K40).

Deployments:
    naive       detect + host-stepped restoration sweeps (D2H each sweep)
    persistent  detect + the fused on-device restore while_loop, across
                the engine backend axis (jnp / pallas persistent-halo /
                pallas-multistep temporal blocking)
Also reports restoration quality (PSNR in/out) per noise level —
reproducing the *behaviour*, not just the timing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref as R
from repro.kernels.ops import fused_sweep
from .common import record, time_fn

RES = {"vga": (480, 640), "720p": (720, 1280)}
MAX_IT = 30
BACKENDS = (("jnp", 1), ("pallas", 1), ("pallas-multistep", 3))


def synth_frame(shape, seed=0):
    yy, xx = np.mgrid[0:shape[0], 0:shape[1]]
    base = 0.5 + 0.3 * np.sin(xx / 25.0) * np.cos(yy / 18.0) \
        + 0.2 * ((xx // 40 + yy // 30) % 2)
    return np.clip(base, 0, 1).astype(np.float32)


def add_impulse(frame, level, seed):
    rng = np.random.default_rng(seed)
    imp = rng.uniform(size=frame.shape) < level
    sp = np.where(rng.uniform(size=frame.shape) < 0.5, 0.0, 1.0)
    return np.where(imp, sp, frame).astype(np.float32)


def naive_restore(frame, mask):
    """Host-stepped sweeps with a device_get per iteration (strawman)."""
    f = R.restore_taps(2.0)
    step = jax.jit(lambda u, fr, m: fused_sweep(
        u, f, env=(fr, m), k=1, combine="sum", identity=0.0,
        measure=R.abs_delta, boundary="reflect", use_pallas=False))
    u = frame
    for _ in range(MAX_IT):
        u, s = step(u, frame, mask)
        if float(s) / max(float(mask.sum()), 1) < 1e-3:   # host condition
            break
        u = jax.device_put(np.asarray(jax.device_get(u)))
    return u


def psnr(a, b):
    return -10 * np.log10(np.mean((np.asarray(a) - np.asarray(b)) ** 2)
                          + 1e-12)


def run(resolutions=("vga", "720p"), levels=(0.3, 0.7),
        frames=8) -> list[dict]:
    rows = []
    for res in resolutions:
        clean = synth_frame(RES[res])
        for level in levels:
            noisy = [jnp.asarray(add_impulse(clean, level, s))
                     for s in range(frames)]

            def persistent(backend="jnp", unroll=1):
                out = None
                for fr in noisy:
                    mask, repaired = ops.adaptive_median_detect(fr)
                    out, _, _ = ops.restore(repaired, mask,
                                            max_iters=MAX_IT,
                                            backend=backend, unroll=unroll)
                return out

            def naive():
                out = None
                for fr in noisy:
                    mask, repaired = ops.adaptive_median_detect(fr)
                    out = naive_restore(repaired, mask)
                return out

            t_naive = time_fn(naive, warmup=1, iters=2)
            tag = f"restore_{res}_{int(level * 100)}pct"
            rows.append(record(f"{tag}_naive", t_naive, backend="jnp",
                               derived=f"{frames}frames"))
            for backend, unroll in BACKENDS:
                t_pers = time_fn(persistent, backend, unroll,
                                 warmup=1, iters=2)
                out = persistent(backend, unroll)
                rows.append(record(
                    f"{tag}_persistent", t_pers, backend=backend,
                    unroll=unroll,
                    derived=f"speedup={t_naive / t_pers:.2f}x;"
                    f"psnr {psnr(noisy[0], clean):.1f}->"
                    f"{psnr(out, clean):.1f}dB"))
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
