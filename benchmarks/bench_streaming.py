"""Streaming farm deployments — lane-slot reuse vs per-batch re-entry,
and continuous refill vs the round barrier.

A stream of independent Jacobi convergence loops (the paper's 1:1 mode)
through three deployments:

    per_item     one ``loop.run`` dispatch per item, host sync between
                 items (the naïve strawman)
    batch_farm   the OLD ``sharded_farm`` path: ``device_put`` every
                 batch into a vmapped jitted worker — the worker
                 re-frames (pad + block-round) every lane on every item
    lane_engine  :class:`repro.core.streaming.FarmEngine`: persistent
                 lane slots, device-side in-place refill, host double
                 buffering — frames are built once and reused across
                 stream items
    lane_engine_async
                 the same engine in CHAINED continuous mode (DESIGN.md
                 §Dispatch pipeline): staging ring, fused
                 segment+refill dispatch, ring-seated initial cohort,
                 lag-1 metadata drain — no host sync between segments,
                 and finished lanes re-seat mid-stream instead of
                 idling behind the chunk's straggler

plus the *continuous* variant: a BIMODAL trip-count stream (short items
interleaved with ~20× stragglers — the workload the round barrier is
worst at) through ``FarmEngine`` in round mode vs
``run(continuous=True)``.  Reported: items/sec and the engine's own
``wasted_lane_steps`` counter (done-masked lane sweeps burned behind
stragglers) — the waste ratio is hardware-independent, so it carries the
continuous-refill claim even on CPU-interpret CI where wall time is
dominated by the emulated kernel.  The same round-vs-continuous
comparison also runs on the COMPOSED deployment (lanes over ``data`` ×
per-lane frames ppermute-decomposed over ``model``,
``pallas-sharded``) in an 8-virtual-device subprocess
(:func:`run_composed_continuous`).

Reported per deployment: median wall time, items/sec, and (for the lane
engine) host-transfer bytes per item from the engine's own accounting —
the structural claim (no re-framing per item) is pinned separately by
jaxpr in tests/core/test_farm.py; the wall-clock ratio carries the
perf claim across PRs.  The workers run the "pallas" persistent backend
— the engine tier's target (the jnp path has no frames to keep
resident, and its µs-scale loops drown deployment differences in host
scheduler noise).  In CPU interpret mode the emulated kernel dominates
wall time, so lane_engine ≈ batch_farm is the expected CI reading (the
framing/allocation work the slots avoid only surfaces on TPU) — but
lane_engine_async must BEAT batch_farm even here: on the calibrated
trip-count spread the chained engine simply runs fewer lane sweeps
(mid-flight refill vs the chunk barrier), and chaining keeps its
per-segment cost below the waste it reclaims.

:func:`run_recovery` measures the preemption-recovery path (DESIGN.md
§Recovery): a recovery-armed continuous farm is killed at ~50% of its
segments in a subprocess, respawned via
``repro.resilience.run_to_completion``, and the resumed run's
``recovery_seconds`` / ``replayed_items`` / ``recovered_occupants``
are reported next to the fault-free wall time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FarmEngine, LoopOfStencilReduce, sharded_farm
from repro.kernels import ref as R
from .common import record


def paired_times(fns, warmup: int = 1, iters: int = 9) -> dict:
    """Median wall time per deployment with INTERLEAVED samples.

    Timing each deployment in its own block puts any machine drift
    (thermal, noisy neighbours) entirely onto the ratio between blocks;
    round-robin sampling spreads it evenly, so the recorded speedups
    survive loaded CI hosts.  Each fn must block before returning (ours
    end on a host-side numpy result).
    """
    import time

    for _, fn in fns:
        for _ in range(warmup):
            fn()
    samples: dict = {name: [] for name, _ in fns}
    for _ in range(iters):
        for name, fn in fns:
            t0 = time.perf_counter()
            fn()
            samples[name].append(time.perf_counter() - t0)
    return {name: float(np.median(ts)) for name, ts in samples.items()}


def _mkloop(backend: str, block=(32, 128),
            unroll="auto") -> LoopOfStencilReduce:
    # tolerance calibrated so the _stream items CONVERGE with a real
    # trip-count spread (3..20 iterations across the ×(0.2 + i%5)
    # amplitude cycle): early exit and mid-flight refill — the things
    # the deployments differ on — actually engage.  At a tighter
    # tolerance every item runs to max_iters and the whole suite
    # degenerates into a fixed-iteration dispatch microbenchmark.
    return LoopOfStencilReduce(
        f=R.heat_taps(0.1), k=1, combine="max", delta=R.abs_delta,
        cond=lambda r: r < 1e-1, boundary="zero", max_iters=24,
        backend=backend, block=block, unroll=unroll)


def _stream(rng, size: int, n: int):
    return [np.asarray(rng.normal(size=(size, size)), np.float32)
            * (0.2 + (i % 5)) for i in range(n)]


def _bimodal_items(size: int, n: int, short=2, long=40):
    """Countdown items with bimodal trip counts (mostly short, every
    4th a straggler) — the adversarial spread for the round barrier."""
    base = np.linspace(0.1, 0.9, size * size,
                       dtype=np.float32).reshape(size, size)
    trips = [long if i % 4 == 3 else short for i in range(n)]
    return [base + float(t) - 1.0 for t in trips]


def _mk_countdown(block=(32, 128), max_iters=64) -> LoopOfStencilReduce:
    return LoopOfStencilReduce(
        f=lambda get, *_: get(0, 0) - 1.0, k=1, combine="max",
        cond=lambda r: r < 0.5, boundary="zero", max_iters=max_iters,
        backend="pallas", block=block)


def run_continuous(sizes=(64,), stream_n=16, lanes=4,
                   iters=5) -> list[dict]:
    """Round barrier vs continuous refill on a bimodal stream."""
    rows = []
    for size in sizes:
        items = _bimodal_items(size, stream_n)
        # ONE engine per mode for the whole timing block: the slots (and
        # the single compilation behind them) are reused across samples,
        # exactly as a long-running stream would; the waste counters
        # accumulate, so report the per-stream average
        eng_round = FarmEngine(_mk_countdown(), lanes=lanes)
        eng_cont = FarmEngine(_mk_countdown(), lanes=lanes, segment=8)

        def round_mode():
            return eng_round.run(items, lambda r: None)

        def continuous():
            return eng_cont.run(items, lambda r: None, continuous=True)

        ts = paired_times([("round", round_mode),
                           ("continuous", continuous)],
                          warmup=1, iters=iters)
        runs = iters + 1
        w_round = eng_round.wasted_lane_steps // runs
        w_cont = eng_cont.wasted_lane_steps // runs
        s_round = eng_round.lane_steps // runs
        s_cont = eng_cont.lane_steps // runs
        rows.append(record(
            f"stream_{size}_round_bimodal", ts["round"],
            backend="pallas",
            derived=(f"items_per_s={stream_n / ts['round']:.1f};"
                     f"wasted_lane_steps={w_round};"
                     f"lane_steps={s_round}")))
        rows.append(record(
            f"stream_{size}_continuous_bimodal", ts["continuous"],
            backend="pallas",
            derived=(f"items_per_s={stream_n / ts['continuous']:.1f};"
                     f"wasted_lane_steps={w_cont};"
                     f"lane_steps={s_cont};"
                     f"waste_cut={w_round / max(w_cont, 1):.1f}x")))
    return rows


_COMPOSED_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time, json
sys.path.insert(0, %r)
import jax, numpy as np
from repro.core import FarmEngine, GridPartition, LoopOfStencilReduce

SIZE, STREAM_N, LANES, ITERS = %d, %d, %d, %d

def countdown(get, *_):
    return get(0, 0) - 1.0

mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))

def mk():
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=64, backend="pallas-sharded",
        partition=part, interpret=True, block=(16, 128))

base = np.linspace(0.1, 0.9, SIZE * SIZE,
                   dtype=np.float32).reshape(SIZE, SIZE)
trips = [40 if i %% 4 == 3 else 2 for i in range(STREAM_N)]
items = [base + float(t) - 1.0 for t in trips]

eng_round = FarmEngine(mk(), lanes=LANES, mesh=mesh)
eng_cont = FarmEngine(mk(), lanes=LANES, mesh=mesh, segment=8)

def time_mode(fn, eng):
    fn()                                      # warmup/compile
    ts = []
    for _ in range(ITERS):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    runs = ITERS + 1
    return (float(np.median(ts)), eng.wasted_lane_steps // runs,
            eng.lane_steps // runs)

t_r, w_r, s_r = time_mode(
    lambda: eng_round.run(items, lambda r: None), eng_round)
t_c, w_c, s_c = time_mode(
    lambda: eng_cont.run(items, lambda r: None, continuous=True),
    eng_cont)
print(json.dumps({"round": [t_r, w_r, s_r],
                  "continuous": [t_c, w_c, s_c]}))
"""


def run_composed_continuous(size=64, stream_n=12, lanes=4,
                            iters=3) -> list[dict]:
    """Round barrier vs continuous refill on the COMPOSED (lanes over
    'data' × per-lane frames ppermute-decomposed over 'model')
    deployment — an 8-virtual-device subprocess, bimodal trip counts.
    The waste ratio carries the claim (CPU interpret wall time is
    emulation-bound); parity and jaxpr structure are pinned in
    tests/core/test_farm.py::TestComposedContinuous."""
    import json
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _COMPOSED_WORKER % (src, size, stream_n, lanes, iters)
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=900)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-1500:])
        res = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:
        return [record(f"stream_{size}_composed", -1.0, mesh="2x4",
                       derived=f"ERROR:{type(e).__name__}")]
    rows = []
    (t_r, w_r, s_r), (t_c, w_c, s_c) = res["round"], res["continuous"]
    rows.append(record(
        f"stream_{size}_composed_round_bimodal", t_r,
        backend="pallas-sharded", mesh="2x4",
        derived=(f"items_per_s={stream_n / t_r:.1f};"
                 f"wasted_lane_steps={w_r};lane_steps={s_r}")))
    rows.append(record(
        f"stream_{size}_composed_continuous_bimodal", t_c,
        backend="pallas-sharded", mesh="2x4",
        derived=(f"items_per_s={stream_n / t_c:.1f};"
                 f"wasted_lane_steps={w_c};lane_steps={s_c};"
                 f"waste_cut={w_r / max(w_c, 1):.1f}x")))
    return rows


_RECOVERY_WORKER = """
import json, os, sys
sys.path.insert(0, %(src)r)
import time
import numpy as np
from repro.core import FarmEngine, LoopOfStencilReduce
from repro.resilience import FaultPlan, RecoveryConfig

SIZE, STREAM_N, LANES, AT = %(size)d, %(stream_n)d, %(lanes)d, %(at)d

def mk():
    return LoopOfStencilReduce(
        f=lambda get, *_: get(0, 0) - 1.0, k=1, combine="max",
        cond=lambda r: r < 0.5, boundary="zero", max_iters=64,
        backend="pallas", block=(32, 128))

base = np.linspace(0.1, 0.9, SIZE * SIZE,
                   dtype=np.float32).reshape(SIZE, SIZE)
trips = [40 if i %% 4 == 3 else 2 for i in range(STREAM_N)]
items = [base + float(t) - 1.0 for t in trips]

rec = RecoveryConfig(dir=%(recdir)r, snapshot_every=1)
resume = os.path.isdir(rec.snap_dir) or os.path.exists(rec.journal_path)
# armed on first launch only; AT sits at ~50%% of the uninterrupted
# run's segment count
hook = None if resume else FaultPlan(
    lanes=LANES, preempt_at_segment=AT).preempt_hook()
eng = FarmEngine(mk(), lanes=LANES, segment=8)
t0 = time.perf_counter()
n = eng.run(items, lambda r: None, continuous=True, recovery=rec,
            resume=resume, on_segment=hook)
wall = time.perf_counter() - t0
with open(%(statpath)r, "w") as f:
    json.dump({"n_out": n, "wall": wall,
               "recovery_seconds": eng.stats["recovery_seconds"],
               "replayed_items": eng.stats["replayed_items"],
               "recovered_occupants": eng.stats["recovered_occupants"],
               "segments": eng.stats["segments"],
               "snapshots": eng.stats["snapshots"]}, f)
"""


def run_recovery(size=64, stream_n=16, lanes=4) -> list[dict]:
    """Preempt-at-~50%% kill-and-respawn: a recovery-armed continuous
    farm is killed (``os._exit``, no cleanup) halfway through a bimodal
    stream and respawned with ``--resume`` semantics.  Records the
    resumed run's ``recovery_seconds`` (journal replay + snapshot
    restore + re-seating, the restart tax the snapshot cadence buys)
    and ``replayed_items`` / ``recovered_occupants`` next to the
    fault-free wall time — the robustness claim's standing perf row."""
    import json
    import os
    import subprocess
    import sys
    import tempfile
    import time as _time

    from repro.resilience.recovery import run_to_completion

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    # fault-free baseline in-process (same engine config)
    base = np.linspace(0.1, 0.9, size * size,
                       dtype=np.float32).reshape(size, size)
    items = [base + float(40 if i % 4 == 3 else 2) - 1.0
             for i in range(stream_n)]
    eng0 = FarmEngine(_mk_countdown(), lanes=lanes, segment=8)
    eng0.run(items, lambda r: None, continuous=True)     # compile
    segments0 = eng0.stats["segments"]
    eng1 = FarmEngine(_mk_countdown(), lanes=lanes, segment=8)
    t0 = _time.perf_counter()
    n0 = eng1.run(items, lambda r: None, continuous=True)
    t_clean = _time.perf_counter() - t0
    assert n0 == stream_n

    with tempfile.TemporaryDirectory() as d:
        statpath = os.path.join(d, "stats.json")
        code = _RECOVERY_WORKER % {
            "src": src, "size": size, "stream_n": stream_n,
            "lanes": lanes, "at": max(segments0 // 2, 1),
            "recdir": os.path.join(d, "rec"), "statpath": statpath}
        env = dict(os.environ)
        try:
            t0 = _time.perf_counter()
            restarts = run_to_completion(
                [sys.executable, "-c", code], env=env, max_restarts=4,
                timeout=900)
            t_total = _time.perf_counter() - t0
            with open(statpath) as f:
                st = json.load(f)
        except Exception as e:
            return [record(f"stream_{size}_recovery_preempt50", -1.0,
                           derived=f"ERROR:{type(e).__name__}")]
    if st["n_out"] != stream_n:
        return [record(f"stream_{size}_recovery_preempt50", -1.0,
                       derived=f"ERROR:items={st['n_out']}")]
    return [record(
        f"stream_{size}_recovery_preempt50", st["wall"],
        backend="pallas",
        derived=(f"recovery_seconds={st['recovery_seconds']:.4f};"
                 f"replayed_items={st['replayed_items']};"
                 f"recovered_occupants={st['recovered_occupants']};"
                 f"restarts={restarts};"
                 f"snapshots={st['snapshots']};"
                 f"clean_wall={t_clean:.4f};"
                 f"total_wall_with_kill={t_total:.4f}"))]


def run(sizes=(64,), stream_n=24, lanes=4, iters=9) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    mesh = jax.make_mesh((1,), ("data",))
    for size in sizes:
        items = _stream(rng, size, stream_n)
        for backend in ("pallas",):
            loop = _mkloop(backend)
            jrun = jax.jit(loop.run)

            # every deployment delivers per-item (a, iters) results to a
            # host sink — the stream write stage — so the comparison is
            # end to end, not dispatch-only
            def per_item():
                sink = []
                for it in items:
                    res = jrun(jnp.asarray(it))
                    sink.append(np.asarray(res.a))
                return sink[-1]

            old_farm = sharded_farm(loop.run, mesh)

            def batch_farm():
                sink = []
                for i in range(0, stream_n, lanes):
                    chunk = np.stack(items[i:i + lanes])
                    count = chunk.shape[0]
                    if count < lanes:              # keep one compilation
                        chunk = np.concatenate(
                            [chunk, np.zeros((lanes - count,
                                              size, size), np.float32)])
                    res = old_farm(chunk)
                    a = np.asarray(res.a)
                    for j in range(count):
                        sink.append(a[j])
                return sink[-1]

            eng = FarmEngine(loop, lanes=lanes)

            def lane_engine():
                sink = []
                eng.run(items, lambda r: sink.append(r.a))
                return sink[-1]

            # chained continuous dispatch (DESIGN.md §Dispatch
            # pipeline): staging ring + fused segment/refill + ring-
            # seated initial cohort, lag-1 drain — the per-segment host
            # round trips the plain lane engine pays are gone, and
            # mid-flight refill reclaims the max-of-chunk waste
            # batch_farm burns on the trip-count spread, so this row
            # must not lose to the re-framing strawman (CI-asserted).
            # unroll=4 is the engine's tuned config: 4 sweeps per
            # while trip cuts the loop-carry overhead that dominates
            # short segments (the auto_unroll segment fold makes the
            # same call on the deep backends).
            eng_async = FarmEngine(_mkloop(backend, unroll=4),
                                   lanes=lanes, segment=12)

            def lane_engine_async():
                sink = []
                eng_async.run(items, lambda r: sink.append(r.a),
                              continuous=True)
                return sink[-1]

            ts = paired_times([("per_item", per_item),
                               ("batch_farm", batch_farm),
                               ("lane_engine", lane_engine),
                               ("lane_engine_async",
                                lane_engine_async)],
                              warmup=1, iters=iters)
            t_item, t_old, t_new = (ts["per_item"], ts["batch_farm"],
                                    ts["lane_engine"])
            t_async = ts["lane_engine_async"]
            ips = stream_n / max(t_new, 1e-12)
            bpi = ((eng.stats["h2d_bytes"] + eng.stats["d2h_bytes"])
                   / max(eng.stats["items"], 1))
            rows.append(record(
                f"stream_{size}_per_item", t_item, backend=backend,
                derived=f"items_per_s={stream_n / t_item:.1f}"))
            rows.append(record(
                f"stream_{size}_batch_farm", t_old, backend=backend,
                derived=f"items_per_s={stream_n / t_old:.1f}"))
            rows.append(record(
                f"stream_{size}_lane_engine", t_new, backend=backend,
                derived=(f"items_per_s={ips:.1f};"
                         f"host_bytes_per_item={bpi:.0f};"
                         f"speedup_vs_batch_farm={t_old / t_new:.2f}x")))
            rows.append(record(
                f"stream_{size}_lane_engine_async", t_async,
                backend=backend,
                derived=(f"items_per_s={stream_n / t_async:.1f};"
                         f"speedup_vs_batch_farm="
                         f"{t_old / t_async:.2f}x;"
                         f"segments={eng_async.stats['segments']};"
                         f"chain_traces="
                         f"{eng_async.stats['chain_traces']}")))
    rows += run_continuous(sizes=sizes, stream_n=max(stream_n // 2, 8),
                           lanes=lanes, iters=max(iters // 2, 3))
    rows += run_composed_continuous(size=min(sizes), lanes=lanes,
                                    iters=max(iters // 3, 2))
    rows += run_recovery(size=min(sizes),
                         stream_n=max(stream_n // 2, 8), lanes=lanes)
    return rows


if __name__ == "__main__":
    from .common import csv_row
    print("\n".join(csv_row(r) for r in run()))
