"""qwen3-1.7b — 28L d2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
QK-norm + GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-1.7b", family="dense",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=6144, vocab_size=151936,
        qk_norm=True, act="silu", rope_theta=1_000_000.0,
        tie_embeddings=True)


def reduced() -> ArchConfig:
    return shrink(config())
