"""Architecture config system.

Every assigned architecture is an :class:`ArchConfig`; the generic stack in
:mod:`repro.models.transformer` interprets the *block pattern*: a repeating
unit of :class:`LayerSpec` entries (scanned ``n_repeats`` times) plus
optional unscanned prefix layers.  This keeps trace/compile time O(unit)
instead of O(depth) — required for the 80-compile dry-run and the right
call at 1000-node scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating block unit."""
    kind: str = "attn"          # "attn" | "ssm"
    window: int = 0             # sliding-window size (attn; 0 = global)
    ffn: str = "dense"          # "dense" | "moe" | "none"
    cross: bool = False         # decoder cross-attention (enc-dec)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | audio | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 → d_model // num_heads

    # attention
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0
    local_global: bool = False  # alternate local/global layers (gemma2)
    post_norms: bool = False    # gemma2 post-block norms
    embed_scale: bool = False   # gemma2 √d_model embedding scaling

    # FFN / MoE
    mlp_gated: bool = True
    act: str = "silu"
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    shared_d_ff: int = 0
    first_dense: bool = False   # deepseek-moe: layer 0 dense
    moe_every: int = 1          # jamba: MoE each Nth layer
    moe_capacity_factor: float = 1.25
    moe_dropless: bool = False  # exact dispatch (C=T); decode/smoke paths

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    attn_period: int = 0        # jamba: attn layer every N layers ...
    attn_offset: int = 0        # ... at this offset within the period

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500     # stub audio frontend frame count

    # vlm
    vision_patches: int = 0     # stub CLIP patch count
    vision_embed_dim: int = 0   # stub patch-embedding dim (pre-projection)

    # positions
    use_rope: bool = True
    abs_pos_embed: bool = False  # whisper: absolute position embeddings

    # parallelism policy
    attn_sequence_parallel: bool = False
    # ^ context-parallel attention: replicate attention weights and shard
    #   the sequence on the model axis instead.  Used when the head counts
    #   don't divide the TP degree (phi3: 40H/10KV vs tp=16; whisper: 8H)
    #   — the sequence is the shardable axis, exactly the paper's 1-D
    #   stencil decomposition (DESIGN.md §4).

    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    vocab_pad_to: int = 256
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "block_outs"
    # "full"       — recompute the whole unit (3rd collective pass in bwd)
    # "block_outs" — save the post-collective attention/FFN block outputs:
    #                the backward pass never re-runs the TP all-reduces
    #                (≈ -1/3 collective bytes for ~67MB/layer saved)

    # ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        q = self.vocab_pad_to
        return -(-self.vocab_size // q) * q

    def block_pattern(self) -> Tuple[Tuple[LayerSpec, ...],
                                     Tuple[LayerSpec, ...], int]:
        """Returns (prefix_layers, repeat_unit, n_repeats)."""
        if self.family == "ssm":
            return (), (LayerSpec(kind="ssm", ffn="none"),), self.num_layers
        if self.family == "hybrid":
            unit = []
            for i in range(self.attn_period):
                kind = "attn" if i == self.attn_offset else "ssm"
                ffn = ("moe" if self.n_experts and
                       (i % self.moe_every == self.moe_every - 1) else
                       "dense")
                unit.append(LayerSpec(kind=kind, ffn=ffn))
            reps, rem = divmod(self.num_layers, self.attn_period)
            assert rem == 0, "hybrid depth must be a multiple of the period"
            return (), tuple(unit), reps
        if self.n_experts:
            moe_spec = LayerSpec(kind="attn", ffn="moe")
            if self.first_dense:
                return ((LayerSpec(kind="attn", ffn="dense"),),
                        (moe_spec,), self.num_layers - 1)
            return (), (moe_spec,), self.num_layers
        if self.local_global:
            unit = (LayerSpec(kind="attn", window=self.sliding_window),
                    LayerSpec(kind="attn", window=0))
            reps, rem = divmod(self.num_layers, 2)
            assert rem == 0
            return (), unit, reps
        window = self.sliding_window
        return (), (LayerSpec(kind="attn", window=window),), self.num_layers

    def decoder_pattern(self):
        """Enc-dec models: the decoder unit (self-attn + cross + FFN)."""
        assert self.is_encoder_decoder
        return ((), (LayerSpec(kind="attn", cross=True),),
                self.num_layers)

    @property
    def sub_quadratic(self) -> bool:
        """Whether long-context (500k) decode is admissible (DESIGN.md)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.local_global:        # half the layers are sequence stencils
            return True
        return False


def shrink(cfg: ArchConfig) -> ArchConfig:
    """Derive the reduced smoke-test config: same family/pattern/features,
    tiny dimensions.  Exercised by per-arch CPU smoke tests; the full
    config is exercised only via the dry-run (no allocation)."""
    if cfg.family == "hybrid":
        layers = cfg.attn_period
    elif cfg.local_global:
        layers = 4
    elif cfg.first_dense:
        layers = 3
    else:
        layers = 2
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=160 if cfg.d_ff else 0,
        vocab_size=736,
        sliding_window=8 if cfg.sliding_window else 0,
        n_experts=4 if cfg.n_experts else 0,
        top_k=2 if cfg.top_k else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        expert_d_ff=64 if cfg.expert_d_ff else 0,
        shared_d_ff=96 if cfg.shared_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=8,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=12 if cfg.is_encoder_decoder else cfg.encoder_seq,
        vision_patches=4 if cfg.vision_patches else 0,
        vision_embed_dim=24 if cfg.vision_embed_dim else 0,
        moe_dropless=True,
        dtype="float32",
        remat=False,
    )


def register(cfg_fn):
    """Decorator: register ``<arch>.py``'s config() under its name."""
    cfg = cfg_fn()
    _REGISTRY[cfg.name] = cfg_fn
    return cfg_fn


def get_config(name: str) -> ArchConfig:
    from . import ALL_ARCHS  # ensure modules imported
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    from . import ALL_ARCHS
    return sorted(_REGISTRY)
