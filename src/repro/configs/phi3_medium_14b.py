"""phi3-medium-14b — 40L d5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
RoPE + SwiGLU + GQA. [arXiv:2404.14219; unverified]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        head_dim=128, d_ff=17920, vocab_size=100352,
        act="silu", rope_theta=10_000.0, tie_embeddings=False,
        # 40 heads / 10 KV heads don't divide tp=16 -> context-parallel
        # attention (sequence sharded on the model axis)
        attn_sequence_parallel=True)


def reduced() -> ArchConfig:
    return shrink(config())
