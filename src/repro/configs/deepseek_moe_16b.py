"""deepseek-moe-16b — 28L d2048 16H (kv=16) vocab=102400; fine-grained MoE:
2 shared + 64 routed experts, top-6, expert d_ff=1408 (dense layer 0 uses
d_ff=10944). [arXiv:2401.06066; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=10944, vocab_size=102400,
        n_experts=64, top_k=6, n_shared_experts=2,
        expert_d_ff=1408, shared_d_ff=2816, first_dense=True,
        act="silu", rope_theta=10_000.0, tie_embeddings=False)


def reduced() -> ArchConfig:
    return shrink(config())
