"""whisper-base — enc-dec, 6+6L d512 8H d_ff=2048 vocab=51865; conv audio
frontend STUBBED per assignment (input_specs provides precomputed frame
embeddings, 1500 frames); absolute positions, non-gated GELU MLP.
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865,
        is_encoder_decoder=True, encoder_layers=6, encoder_seq=1500,
        use_rope=False, abs_pos_embed=True,
        mlp_gated=False, act="gelu", tie_embeddings=True,
        # 8 heads < tp=16 -> context-parallel attention
        attn_sequence_parallel=True)


def reduced() -> ArchConfig:
    return shrink(config())
