"""gemma2-9b — 42L d3584 16H (GQA kv=8) hd256 d_ff=14336 vocab=256000.
Local(4096-window)+global alternating attention, attn softcap 50, final
logit softcap 30, post-block norms, GeGLU. [arXiv:2408.00118; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b", family="dense",
        num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=14336, vocab_size=256000,
        sliding_window=4096, local_global=True,
        attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, embed_scale=True,
        act="gelu", rope_theta=10_000.0, tie_embeddings=True)


def reduced() -> ArchConfig:
    return shrink(config())
