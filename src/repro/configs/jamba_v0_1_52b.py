"""jamba-v0.1-52b — 32L d4096 32H (GQA kv=8) d_ff=14336 vocab=65536;
hybrid Mamba+attention 1:7 interleave (attn at offset 4 of each 8-layer
block), MoE 16 experts top-2 on every other layer, no positional
embeddings (Mamba carries position).  We instantiate the Mamba layers with
our Mamba-2/SSD block (d_state=16) — deviation noted in DESIGN.md.
[arXiv:2403.19887; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=65536,
        n_experts=16, top_k=2, expert_d_ff=14336, moe_every=2,
        attn_period=8, attn_offset=4,
        ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        use_rope=False, act="silu", tie_embeddings=False)


def reduced() -> ArchConfig:
    return shrink(config())
