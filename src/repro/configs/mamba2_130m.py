"""mamba2-130m — 24L d768, attention-free SSD (state-space duality),
ssm_state=128, vocab=50280. [arXiv:2405.21060; unverified]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        use_rope=False, tie_embeddings=True, norm_eps=1e-5)


def reduced() -> ArchConfig:
    return shrink(config())
