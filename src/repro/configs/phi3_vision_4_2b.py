"""phi-3-vision-4.2b — 32L d3072 32H (kv=32) d_ff=8192 vocab=32064;
phi3-mini backbone + CLIP frontend STUBBED (input_specs provides
precomputed patch embeddings, 576 patches @ 1024-d, projected in).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        head_dim=96, d_ff=8192, vocab_size=32064,
        vision_patches=576, vision_embed_dim=1024,
        act="silu", rope_theta=10_000.0, tie_embeddings=False)


def reduced() -> ArchConfig:
    return shrink(config())
