"""qwen3-moe-30b-a3b — 48L d2048 32H (GQA kv=4) vocab=151936; 128 experts
top-8, expert d_ff=768, QK-norm. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        n_experts=128, top_k=8, expert_d_ff=768,
        qk_norm=True, act="silu", rope_theta=1_000_000.0,
        tie_embeddings=False)


def reduced() -> ArchConfig:
    return shrink(config())
