"""yi-9b — 48L d4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
Llama-architecture GQA. [arXiv:2403.04652; hf]"""
from .base import ArchConfig, register, shrink


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="yi-9b", family="dense",
        num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=11008, vocab_size=64000,
        act="silu", rope_theta=10_000.0, tie_embeddings=False)


def reduced() -> ArchConfig:
    return shrink(config())
