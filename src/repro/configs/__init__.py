"""Assigned-architecture configs (10 archs) + registry access."""
from . import (gemma2_9b, phi3_medium_14b, yi_9b, qwen3_1_7b,
               deepseek_moe_16b, qwen3_moe_30b_a3b, whisper_base,
               mamba2_130m, phi3_vision_4_2b, jamba_v0_1_52b)
from .base import ArchConfig, LayerSpec, get_config, list_archs, shrink

ALL_ARCHS = [
    "gemma2-9b", "phi3-medium-14b", "yi-9b", "qwen3-1.7b",
    "deepseek-moe-16b", "qwen3-moe-30b-a3b", "whisper-base",
    "mamba2-130m", "phi-3-vision-4.2b", "jamba-v0.1-52b",
]

_MODULES = {
    "gemma2-9b": gemma2_9b, "phi3-medium-14b": phi3_medium_14b,
    "yi-9b": yi_9b, "qwen3-1.7b": qwen3_1_7b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b, "whisper-base": whisper_base,
    "mamba2-130m": mamba2_130m, "phi-3-vision-4.2b": phi3_vision_4_2b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
}


def get_reduced(name: str) -> ArchConfig:
    return _MODULES[name].reduced()
