"""Shared model layers: norms, RoPE, MLPs, MoE.

Everything is functional (params are explicit pytrees) so the whole stack
is transparent to pjit/shard_map, scan-over-layers, remat, and the
dry-run's eval_shape path (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


Params = dict  # nested param pytrees


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return ((1.0 + scale.astype(jnp.float32)) * out).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


# -- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings."""
    pos = np.arange(seq)[:, None]
    inv = 1.0 / (10000 ** (np.arange(0, dim, 2) / dim))
    ang = pos * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


# -- MLP --------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, gated: bool, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(d_ff))
    p = {"up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
         "down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}
    if gated:
        p["gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def mlp(params: Params, x, act: str = "silu"):
    a = ACTS[act]
    up = x @ params["up"]
    h = a(x @ params["gate"]) * up if "gate" in params else a(up)
    return h @ params["down"]


# -- MoE --------------------------------------------------------------------

def init_moe(key, d_model, n_experts, expert_d_ff, n_shared, shared_d_ff,
             gated: bool, dtype) -> Params:
    ks = jax.random.split(key, 4)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(expert_d_ff))
    ncols = 3 if gated else 2
    p = {
        "router": jax.random.normal(ks[0], (d_model, n_experts),
                                    jnp.float32) * s_in,
        # experts stacked on a leading axis => expert-parallel shardable
        "w_up": jax.random.normal(ks[1], (n_experts, d_model, expert_d_ff),
                                  dtype) * s_in,
        "w_gate": jax.random.normal(ks[2], (n_experts, d_model, expert_d_ff),
                                    dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (n_experts, expert_d_ff, d_model),
                                    dtype) * s_out,
    }
    if n_shared:
        p["shared"] = init_mlp(jax.random.fold_in(key, 7), d_model,
                               shared_d_ff, gated, dtype)
    return p


def moe(params: Params, x, *, top_k: int, act: str = "silu",
        capacity_factor: float = 1.25, dropless: bool = False):
    """Token-choice top-k MoE, capacity-based sorted dispatch (GShard-style).

    The TPU-native formulation: flatten the (token, choice) assignments,
    sort by expert id, rank each token within its expert, drop past the
    per-expert capacity ``C = ceil(T·k/E · capacity_factor)``, scatter into
    an (E, C, D) buffer, run every expert as one batched einsum on the MXU,
    and scatter-add weighted results back.  With the expert axis sharded on
    the ``model`` mesh axis this is expert parallelism — the dispatch
    scatter/gather lower to the token⇄expert all-to-all.
    Returns (output, aux) with load-balancing stats.
    """
    a = ACTS[act]
    B, S, D = x.shape
    E = params["w_up"].shape[0]
    xt = x.reshape(-1, D)                                    # (T, D)
    T = xt.shape[0]
    # dropless: worst case one expert receives every token (C = T) —
    # exact but memory ∝ E·T; used for decode/consistency paths
    C = T if dropless else max(
        1, int(np.ceil(T * top_k / E * capacity_factor)))

    logits = (xt.astype(jnp.float32) @ params["router"])     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)               # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # flatten assignments and sort by expert id (stable → earlier tokens win)
    eid = top_i.reshape(-1)                                  # (T·k,)
    tid = jnp.repeat(jnp.arange(T), top_k)
    wgt = top_p.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s, tid_s, wgt_s = eid[order], tid[order], wgt[order]
    # rank within expert = position − first position of that expert
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    pos_s = jnp.arange(T * top_k) - first
    keep = pos_s < C
    # dropped assignments are routed OUT OF BOUNDS so mode="drop"
    # discards them (an in-range clamp would overwrite slot (e, 0))
    eid_c = jnp.where(keep, eid_s, E)
    pos_c = jnp.where(keep, pos_s, 0)

    # dispatch: (E, C, D) expert buffers
    xe = jnp.zeros((E, C, D), x.dtype).at[eid_c, pos_c].set(
        xt[tid_s], mode="drop")
    h = a(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])     # (E, C, D)

    # combine: weighted scatter-add back to token order
    back = ye[jnp.where(keep, eid_s, 0), pos_c] \
        * (wgt_s * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[tid_s].add(back, mode="drop")
    if "shared" in params:
        y = y + mlp(params["shared"], xt, act)
    # aux: load-balance loss terms (Switch-style) + drop fraction
    me = probs.mean(axis=0)                                  # router prob mass
    ce = jnp.zeros((E,), jnp.float32).at[eid].add(1.0) / (T * top_k)
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "router_z": jnp.mean(
               jax.scipy.special.logsumexp(logits, axis=-1) ** 2),
           "drop_frac": 1.0 - keep.mean()}
    return y.reshape(B, S, D), aux
