"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) in JAX.

The SSD chunked algorithm is an instance of the paper's pattern vocabulary:

* the depthwise causal conv (width 4) is a one-sided 1-D **stencil** along
  the sequence;
* the chunked scan is a **stencil-with-carry**: quadratic attention-like
  compute *within* a chunk (local neighbourhood) plus a linear recurrence
  *between* chunk states — which is exactly how the distributed stencil
  propagates halo state between shards;
* decode is the -s variant's ideal case: O(1) state, the loop carries
  ``h`` in device memory across iterations (memory persistence).

Scalar-per-head A (the Mamba-2 restriction), grouped B/C (ngroups=1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, rms_norm

CHUNK = 128  # SSD chunk length (MXU-aligned)


def ssm_dims(d_model: int, expand: int = 2, head_dim: int = 64,
             state: int = 128, conv_width: int = 4, ngroups: int = 1):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    return dict(d_inner=d_inner, nheads=nheads, head_dim=head_dim,
                state=state, conv_width=conv_width, ngroups=ngroups)


def init_ssm(key, d_model, dims, dtype) -> Params:
    ks = jax.random.split(key, 6)
    di, nh, hd, n, cw = (dims["d_inner"], dims["nheads"], dims["head_dim"],
                         dims["state"], dims["conv_width"])
    g = dims["ngroups"]
    s = float(1.0 / np.sqrt(d_model))
    # in_proj emits [z (di), x (di), B (g·n), C (g·n), dt (nh)]
    d_proj = 2 * di + 2 * g * n + nh
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, d_proj), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (cw, di + 2 * g * n), dtype) * 0.2,
        "conv_b": jnp.zeros((di + 2 * g * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.random.default_rng(0).uniform(
                1e-3, 0.1, nh))), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d_model), dtype)
        * float(1.0 / np.sqrt(di)),
    }


def _split_proj(z_x_b_c_dt, dims):
    di, n, g, nh = (dims["d_inner"], dims["state"], dims["ngroups"],
                    dims["nheads"])
    z, x, B, C, dt = jnp.split(
        z_x_b_c_dt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return z, x, B, C, dt


def causal_conv(x, w, b, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along the sequence (1-D stencil, k one-sided).

    x: (B, S, C); w: (W, C).  With ``cache`` (B, W-1, C) this is the decode
    step: returns (y, new_cache).
    """
    W = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
        new_cache = None
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
        new_cache = xp[:, -(W - 1):].astype(cache.dtype)
    # taps formulation: y_t = Σ_w x_{t-(W-1)+w} · w_w  (shift algebra)
    S = x.shape[1]
    y = sum(xp[:, i:i + S] * w[i] for i in range(W)) + b
    return jax.nn.silu(y), new_cache


def ssd_chunked(x, dt, A, B, C, D, *, dims, h0=None):
    """SSD forward over a full sequence (training / prefill).

    x: (Bt, S, nh, hd); dt: (Bt, S, nh); A: (nh,) negative decay rates;
    B, C: (Bt, S, g, n).  Returns (y, h_last) with h: (Bt, nh, hd, n).

    Chunked algorithm: intra-chunk quadratic term (masked (C·Bᵀ) kernel on
    the MXU) + inter-chunk linear recurrence over chunk states (the carry).
    """
    Bt, S, nh, hd = x.shape
    n = dims["state"]
    g = dims["ngroups"]
    Q = min(CHUNK, S)
    S_orig = S
    if S % Q:
        # pad to a chunk multiple with dt=0 steps: a=exp(0)=1 keeps the
        # state, dt·x·B=0 adds nothing — padding is exactly inert
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    # broadcast groups over heads
    heads_per_g = nh // g
    Bh = jnp.repeat(B, heads_per_g, axis=2) if g != nh else B   # (Bt,S,nh,n)
    Ch = jnp.repeat(C, heads_per_g, axis=2) if g != nh else C

    xc = x.reshape(Bt, nc, Q, nh, hd)
    dtc = dt.reshape(Bt, nc, Q, nh)
    Bc = Bh.reshape(Bt, nc, Q, nh, n)
    Cc = Ch.reshape(Bt, nc, Q, nh, n)

    dA = dtc * (-jnp.exp(A))[None, None, None, :]       # log-decay ≤ 0
    La = jnp.cumsum(dA, axis=2)                         # (Bt,nc,Q,nh)
    Ltot = La[:, :, -1]                                 # (Bt,nc,nh)

    # intra-chunk: y_i = Σ_{j<=i} exp(La_i - La_j) (C_i·B_j) dt_j x_j
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)       # (Bt,nc,nh,Q,Q)
    Li = La.transpose(0, 1, 3, 2)                       # (Bt,nc,nh,Q)
    decay = jnp.exp(Li[..., :, None] - Li[..., None, :])  # exp(La_i - La_j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    kernel = jnp.where(mask, CB * decay, 0.0)
    dx = (dtc[..., None] * xc)                          # (Bt,nc,Q,nh,hd)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", kernel, dx)

    # chunk states: S_c = Σ_j exp(Ltot - La_j) dt_j x_j ⊗ B_j
    sdecay = jnp.exp(Ltot[:, :, None] - La)             # (Bt,nc,Q,nh)
    states = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", sdecay, dx, Bc)

    # inter-chunk recurrence: h_c = exp(Ltot_c) h_{c-1} + S_c  (the carry)
    def scan_fn(h, inp):
        st, ltot = inp
        h_new = jnp.exp(ltot)[..., None, None] * h + st
        return h_new, h
    h_init = (jnp.zeros((Bt, nh, hd, n), jnp.float32)
              if h0 is None else h0.astype(jnp.float32))
    states_t = states.transpose(1, 0, 2, 3, 4)          # (nc,Bt,nh,hd,n)
    ltot_t = Ltot.transpose(1, 0, 2)                    # (nc,Bt,nh)
    h_last, h_prevs = jax.lax.scan(scan_fn, h_init, (states_t, ltot_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (Bt,nc,nh,hd,n)

    # inter-chunk contribution: y_i += exp(La_i) C_i · h_{c-1}
    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(La), Cc, h_prevs)
    y = (y_intra + y_inter).reshape(Bt, S, nh, hd)
    y = y + D[None, None, :, None] * x
    return y[:, :S_orig].astype(x.dtype), h_last


def ssd_ref(x, dt, A, B, C, D, *, dims, h0=None):
    """Sequential-scan oracle for :func:`ssd_chunked` (O(S) steps)."""
    Bt, S, nh, hd = x.shape
    n = dims["state"]
    g = dims["ngroups"]
    heads_per_g = nh // g
    Bh = jnp.repeat(B, heads_per_g, axis=2) if g != nh else B
    Ch = jnp.repeat(C, heads_per_g, axis=2) if g != nh else C

    def step(h, inp):
        xt, dtt, bt, ct = inp                            # (Bt,nh,hd) ...
        a = jnp.exp(dtt * (-jnp.exp(A))[None, :])        # (Bt,nh)
        h = a[..., None, None] * h + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y
    h_init = (jnp.zeros((Bt, nh, hd, n), jnp.float32)
              if h0 is None else h0.astype(jnp.float32))
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    h_last, ys = jax.lax.scan(step, h_init, xs)
    y = ys.transpose(1, 0, 2, 3) + D[None, None, :, None] * x
    return y.astype(x.dtype), h_last


def mamba2_block(params: Params, x, *, dims, norm_eps=1e-6,
                 ssm_cache: Optional[dict] = None, use_ref=False):
    """Full Mamba-2 block.  Returns (out, new_cache or None).

    cache = {'conv': (B, W-1, di+2gn), 'h': (B, nh, hd, n)} for decode.
    """
    Bt, S, D = x.shape
    di, nh, hd, n = (dims["d_inner"], dims["nheads"], dims["head_dim"],
                     dims["state"])
    proj = x @ params["in_proj"]
    z, xs, Bv, Cv, dt = _split_proj(proj, dims)
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)
    conv_cache = None if ssm_cache is None else ssm_cache["conv"]
    conv_out, new_conv = causal_conv(conv_in, params["conv_w"],
                                     params["conv_b"], conv_cache)
    xs, Bv, Cv = jnp.split(conv_out, [di, di + dims["ngroups"] * n], axis=-1)
    xs = xs.reshape(Bt, S, nh, hd)
    Bv = Bv.reshape(Bt, S, dims["ngroups"], n)
    Cv = Cv.reshape(Bt, S, dims["ngroups"], n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])

    A = params["A_log"]
    h0 = None if ssm_cache is None else ssm_cache["h"]
    if ssm_cache is not None and S == 1:
        # decode: exact single-step recurrence (O(1) state update)
        y, h_last = ssd_ref(xs, dt, A, Bv, Cv, params["D"], dims=dims, h0=h0)
    elif use_ref:
        y, h_last = ssd_ref(xs, dt, A, Bv, Cv, params["D"], dims=dims, h0=h0)
    else:
        y, h_last = ssd_chunked(xs, dt, A, Bv, Cv, params["D"], dims=dims,
                                h0=h0)
    y = y.reshape(Bt, S, di)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    out = y @ params["out_proj"]
    new_cache = (None if ssm_cache is None
                 else {"conv": new_conv, "h": h_last})
    return out, new_cache


def init_ssm_cache(batch, dims, dtype=jnp.float32):
    """conv cache in the model dtype (it joins activations directly);
    the recurrent state h stays fp32 (exactness of the recurrence)."""
    di, nh, hd, n = (dims["d_inner"], dims["nheads"], dims["head_dim"],
                     dims["state"])
    cw, g = dims["conv_width"], dims["ngroups"]
    return {"conv": jnp.zeros((batch, cw - 1, di + 2 * g * n), dtype),
            "h": jnp.zeros((batch, nh, hd, n), jnp.float32)}
