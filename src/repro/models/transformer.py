"""Generic layer-stack model: interprets an ArchConfig's block pattern.

One code path serves all ten assigned architectures (dense GQA, local+global
alternation, fine-grained MoE, SSD/Mamba-2, hybrid interleave, enc-dec,
VLM-stub).  The repeating block unit is scanned (`jax.lax.scan`) over
stacked parameters so trace/compile cost is O(unit), not O(depth), and
remat checkpoints exactly one unit.

Entry points:
    init_params(cfg, key, max_position)      — real weights (smoke/training)
    forward(cfg, params, batch, ...)         — logits for train / prefill
    init_cache(cfg, batch, max_seq)          — stacked KV/SSM caches
    decode_step(cfg, params, cache, tokens, pos, ...) — one serving step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, LayerSpec
from . import ssm as ssm_mod
from .attention import attention, init_attention, init_kv_cache
from .layers import (Params, init_mlp, init_moe, mlp, moe, rms_norm,
                     sinusoidal_positions, softcap)


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# Optional activation-sharding hook (installed by the launcher; identity
# by default so the model stays mesh-agnostic).  Signature:
#     hook(tag: str, x: Array) -> Array        tags: "attn_in", "attn_out"
# Used for context-parallel attention (ArchConfig.attn_sequence_parallel).
_SHARDING_HOOK = None

# Optional explicit expert-parallel MoE dispatch (shard_map schedule from
# repro.models.moe_parallel), installed by the launcher together with its
# mesh.  None -> the mesh-agnostic GSPMD-auto path in layers.moe.
_MOE_PARALLEL = None


def set_sharding_hook(fn):
    global _SHARDING_HOOK
    _SHARDING_HOOK = fn


def set_moe_parallel(fn):
    global _MOE_PARALLEL
    _MOE_PARALLEL = fn


def _hook(tag, x):
    return _SHARDING_HOOK(tag, x) if _SHARDING_HOOK is not None else x


def _ckpt_name(cfg, x, name):
    if cfg.remat and cfg.remat_policy == "block_outs":
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(x, name)
    return x


def _remat_policy(cfg):
    if cfg.remat_policy == "block_outs":
        return jax.checkpoint_policies.save_only_these_names("block_out")
    return None


def _ssm_dims(cfg: ArchConfig):
    return ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim,
                            cfg.ssm_state, cfg.ssm_conv, cfg.ssm_ngroups)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(cfg: ArchConfig, spec: LayerSpec, key) -> Params:
    dt = _dtype(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": jnp.zeros((D,), jnp.float32)}
    if spec.kind == "attn":
        p["attn"] = init_attention(ks[0], D, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, dt,
                                   qk_norm=cfg.qk_norm)
    else:
        p["ssm"] = ssm_mod.init_ssm(ks[0], D, _ssm_dims(cfg), dt)
    if cfg.post_norms:
        p["post_ln1"] = jnp.zeros((D,), jnp.float32)
    if spec.cross:
        p["ln_x"] = jnp.zeros((D,), jnp.float32)
        p["cross"] = init_attention(ks[1], D, cfg.num_heads,
                                    cfg.num_kv_heads,
                                    cfg.resolved_head_dim, dt)
    if spec.ffn == "dense":
        p["ln2"] = jnp.zeros((D,), jnp.float32)
        p["mlp"] = init_mlp(ks[2], D, cfg.d_ff, cfg.mlp_gated, dt)
    elif spec.ffn == "moe":
        p["ln2"] = jnp.zeros((D,), jnp.float32)
        p["moe"] = init_moe(ks[2], D, cfg.n_experts, cfg.expert_d_ff,
                            cfg.n_shared_experts, cfg.shared_d_ff,
                            cfg.mlp_gated, dt)
        if cfg.post_norms:
            p["post_ln2"] = jnp.zeros((D,), jnp.float32)
        return p
    if cfg.post_norms and spec.ffn != "none":
        p["post_ln2"] = jnp.zeros((D,), jnp.float32)
    return p


def init_params(cfg: ArchConfig, key, max_position: int = 0) -> Params:
    dt = _dtype(cfg)
    D, V = cfg.d_model, cfg.padded_vocab
    keys = jax.random.split(key, 16)
    params: Params = {
        "embed": jax.random.normal(keys[0], (V, D), dt) * (D ** -0.5),
        "final_norm": jnp.zeros((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(keys[1], (D, V), dt) \
            * (D ** -0.5)
    if cfg.abs_pos_embed:
        mp = max_position or 4096
        params["pos_embed"] = jax.random.normal(keys[2], (mp, D), dt) * 0.01
    if cfg.vision_patches:
        params["vision_proj"] = jax.random.normal(
            keys[3], (cfg.vision_embed_dim, D), dt) \
            * (cfg.vision_embed_dim ** -0.5)

    pattern = (cfg.decoder_pattern() if cfg.is_encoder_decoder
               else cfg.block_pattern())
    prefix, unit, reps = pattern
    params["prefix"] = [init_layer(cfg, s, jax.random.fold_in(keys[4], i))
                        for i, s in enumerate(prefix)]
    params["unit"] = [
        jax.vmap(lambda k, s=s: init_layer(cfg, s, k))(
            jax.random.split(jax.random.fold_in(keys[5], i), reps))
        for i, s in enumerate(unit)]

    if cfg.is_encoder_decoder:
        enc_spec = LayerSpec(kind="attn", ffn="dense")
        params["encoder"] = {
            "unit": [jax.vmap(lambda k: init_layer(cfg, enc_spec, k))(
                jax.random.split(keys[6], cfg.encoder_layers))],
            "final_norm": jnp.zeros((D,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def apply_layer(cfg: ArchConfig, spec: LayerSpec, p: Params, x, *,
                positions, causal=True, cache=None, cache_pos=None,
                enc_out=None, cross_cache=None, kv_len=None):
    """One block: (attn|ssm) + optional cross-attn + FFN, pre-norm residual.
    Returns (x, new_cache, aux).  ``kv_len`` is the ragged-prefill
    prompt-length mask (self-attention only; see
    :func:`repro.models.attention.attention`)."""
    aux = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.attn_sequence_parallel:
            h = _hook("attn_in", h)
        out, new_attn = attention(
            p["attn"], h, positions=positions, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta if cfg.use_rope else 0.0,
            causal=causal, window=spec.window,
            attn_softcap=cfg.attn_softcap, qk_norm=cfg.qk_norm,
            norm_eps=cfg.norm_eps, kv_cache=cache, cache_pos=cache_pos,
            kv_len=kv_len)
        if cfg.attn_sequence_parallel:
            out = _hook("attn_out", out)
        out = _ckpt_name(cfg, out, "block_out")
        new_cache = new_attn
    else:
        out, new_cache = ssm_mod.mamba2_block(
            p["ssm"], h, dims=_ssm_dims(cfg), norm_eps=cfg.norm_eps,
            ssm_cache=cache)
        out = _ckpt_name(cfg, out, "block_out")
    if cfg.post_norms:
        out = rms_norm(out, p["post_ln1"], cfg.norm_eps)
    x = x + out

    if spec.cross:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        out, _ = attention(
            p["cross"], h, positions=positions, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            causal=False, x_kv=enc_out, kv_cache=cross_cache)
        x = x + out

    if spec.ffn != "none":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            out = mlp(p["mlp"], h, cfg.act)
        elif _MOE_PARALLEL is not None and not cfg.moe_dropless:
            out, aux = _MOE_PARALLEL(p["moe"], h, top_k=cfg.top_k,
                                     act=cfg.act,
                                     capacity_factor=cfg
                                     .moe_capacity_factor)
        else:
            out, aux = moe(p["moe"], h, top_k=cfg.top_k, act=cfg.act,
                           capacity_factor=cfg.moe_capacity_factor,
                           dropless=cfg.moe_dropless or cache is not None)
        if cfg.post_norms:
            out = rms_norm(out, p["post_ln2"], cfg.norm_eps)
        out = _ckpt_name(cfg, out, "block_out")
        x = x + out
    return x, new_cache, aux


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32),
            "drop_frac": jnp.zeros((), jnp.float32)}


def _acc_aux(acc, aux):
    if not aux:
        return acc
    return {k: acc[k] + aux.get(k, 0.0) for k in acc}


def run_stack(cfg: ArchConfig, params: Params, x, *, pattern, positions,
              causal=True, caches=None, cache_pos=None, enc_out=None,
              cross_caches=None, param_root=None, kv_len=None):
    """Apply prefix layers then the scanned repeat unit.

    ``caches``/``cross_caches``: {"prefix": [...], "unit": [...]} matching
    the pattern, every unit leaf stacked on a leading reps axis.
    Returns (x, new_caches, aux_sum).
    """
    root = params if param_root is None else param_root
    prefix, unit, reps = pattern
    aux_sum = _zero_aux()
    new_caches = {"prefix": [], "unit": []}

    for i, spec in enumerate(prefix):
        c = caches["prefix"][i] if caches else None
        x, nc, aux = apply_layer(cfg, spec, root["prefix"][i], x,
                                 positions=positions, causal=causal,
                                 cache=c, cache_pos=cache_pos,
                                 enc_out=enc_out, kv_len=kv_len)
        new_caches["prefix"].append(nc)
        aux_sum = _acc_aux(aux_sum, aux)

    unit_params = root["unit"]
    unit_caches = caches["unit"] if caches else [None] * len(unit)
    unit_cross = cross_caches["unit"] if cross_caches else [None] * len(unit)

    def body(carry, xs):
        x = carry
        p_slices, c_slices, xc_slices = xs
        aux_acc = _zero_aux()
        nc_out = []
        for spec, p, c, xc in zip(unit, p_slices, c_slices, xc_slices):
            x, nc, aux = apply_layer(cfg, spec, p, x, positions=positions,
                                     causal=causal, cache=c,
                                     cache_pos=cache_pos, enc_out=enc_out,
                                     cross_cache=xc, kv_len=kv_len)
            nc_out.append(nc)
            aux_acc = _acc_aux(aux_acc, aux)
        return x, (nc_out, aux_acc)

    body_fn = jax.checkpoint(body, policy=_remat_policy(cfg)) \
        if cfg.remat else body
    x, (ncs, auxs) = jax.lax.scan(
        body_fn, x, (unit_params, unit_caches, unit_cross), length=reps)
    new_caches["unit"] = ncs
    aux_sum = {k: aux_sum[k] + auxs[k].sum() for k in aux_sum}
    return x, new_caches, aux_sum


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def encode(cfg: ArchConfig, params: Params, frames):
    """Whisper encoder over stub frame embeddings (B, F, D)."""
    D = cfg.d_model
    pos = jnp.asarray(sinusoidal_positions(frames.shape[1], D),
                      frames.dtype)
    x = frames + pos[None]
    pattern = ((), (LayerSpec(kind="attn", ffn="dense"),),
               cfg.encoder_layers)
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                                 frames.shape[:2])
    x, _, _ = run_stack(cfg, params, x, pattern=pattern,
                        positions=positions, causal=False,
                        param_root=params["encoder"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def embed_inputs(cfg: ArchConfig, params: Params, tokens, patch_embeds=None,
                 pos_offset=0):
    """Token (+vision-stub) embedding with position bookkeeping."""
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    B, S = x.shape[:2]
    positions = pos_offset + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.abs_pos_embed:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos_offset, S, axis=0)[None]
    return x, positions


def lm_head(cfg: ArchConfig, params: Params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    # The logits einsum runs in the model dtype and is upcast AFTER: the
    # loss/softmax stay fp32, but the cotangent entering the backward
    # network is bf16 — otherwise an f32 logits einsum propagates f32
    # cotangents through every layer, doubling gradient collective and
    # HBM traffic (measured 2× on qwen3-moe; EXPERIMENTS.md §Perf).
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def forward(cfg: ArchConfig, params: Params, batch: dict):
    """Training / evaluation forward: returns (logits, aux)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(cfg, params, batch["frames"])
    x, positions = embed_inputs(cfg, params, batch["tokens"],
                                batch.get("patch_embeds"))
    pattern = (cfg.decoder_pattern() if cfg.is_encoder_decoder
               else cfg.block_pattern())
    x, _, aux = run_stack(cfg, params, x, pattern=pattern,
                          positions=positions, causal=True,
                          enc_out=enc_out)
    return lm_head(cfg, params, x), aux


# -- serving ----------------------------------------------------------------

def init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     max_seq: int, dtype=jnp.bfloat16,
                     quant: bool = False):
    if spec.kind == "attn":
        return init_kv_cache(batch, max_seq, cfg.num_kv_heads,
                             cfg.resolved_head_dim, dtype,
                             window=spec.window, quant=quant)
    return ssm_mod.init_ssm_cache(batch, _ssm_dims(cfg), dtype)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, quant: bool = False):
    """Stacked decode caches for the whole stack (``quant``: int8 KV)."""
    pattern = (cfg.decoder_pattern() if cfg.is_encoder_decoder
               else cfg.block_pattern())
    prefix, unit, reps = pattern
    caches = {"prefix": [init_layer_cache(cfg, s, batch, max_seq, dtype,
                                          quant)
                         for s in prefix]}
    caches["unit"] = [
        jax.tree.map(lambda l: jnp.broadcast_to(
            l[None], (reps,) + l.shape).astype(l.dtype),
            init_layer_cache(cfg, s, batch, max_seq, dtype, quant))
        for s in unit]
    return caches


def prefill_cross_caches(cfg: ArchConfig, params: Params, enc_out):
    """Precompute read-only cross-attention K/V from the encoder output."""
    prefix, unit, reps = cfg.decoder_pattern()

    def kv(p):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
        return {"k": k, "v": v}
    return {"prefix": [kv(p) for p in params["prefix"]],
            "unit": [jax.vmap(kv)(p) for p in params["unit"]]}


def step_with_cache(cfg: ArchConfig, params: Params, caches, tokens, pos,
                    patch_embeds=None, enc_out=None, cross_caches=None,
                    prompt_len=None):
    """Forward S tokens (S=1 decode, S>1 prefill) writing the cache at
    ``pos``.  Returns (logits, new_caches).

    ``pos`` is a scalar for the uniform case (standard batched decode /
    prefill: every sequence at the same depth) or a (B, 1) int array for
    per-sequence depths (continuous batching — each KV slot holds a
    sequence admitted at a different time); positions, RoPE, the causal
    mask and the cache writes all follow per sequence.  Per-sequence
    ``pos`` requires relative position handling (RoPE/none) — absolute
    position embeddings index a table with the uniform offset.

    ``prompt_len`` ((B,) int, prefill of RIGHT-PADDED ragged prompts):
    each sequence's true prompt length.  Pad keys are masked out of the
    attention windows and never enter ring-buffer caches; sample the
    next token from ``logits[b, prompt_len[b] - 1]``, not the last row.
    Attention-only stacks (SSM state updates are sequential and have no
    pad-masking path — the serve engine guards this).
    """
    if jnp.ndim(pos) != 0 and cfg.abs_pos_embed:
        raise ValueError(
            "per-sequence positions are not supported with absolute "
            "position embeddings (the pos_embed table is indexed by a "
            "uniform batch offset); use a scalar pos")
    x, positions = embed_inputs(cfg, params, tokens, patch_embeds,
                                pos_offset=pos)
    cache_pos = jnp.full((1, 1), pos, jnp.int32) if jnp.ndim(pos) == 0 \
        else pos
    pattern = (cfg.decoder_pattern() if cfg.is_encoder_decoder
               else cfg.block_pattern())
    x, new_caches, aux = run_stack(
        cfg, params, x, pattern=pattern, positions=positions, causal=True,
        caches=caches, cache_pos=cache_pos, enc_out=enc_out,
        cross_caches=cross_caches, kv_len=prompt_len)
    return lm_head(cfg, params, x), new_caches


def decode_step(cfg: ArchConfig, params: Params, caches, tokens, pos,
                enc_out=None, cross_caches=None):
    """One serving step: ``tokens`` (B, 1) at absolute position ``pos``."""
    return step_with_cache(cfg, params, caches, tokens, pos,
                           enc_out=enc_out, cross_caches=cross_caches)
