"""Expert-parallel MoE dispatch via shard_map (beyond-paper optimisation).

The GSPMD-auto path (repro.models.layers.moe) scatters tokens into an
(E, C, D) buffer; with experts sharded on the model axis the partitioner
falls back to all-gathering the whole buffer per layer — measured at
~5.4 GB per layer-pass on qwen3-moe-30b-a3b (EXPERIMENTS.md §Perf).

This explicit schedule exploits the mesh structure instead:

* tokens are data-sharded and *replicated* across the model axis — so
  every model shard already holds the tokens it needs;
* each model shard routes all its local tokens but dispatches ONLY into
  its own E/tp experts (local scatter, local einsum, local combine);
* the per-expert partial outputs are summed with ONE psum over the model
  axis per MoE layer (the only collective: T_loc·D wire).

Semantics note: capacity is enforced per (data-shard × expert) —
C_loc = ceil(T_loc·k/E·factor) — the standard per-device capacity of
large-scale MoE systems (vs the global-sorted capacity of the dense
path).  Load-balance aux losses are pmean'd across the mesh.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .layers import ACTS, Params, mlp


def expert_parallel_moe(params: Params, x, *, top_k: int, act: str,
                        capacity_factor: float, mesh: Mesh,
                        dp_axes: Sequence[str], ep_axis: str = "model"):
    """Drop-in for :func:`repro.models.layers.moe` under a mesh."""
    B, S, D = x.shape
    E = params["w_up"].shape[0]
    tp = mesh.shape[ep_axis]
    a = ACTS[act]

    # batch sharding only over axes the batch actually divides (B=1
    # long-context decode runs token-replicated over data — correct,
    # just redundant; the expert math still shards over the model axis)
    dp = []
    rem = B
    for ax in dp_axes:
        n = mesh.shape[ax]
        if rem % n == 0:
            dp.append(ax)
            rem //= n
    dp = tuple(dp)
    x_spec = P(dp if dp else None, None, None)
    w_spec = P(ep_axis, None, None)
    r_spec = P(None, None)

    def local_moe(router, w_gate, w_up, w_down, xb):
        e_loc = w_up.shape[0]                      # E / tp experts here
        my_first = lax.axis_index(ep_axis) * e_loc
        xt = xb.reshape(-1, D)                     # (T_loc, D)
        T = xt.shape[0]
        C = max(1, int(np.ceil(T * top_k / E * capacity_factor)))

        logits = xt.astype(jnp.float32) @ router   # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = lax.top_k(probs, top_k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        eid = top_i.reshape(-1)
        tid = jnp.repeat(jnp.arange(T), top_k)
        wgt = top_p.reshape(-1)
        order = jnp.argsort(eid, stable=True)
        eid_s, tid_s, wgt_s = eid[order], tid[order], wgt[order]
        first = jnp.searchsorted(eid_s, eid_s, side="left")
        pos_s = jnp.arange(T * top_k) - first
        # keep only assignments that land in THIS shard's expert range;
        # everything else goes OUT OF BOUNDS so mode="drop" discards it
        local = (eid_s >= my_first) & (eid_s < my_first + e_loc)
        keep = (pos_s < C) & local
        le = jnp.where(keep, eid_s - my_first, e_loc)
        pc = jnp.where(keep, pos_s, 0)

        xe = jnp.zeros((e_loc, C, D), xb.dtype).at[le, pc].set(
            xt[tid_s], mode="drop")
        h = a(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
            * jnp.einsum("ecd,edf->ecf", xe, w_up)
        ye = jnp.einsum("ecf,efd->ecd", h, w_down)
        back = ye[jnp.where(keep, le, 0), pc] \
            * (wgt_s * keep.astype(wgt_s.dtype))[:, None].astype(xb.dtype)
        y = jnp.zeros((T, D), xb.dtype).at[tid_s].add(back, mode="drop")
        y = lax.psum(y, ep_axis)                   # THE one collective
        # aux (pmean'd so every shard agrees)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[eid].add(1.0) / (T * top_k)
        lb = E * jnp.sum(me * ce)
        rz = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
        drop = 1.0 - (pos_s < C).mean()
        aux = jnp.stack([lb, rz, drop])
        for ax in dp:
            aux = lax.pmean(aux, ax)
        aux = lax.pmean(aux, ep_axis)
        return y.reshape(xb.shape), aux

    from repro.sharding.specs import shard_map
    y, aux_v = shard_map(
        local_moe, mesh=mesh,
        in_specs=(r_spec, w_spec, w_spec, w_spec, x_spec),
        out_specs=(x_spec, P()))(
            params["router"], params["w_gate"], params["w_up"],
            params["w_down"], x)
    if "shared" in params:
        y = y + mlp(params["shared"], x.reshape(-1, D),
                    act).reshape(x.shape)
    aux = {"lb_loss": aux_v[0], "router_z": aux_v[1],
           "drop_frac": aux_v[2]}
    return y, aux
