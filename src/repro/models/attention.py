"""Attention: GQA with RoPE, sliding-window (sequence-stencil) masking,
soft-capping, qk-norm, cross-attention, and KV-cache decode.

Sliding-window layers are exactly the paper's stencil specialised to one
dimension: each query attends to a fixed-radius neighbourhood of the
sequence.  Global layers are the k=∞ degenerate case (map, not stencil) —
see DESIGN.md §Arch-applicability.

The grouped-query einsum keeps K/V unrepeated ((B,S,KH,hd) throughout), so
the compiled HLO carries the GQA memory saving through to the roofline.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, apply_rope, rms_norm, softcap

NEG_INF = -2.0 ** 30  # large-negative mask value, safe in bf16/f32

# Launcher-set flag: route train/prefill self-attention through the
# Pallas flash sliding-window kernel (kernels/swa_attention).  Off by
# default — on CPU the kernel runs in interpret mode (correctness tool);
# on TPU the launcher flips it for the compiled fast path.
USE_FLASH_SWA = False


def set_flash_swa(enabled: bool):
    global USE_FLASH_SWA
    USE_FLASH_SWA = enabled


def init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype,
                   qk_norm=False) -> Params:
    ks = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(d_model))
    so = float(1.0 / np.sqrt(num_heads * head_dim))
    p = {"wq": jax.random.normal(ks[0], (d_model, num_heads, head_dim),
                                 dtype) * s,
         "wk": jax.random.normal(ks[1], (d_model, num_kv_heads, head_dim),
                                 dtype) * s,
         "wv": jax.random.normal(ks[2], (d_model, num_kv_heads, head_dim),
                                 dtype) * s,
         "wo": jax.random.normal(ks[3], (num_heads, head_dim, d_model),
                                 dtype) * so}
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((head_dim,), jnp.float32)
    return p


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int, kv_len=None):
    """Additive attention bias (B, Q, S) from position constraints.

    ``window`` is the sequence-stencil radius: key j visible to query i iff
    ``i - window < j <= i`` (one-sided causal neighbourhood).

    ``kv_len`` ((B,) or (B, 1) int) is the ragged-prefill prompt-length
    mask: keys at positions >= the sequence's own prompt length are pad
    rows of a right-padded prompt and are invisible to EVERY query (the
    causal mask already hides them from the real queries, whose positions
    stay below the pad positions; the explicit mask also blinds the pad
    queries themselves, whose logits are never sampled).
    """
    qp = q_pos[:, :, None]                       # (B, Q, 1)
    kp = k_pos[:, None, :]                       # (B|1, 1, S)
    ok = kp >= 0        # ring-buffer caches mark empty slots with -1
    if causal:
        ok &= kp <= qp
    if window:
        ok &= kp > qp - window
    if kv_len is not None:
        ok = ok & (kp < jnp.reshape(kv_len, (-1,))[:, None, None])
    return jnp.where(ok, 0.0, NEG_INF)


def attention(params: Params, x, *, positions, num_heads, num_kv_heads,
              head_dim, rope_theta=10000.0, causal=True, window=0,
              attn_softcap=0.0, qk_norm=False, norm_eps=1e-6,
              x_kv=None, kv_cache: Optional[dict] = None,
              cache_pos=None, kv_len=None):
    """Returns (out, new_kv_cache or None).

    Training/prefill: ``kv_cache=None`` — keys/values from ``x`` (or
    ``x_kv`` for cross-attention; no RoPE, no mask there).
    Decode: ``kv_cache={'k','v'}`` (B, S_cache, KH, hd); the current
    step's K/V are written at ``cache_pos`` and attention runs over the
    whole cache under the causal(+window) mask.  Cross caches are
    read-only (precomputed from the encoder output).

    ``kv_len`` ((B,) int, ragged padded prefill — continuous batching):
    each sequence's true prompt length inside a right-padded chunk.  Pad
    keys are masked out of every attention window, and ring-buffer
    (sliding-window) caches write each sequence's own last ``min(W,
    len)`` REAL keys — a pad key never enters the ring.  Full caches may
    keep pad rows past the prompt: decode overwrites row ``len + t - 1``
    before any query position reaches it, so they are dead by the causal
    mask (the no-pad-leak invariant is property-tested in
    tests/train/test_serve_properties.py).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
    is_cross = x_kv is not None

    if kv_cache is not None and is_cross:
        k, v = kv_cache["k"], kv_cache["v"]          # precomputed, read-only
        new_cache = kv_cache
        k_pos = jnp.arange(k.shape[1])[None, :]
    else:
        src = x if not is_cross else x_kv
        k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
        if qk_norm:
            k = rms_norm(k, params["k_norm"], norm_eps)
        if not is_cross and rope_theta:
            # keys take the same absolute positions as the queries; the
            # cache_pos only sets the write offset (prefill writes S keys)
            k = apply_rope(k, positions, rope_theta)
        if kv_cache is not None and "pos" in kv_cache:
            # ring buffer (sliding-window layers): slot = position mod W
            ragged = cache_pos is not None and cache_pos.shape[0] > 1
            rk, rv, pos_arr = _ring_write(kv_cache, k, v, positions,
                                          ragged=ragged,
                                          kv_len=kv_len if S > 1
                                          else None)
            new_cache = {"k": rk, "v": rv, "pos": pos_arr}
            if S > 1:
                # prefill chunk: queries attend the chunk's OWN keys
                # (the ring holds only the last W — correct for future
                # steps, not for earlier in-chunk queries).  Single-chunk
                # prefill from position 0 is the engine's contract.
                k_pos = positions
            else:
                k, v = rk, rv
                k_pos = pos_arr                      # absolute positions
        elif kv_cache is not None and "k_scale" in kv_cache:
            # int8-quantised cache: write quantised, read dequantised
            # (the dequant fuses into the scores/AV dots — HBM moves
            # int8 bytes, halving the decode cells' dominant term)
            qk, sk = _quantize_kv(k)
            qv, sv = _quantize_kv(v)
            ck = _scatter_cache(kv_cache["k"], qk, cache_pos)
            cv = _scatter_cache(kv_cache["v"], qv, cache_pos)
            csk = _scatter_cache(kv_cache["k_scale"], sk, cache_pos)
            csv = _scatter_cache(kv_cache["v_scale"], sv, cache_pos)
            new_cache = {"k": ck, "v": cv, "k_scale": csk, "v_scale": csv}
            k = _dequantize_kv(ck, csk, x.dtype)
            v = _dequantize_kv(cv, csv, x.dtype)
            k_pos = jnp.arange(k.shape[1])[None, :]
        elif kv_cache is not None:                   # decode self-attention
            k = _scatter_cache(kv_cache["k"], k, cache_pos)
            v = _scatter_cache(kv_cache["v"], v, cache_pos)
            new_cache = {"k": k, "v": v}
            k_pos = jnp.arange(k.shape[1])[None, :]
        else:                                        # train / prefill
            new_cache = None
            k_pos = positions if not is_cross else \
                jnp.arange(k.shape[1])[None, :]

    if not is_cross and rope_theta:
        q = apply_rope(q, positions, rope_theta)

    if (USE_FLASH_SWA and kv_cache is None and not is_cross and causal
            and S % 128 == 0 and not qk_norm and kv_len is None):
        # flash path: (B,S,H,hd) -> (B·H,S,hd); kv stay per-group
        from repro.kernels.swa_attention import swa_attention
        qf = q.transpose(0, 2, 1, 3).reshape(B * num_heads, S, head_dim)
        kf = k.transpose(0, 2, 1, 3).reshape(B * num_kv_heads, S,
                                             head_dim)
        vf = v.transpose(0, 2, 1, 3).reshape(B * num_kv_heads, S,
                                             head_dim)
        of = swa_attention(qf, kf, vf, window=window, causal=True,
                           softcap=attn_softcap,
                           interpret=jax.default_backend() != "tpu")
        out = of.reshape(B, num_heads, S, head_dim).transpose(0, 2, 1, 3)
        out = jnp.einsum("bqhk,hkd->bqd", out, params["wo"])
        return out, new_cache

    # grouped-query attention einsum: (B,S,KH,G,hd) vs (B,T,KH,hd)
    G = num_heads // num_kv_heads
    qg = q.reshape(B, S, num_kv_heads, G, head_dim)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    scores = scores * float(1.0 / np.sqrt(head_dim))
    if attn_softcap:
        scores = softcap(scores, attn_softcap)

    bias = _mask_bias(positions, k_pos,
                      causal=(causal and not is_cross),
                      window=(window if not is_cross else 0),
                      kv_len=(kv_len if not is_cross else None))
    scores = scores + bias[:, None, None]            # (B,1,1,Q,S)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
    out = out.reshape(B, S, num_heads, head_dim)
    out = jnp.einsum("bqhk,hkd->bqd", out, params["wo"])
    return out, new_cache


def _ring_write(cache, k, v, positions, ragged: bool = False,
                kv_len=None):
    """Write S_new keys into the W-slot ring at slots ``pos mod W``.

    Keys are stored post-RoPE (absolute positions), so the ring only has
    to remember each slot's absolute position for masking; empty slots
    hold -1 and are masked out.  When S_new ≥ W only the last W entries
    survive (anything older is outside the window by construction).

    ``ragged`` (continuous batching, S_new == 1): every sequence decodes
    at its own depth, so each writes its own ring slot — a vmapped
    single-slot write instead of the shared-index fast path.

    ``kv_len`` (ragged padded prefill, S_new > 1): each sequence keeps
    its OWN last ``min(W, len)`` REAL keys — rows at positions past the
    prompt length (pads) or below the window map to an out-of-range slot
    and are dropped, so a pad key never enters the ring and a short
    prompt never loses in-window keys to the pads' positions.
    """
    W = cache["k"].shape[1]
    S_new = k.shape[1]
    if kv_len is not None and S_new > 1:
        kl = jnp.reshape(kv_len, (-1,)).astype(jnp.int32)     # (B,)

        def one(ck, cv, cp, kk, vv, pp, L):
            valid = jnp.logical_and(pp < L, pp >= L - W)
            slot = jnp.where(valid, pp % W, W)     # W = OOB, dropped
            return (ck.at[slot].set(kk.astype(ck.dtype)),
                    cv.at[slot].set(vv.astype(cv.dtype)),
                    cp.at[slot].set(pp))
        return jax.vmap(one)(cache["k"], cache["v"], cache["pos"],
                             k, v,
                             jnp.broadcast_to(
                                 positions, (k.shape[0], S_new))
                             .astype(jnp.int32), kl)
    if ragged:
        if S_new != 1:
            raise ValueError(
                "per-sequence ring writes are decode-only (S_new == 1); "
                "continuous prefill stages one sequence at a time")
        pos = positions[:, 0].astype(jnp.int32)          # (B,)
        idx = pos % W

        def one(ck, cv, cp, kk, vv, ii, pp):
            return (ck.at[ii].set(kk.astype(ck.dtype)),
                    cv.at[ii].set(vv.astype(cv.dtype)),
                    cp.at[ii].set(pp))
        return jax.vmap(one)(cache["k"], cache["v"], cache["pos"],
                             k[:, 0], v[:, 0], idx, pos)
    pos_row = positions[0]                        # uniform across batch
    if S_new >= W:
        keep = slice(S_new - W, S_new)
        idx = pos_row[keep] % W
        return (cache["k"].at[:, idx].set(k[:, keep].astype(
                    cache["k"].dtype)),
                cache["v"].at[:, idx].set(v[:, keep].astype(
                    cache["v"].dtype)),
                cache["pos"].at[:, idx].set(pos_row[keep][None]
                                            .astype(jnp.int32)))
    idx = pos_row % W
    return (cache["k"].at[:, idx].set(k.astype(cache["k"].dtype)),
            cache["v"].at[:, idx].set(v.astype(cache["v"].dtype)),
            cache["pos"].at[:, idx].set(
                jnp.broadcast_to(pos_row[None], cache["pos"][:, idx]
                                 .shape).astype(jnp.int32)))


def _scatter_cache(cache, new, cache_pos):
    """Write (B, S_new, KH, hd) at step ``cache_pos`` into the cache.

    ``cache_pos`` is (1, 1) when the step index is uniform across the
    batch (standard batched decode / prefill — the slice write stays one
    cheap dynamic-update-slice), or (B, 1) with per-sequence indices
    (continuous batching: every slot decodes at its own depth, so each
    sequence writes its own cache row position via a vmapped slice
    write).
    """
    if cache_pos.shape[0] == 1:
        pos0 = cache_pos.reshape(-1)[0]
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), pos0, axis=1)
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), p, axis=0)
    )(cache, new, cache_pos.reshape(-1))


def init_kv_cache(batch, max_seq, num_kv_heads, head_dim,
                  dtype=jnp.bfloat16, window: int = 0,
                  quant: bool = False):
    """Decode cache.  Sliding-window layers with ``window < max_seq`` get
    a ring buffer of W slots plus a per-slot absolute-position array
    (−1 = empty) — cache memory W/max_seq of the full layout.

    ``quant=True``: int8 per-(token, kv-head) symmetric quantisation —
    halves cache bytes vs bf16 (the dominant term of the memory-bound
    decode cells); scales stored f32 per slot.  Ring layers keep the
    model dtype (they are already W/S of the footprint)."""
    if window and window < max_seq:
        z = jnp.zeros((batch, window, num_kv_heads, head_dim), dtype)
        return {"k": z, "v": jnp.zeros_like(z),
                "pos": jnp.full((batch, window), -1, jnp.int32)}
    if quant:
        z = jnp.zeros((batch, max_seq, num_kv_heads, head_dim), jnp.int8)
        s = jnp.zeros((batch, max_seq, num_kv_heads), jnp.float32)
        return {"k": z, "v": jnp.zeros_like(z),
                "k_scale": s, "v_scale": jnp.zeros_like(s)}
    z = jnp.zeros((batch, max_seq, num_kv_heads, head_dim), dtype)
    return {"k": z, "v": jnp.zeros_like(z)}


def _quantize_kv(x):
    """Symmetric int8 per-(token, head): returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-10)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
