"""Model zoo: one generic layer-stack interpreter covering all ten
assigned architectures (see repro.configs)."""
from . import attention, layers, ssm, transformer
from .transformer import (decode_step, forward, init_cache, init_params,
                          step_with_cache, encode, prefill_cross_caches)

__all__ = ["attention", "layers", "ssm", "transformer", "decode_step",
           "forward", "init_cache", "init_params", "step_with_cache",
           "encode", "prefill_cross_caches"]
