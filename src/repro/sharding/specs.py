"""Parallelism policy: param/activation/optimizer PartitionSpecs per arch.

Axes (production mesh, launch/mesh.py):
    pod    — data parallelism across pods (slow inter-pod links carry only
             the gradient all-reduce)
    data   — in-pod data parallelism + ZeRO-1 optimizer-state sharding +
             sequence sharding for the 500k decode cells
    model  — tensor parallelism (vocab / heads / d_ff / experts) and
             KV-cache sequence sharding for decode

Rules are divisibility-aware: e.g. K/V heads shard on the model axis only
when ``kv_heads % tp == 0``; otherwise the head_dim (always a multiple of
16 across the assigned archs) is sharded so K/V stay tensor-parallel
without GSPMD padding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, explicit: bool = False) -> Mesh:
    """Version-portable ``jax.make_mesh``.

    Newer jax takes ``axis_types=(AxisType.Auto, ...)`` (and
    ``AxisType.Explicit`` for sharding-in-types); 0.4.x has neither the
    kwarg nor ``jax.sharding.AxisType``.  Auto is the 0.4.x behaviour, so
    the kwarg is only forwarded where it exists — the launch layer and
    the multi-device tests go through this shim (same contract as
    :func:`shard_map` below).
    """
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(axis_shapes, axis_names)
    ty = AxisType.Explicit if explicit else AxisType.Auto
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(ty,) * len(axis_names))


def make_abstract_mesh(axis_shapes: Sequence[int],
                       axis_names: Sequence[str]):
    """Version-portable ``jax.sharding.AbstractMesh`` (newer jax:
    ``AbstractMesh(sizes, names)``; 0.4.x: one tuple of (name, size)
    pairs)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(axis_shapes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def shard_map(f: Callable, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` without replication checking.

    Newer jax exposes ``jax.shard_map(..., check_vma=)``; 0.4.x has
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  Every
    SPMD entry point in the repo (halo exchange, sharded engine, MoE
    dispatch) goes through this shim so the whole tree runs on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def local_slot(idx, lanes_local: int, axis: str):
    """Map a GLOBAL lane-slot index onto this shard of mesh ``axis``.

    Runs inside ``shard_map``: each lane shard owns ``lanes_local``
    consecutive slots, so slot ``idx`` lives at local index
    ``idx - axis_index * lanes_local`` on exactly one shard.  Returns
    ``(owns, local_idx)`` with ``local_idx`` clipped into range — always
    safe to index with, while ``owns`` masks the actual write (the
    owner-masked scatter of the composed continuous farm refill,
    :func:`repro.core.frames.refill_slot_frame_sharded`).  Pure local
    arithmetic: no collective touches the lane axis.
    """
    me = jax.lax.axis_index(axis)
    li = idx - me * lanes_local
    owns = jnp.logical_and(li >= 0, li < lanes_local)
    return owns, jnp.clip(li, 0, lanes_local - 1)


@dataclasses.dataclass(frozen=True)
class GridPartition:
    """How a global stencil grid maps onto the device mesh (1:n mode).

    Frozen (hashable) so apps can carry it as a jit-static argument.
    ``axis_names`` are mesh axes; ``array_axes`` the array axes they split
    ("evenly for 1D array and by rows for 2D matrix", paper §3.4).
    """
    mesh: Mesh
    axis_names: tuple[str, ...]      # mesh axes carrying the decomposition
    array_axes: tuple[int, ...]      # which array axes they split

    def __post_init__(self):
        object.__setattr__(self, "axis_names", tuple(self.axis_names))
        object.__setattr__(self, "array_axes", tuple(self.array_axes))

    @property
    def pspec(self) -> P:
        spec = [None] * (max(self.array_axes) + 1)
        for name, ax in zip(self.axis_names, self.array_axes):
            spec[ax] = name
        return P(*spec)

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]

    @property
    def shards(self) -> tuple[int, ...]:
        """Decomposition arity per decomposed array axis."""
        return tuple(self.axis_size(n) for n in self.axis_names)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def dp_axes(mesh: Mesh):
    """The data-parallel axes: ('pod','data') multi-pod, ('data',) single."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def mesh_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def param_spec(cfg: ArchConfig, path: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path.

    ``shape`` includes the leading unit-stack (reps) axis for scanned
    leaves; the path contains 'unit' in that case.
    """
    tp = mesh_size(mesh, "model")
    stacked = "unit" in path and "cache" not in path
    off = 1 if stacked else 0           # skip the layer-stack axis
    dims = list(shape)
    spec = [None] * len(dims)

    def set_if(idx, cond=True):
        if cond and _div(dims[idx], tp):
            spec[idx] = "model"
            return True
        return False

    if "embed" in path and "pos" not in path and "patch" not in path:
        # token embedding (V, D) / unembed (D, V): shard the vocab dim
        vdim = int(np.argmax(dims))
        set_if(vdim)
    elif any(k in path for k in ("wq", "wk", "wv", "wo")):
        # Megatron-style GQA TP.  Heads dims (H on wq/wo, KH on wk/wv)
        # shard on the model axis when divisible; when KH < tp the K/V
        # projections REPLICATE (classic GQA replication — keeps the
        # scores einsum head-sharded with no giant score all-reduce);
        # when even H < tp (whisper), every projection shards head_dim
        # so q·k contracts a sharded dim instead.
        h_dim = off + (0 if "wo" in path else 1)   # H/KH position
        d_dim = off + (1 if "wo" in path else 2)   # head_dim position
        is_kv = ("wk" in path) or ("wv" in path)
        nh = dims[h_dim]
        if cfg.attn_sequence_parallel:
            pass          # context-parallel attention: weights replicated,
            #               the sequence shards on the model axis instead
        elif _div(nh, tp):
            spec[h_dim] = "model"
        elif is_kv:
            pass                                   # replicate K/V heads
        else:
            set_if(d_dim)
    elif any(k in path for k in ("w_up", "w_gate", "w_down")):
        set_if(off + 0)                  # expert-parallel: experts axis
    elif "router" in path:
        pass                             # tiny; replicate
    elif "up" in path or "gate" in path:
        set_if(off + 1)                  # (D, F): column parallel
    elif "down" in path:
        set_if(off + 0)                  # (F, D): row parallel
    elif "in_proj" in path:
        set_if(off + 1) or set_if(off + 0)   # (D, d_proj)
    elif "out_proj" in path:
        set_if(off + 0) or set_if(off + 1)   # (d_inner, D)
    elif "vision_proj" in path:
        set_if(off + 1)
    # norms / biases / conv / A_log / dt_bias / pos_embed: replicated
    return P(*spec)


def zero1_spec(spec: P, shape, mesh: Mesh) -> P:
    """Extend a param spec with ZeRO-1 optimizer-state sharding: shard the
    largest still-unsharded dim divisible by the data axis."""
    dz = mesh_size(mesh, "data")
    if dz == 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    cands = [(shape[i], i) for i, s in enumerate(entries)
             if s is None and _div(shape[i], dz) and shape[i] >= dz]
    if not cands:
        return P(*entries)
    _, idx = max(cands)
    entries[idx] = "data"
    return P(*entries)


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in kp)


def params_shardings(cfg: ArchConfig, params_shape, mesh: Mesh):
    """NamedSharding pytree for the params (shape pytree or real params)."""
    def one(kp, leaf):
        return NamedSharding(
            mesh, param_spec(cfg, _path_str(kp), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_shardings(cfg: ArchConfig, opt_shape, mesh: Mesh):
    """AdamState shardings: step replicated; master/m/v = param spec +
    ZeRO-1 over the data axis."""
    def one(kp, leaf):
        path = _path_str(kp)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        base = param_spec(cfg, path, leaf.shape, mesh)
        return NamedSharding(mesh, zero1_spec(base, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, opt_shape)


def batch_spec(mesh: Mesh, batch_size: int, ndim: int = 2) -> P:
    """Shard the batch dim over every data-parallel axis that divides it."""
    axes = [a for a in dp_axes(mesh)]
    use = []
    rem = batch_size
    for a in axes:
        n = mesh_size(mesh, a)
        if rem % n == 0 and rem >= n:
            use.append(a)
            rem //= n
    lead = tuple(use) if use else None
    return P(lead, *([None] * (ndim - 1)))


def cache_shardings(cfg: ArchConfig, cache_shape, mesh: Mesh,
                    batch_size: int, seq_shard: bool = True):
    """Decode-cache shardings.

    KV caches (reps, B, S, KH, hd): batch over the dp axes; the cache
    *sequence* over the model axis (flash-decode style load balancing —
    every chip holds a slice of every head's history).  When B == 1
    (long_500k) the data axis joins the sequence sharding instead.
    SSM caches: batch over dp only (state is O(1), nothing else to shard).
    """
    dp = [a for a in dp_axes(mesh) if _div(batch_size, mesh_size(mesh, a))]
    # compose multi-axis batch sharding only while divisible
    bs = []
    rem = batch_size
    for a in dp:
        if rem % mesh_size(mesh, a) == 0:
            bs.append(a)
            rem //= mesh_size(mesh, a)
    seq_axes = ["model"] if seq_shard else []
    if batch_size == 1:
        seq_axes = [a for a in dp_axes(mesh)] + seq_axes if seq_shard \
            else []
        bs = []

    def one(kp, leaf):
        path = _path_str(kp)
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2:
            spec[1] = tuple(bs) if bs else None       # batch dim
        if "conv" in path or path.endswith("h"):      # ssm caches
            return NamedSharding(mesh, P(*spec))
        seq_ok = (seq_axes and leaf.ndim >= 3 and all(
            _div(leaf.shape[2], mesh_size(mesh, a)) for a in seq_axes))
        if leaf.ndim == 5 and seq_ok:                 # (reps,B,S,KH,hd)
            spec[2] = tuple(seq_axes)
        elif leaf.ndim == 4 and "scale" in path and seq_ok:
            spec[2] = tuple(seq_axes)                 # int8 scales
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
