from . import specs
