"""Data pipeline: deterministic synthetic token streams, sharded batches,
and host-side prefetch.

The synthetic task is *learnable* (orderable structure, not pure noise) so
integration tests and the end-to-end example can assert loss decrease:
tokens follow a randomly-parameterised first-order Markov chain with a
skip-gram copy rule, which a small LM learns within a few hundred steps.

At scale this module is the "read" stage of the paper's streaming tier:
batches are produced on host, placed with `jax.device_put` against the
batch sharding, and prefetched one step ahead (async dispatch overlaps the
H2D copy with the previous step's compute — the paper's asynchronous
H2D/D2H optimisation).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Markov-chain + copy-rule synthetic language modelling task."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = min(self.vocab_size, 4096)  # active vocabulary
        self._V = V
        # sparse-ish transition matrix with strong modes
        trans = rng.dirichlet(np.full(self.n_states, 0.1),
                              size=self.n_states)
        self._trans = trans / trans.sum(-1, keepdims=True)
        self._emit = rng.integers(0, V, size=(self.n_states, 8))

    def batches(self, start_step: int = 0) -> Iterator[np.ndarray]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a step — restart/replay-safe (fault
        tolerance: resuming at step k regenerates the same data)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S = self.global_batch, self.seq_len
        states = rng.integers(0, self.n_states, size=B)
        toks = np.empty((B, S + 1), np.int32)
        u = rng.random((B, S + 1))
        pick = rng.integers(0, 8, size=(B, S + 1))
        for t in range(S + 1):
            toks[:, t] = self._emit[states, pick[:, t]]
            cdf = np.cumsum(self._trans[states], axis=-1)
            states = (u[:, t, None] < cdf).argmax(-1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def shard_batch(batch: dict, sharding) -> dict:
    """Place a host batch against the mesh batch sharding (async H2D)."""
    return jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x), sharding), batch)


class Prefetcher:
    """One-deep prefetch queue: the paper's async H2D overlap."""

    def __init__(self, it: Iterator, sharding=None):
        self._it = it
        self._sharding = sharding
        self._next = self._load()

    def _load(self):
        try:
            b = next(self._it)
        except StopIteration:
            return None
        if self._sharding is not None:
            b = shard_batch(b, self._sharding)
        else:
            b = jax.tree.map(jnp.asarray, b)
        return b

    def __iter__(self):
        return self

    def __next__(self):
        cur = self._next
        if cur is None:
            raise StopIteration
        self._next = self._load()
        return cur
