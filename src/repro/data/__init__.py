from .pipeline import SyntheticLM, Prefetcher, shard_batch

__all__ = ["SyntheticLM", "Prefetcher", "shard_batch"]
