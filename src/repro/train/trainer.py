"""Trainer — the Loop-of-stencil-reduce-s pattern at system scale.

Pattern instantiation (DESIGN.md §4):
    stencil step f : (params, opt) → (params, opt)    (k=0 map case over
                                                       the sharded batch)
    reduce /⊕     : mean loss (psum'd by pjit across the mesh)
    state s       : optimizer state + step counter + fault counters
    condition c   : step budget ∧ target loss ∧ NaN fault detector

Two execution modes:

* :meth:`Trainer.run` — production host loop: data prefetch, periodic
  step-atomic checkpoints, NaN/spike **rollback with batch skip**,
  preemption-signal flush, resume-from-latest.  The host loop is the
  streaming tier; each iteration is one pattern application.
* :meth:`Trainer.run_fused` — K steps lowered as ONE on-device
  ``lax.while_loop`` over pre-staged batches via
  :class:`repro.core.pattern.LoopOfStencilReduce` (step mode).  This is
  the paper's device-memory-persistence claim at trainer scale, and the
  benchmark pair run_fused-vs-host-loop reproduces the paper's
  naïve-vs-persistent comparison on the training workload.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.pattern import LoopOfStencilReduce
from repro.models import transformer as T
from repro.optim import AdamW, AdamState
from . import checkpoint as ckpt_lib
from .objective import grad_accum_step, lm_loss


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    accum: int = 1
    ckpt_dir: str = ""
    ckpt_every: int = 100
    keep_ckpts: int = 3
    target_loss: float = 0.0        # 0 = disabled
    log_every: int = 10
    rollback_on_nan: bool = True
    max_faults: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig, optimizer: AdamW,
                 *, loss_fn=lm_loss, step_jit_kwargs: Optional[dict] = None):
        self.cfg, self.tcfg, self.opt = cfg, tcfg, optimizer
        self.loss_fn = loss_fn
        self._preempted = False
        self._faults = 0
        kw = step_jit_kwargs or {}

        def train_step(params, opt_state, batch):
            grads, loss, metrics = grad_accum_step(
                cfg, params, batch, accum=tcfg.accum, loss_fn=loss_fn)
            params, opt_state, stats = self.opt.update(grads, opt_state,
                                                       params)
            metrics = dict(metrics, **stats, total_loss=loss)
            return params, opt_state, metrics

        self.train_step = jax.jit(train_step, donate_argnums=(0, 1), **kw)

    # -- fault tolerance hooks -------------------------------------------
    def install_preemption_handler(self, signals=(signal.SIGTERM,)):
        def _h(sig, frame):
            self._preempted = True
        for s in signals:
            signal.signal(s, _h)

    # -- production host loop --------------------------------------------
    def run(self, params, batches, *, opt_state: Optional[AdamState] = None,
            start_step: int = 0, log: Callable = print):
        tc = self.tcfg
        opt_state = opt_state if opt_state is not None \
            else self.opt.init(params)
        step = start_step

        # resume from latest checkpoint if present
        if tc.ckpt_dir and ckpt_lib.latest_step(tc.ckpt_dir) is not None:
            (params, opt_state), step, _ = ckpt_lib.restore(
                tc.ckpt_dir, (params, opt_state))
            log(f"[trainer] resumed from step {step}")

        last_good = None
        history = []
        it = iter(batches(step) if callable(batches) else batches)
        t0 = time.time()
        while step < tc.steps:
            batch = next(it)
            params, opt_state, m = self.train_step(params, opt_state, batch)
            loss = float(m["total_loss"])
            step += 1

            if tc.rollback_on_nan and (loss != loss):      # NaN fault
                self._faults += 1
                log(f"[trainer] step {step}: NaN loss — fault "
                    f"{self._faults}/{tc.max_faults}")
                if self._faults > tc.max_faults:
                    raise RuntimeError("fault budget exhausted")
                if last_good is not None:
                    params, opt_state, step = (
                        jax.tree.map(jnp.asarray, last_good[0]),
                        jax.tree.map(jnp.asarray, last_good[1]),
                        last_good[2])
                elif tc.ckpt_dir and ckpt_lib.latest_step(tc.ckpt_dir) \
                        is not None:
                    (params, opt_state), step, _ = ckpt_lib.restore(
                        tc.ckpt_dir, (params, opt_state))
                continue                                    # skip the batch

            history.append(loss)
            if step % tc.log_every == 0:
                dt = (time.time() - t0) / tc.log_every
                log(f"[trainer] step {step} loss={loss:.4f} "
                    f"gnorm={float(m['grad_norm']):.3f} {dt*1e3:.0f}ms/it")
                t0 = time.time()
            if tc.ckpt_dir and step % tc.ckpt_every == 0:
                ckpt_lib.save(tc.ckpt_dir, step, (params, opt_state),
                              keep=tc.keep_ckpts)
                last_good = (jax.device_get(params),
                             jax.device_get(opt_state), step)
            if self._preempted:
                if tc.ckpt_dir:
                    ckpt_lib.save(tc.ckpt_dir, step, (params, opt_state),
                                  keep=tc.keep_ckpts)
                log(f"[trainer] preempted at step {step}; checkpoint "
                    "flushed")
                break
            if tc.target_loss and loss < tc.target_loss:
                log(f"[trainer] target loss reached at step {step}")
                break
        if tc.ckpt_dir:
            ckpt_lib.save(tc.ckpt_dir, step, (params, opt_state),
                          keep=tc.keep_ckpts)
        return params, opt_state, {"history": history, "steps": step,
                                   "faults": self._faults}

    # -- fused on-device segment (the paper's persistence, trainer-scale) -
    def run_fused(self, params, opt_state, stacked_batches, *,
                  target_loss: float = 0.0):
        """Run K = leading-axis steps as ONE on-device while_loop.

        ``stacked_batches``: pytree with a leading K axis, pre-staged in
        device memory.  Returns (params, opt_state, last_loss, iters).
        """
        K = jax.tree.leaves(stacked_batches)[0].shape[0]
        cfg, opt = self.cfg, self.opt

        def step_fn(carry):
            params, opt_state, ptr, _ = carry
            batch = jax.tree.map(lambda x: x[ptr], stacked_batches)
            grads, loss, _ = grad_accum_step(cfg, params, batch,
                                             accum=self.tcfg.accum,
                                             loss_fn=self.loss_fn)
            params, opt_state, _ = opt.update(grads, opt_state, params)
            return (params, opt_state, ptr + 1, loss)

        loop = LoopOfStencilReduce(
            f=step_fn, mode="step", combine="min", identity=jnp.inf,
            measure=lambda c: c[3][None],
            cond=lambda r, s: jnp.logical_or(
                s >= K, r < target_loss if target_loss else False),
            state_init=lambda: jnp.asarray(0, jnp.int32),
            state_update=lambda s, a, it: s + 1,
            max_iters=K)
        res = jax.jit(loop.run)(
            (params, opt_state, jnp.asarray(0, jnp.int32),
             jnp.asarray(jnp.inf, jnp.float32)))
        params, opt_state, _, last_loss = res.a
        return params, opt_state, last_loss, res.iters
