"""Training objective: next-token cross entropy (+ MoE aux losses) and the
micro-batched gradient step (grad-accumulation scan).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


def lm_loss(cfg: ArchConfig, params, batch, *, lb_coef=0.01, z_coef=1e-4):
    """Mean next-token CE over text positions (+ router aux for MoE)."""
    logits, aux = T.forward(cfg, params, batch)
    labels = batch["labels"]
    P = cfg.vision_patches or 0
    if P:
        logits = logits[:, P:]     # loss only on text positions
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = -ll.mean()
    loss = ce
    if cfg.n_experts:
        loss = loss + lb_coef * aux["lb_loss"] + z_coef * aux["router_z"]
    metrics = {"loss": ce, "lb_loss": aux["lb_loss"],
               "router_z": aux["router_z"], "drop_frac": aux["drop_frac"]}
    return loss, metrics


def grad_accum_step(cfg: ArchConfig, params, batch, *, accum: int = 1,
                    loss_fn=lm_loss):
    """Gradients over ``accum`` microbatches via lax.scan.

    The scan keeps per-microbatch activation memory bounded and lets XLA
    overlap the (pod-axis) gradient reduction of slice i with the compute
    of slice i+1 — the paper's compute/communication overlap at LM scale.
    """
    if accum == 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return grads, loss, metrics

    def split(x):
        b = x.shape[0]
        # (B,...) -> (B/accum, accum, ...) -> (accum, B/accum, ...):
        # splitting the *trailing* factor keeps the leading dim divisible
        # by the data axes, so GSPMD shards the microbatch (not the accum
        # index) and every device sees B/(accum·dp) sequences per slice
        return x.reshape(b // accum, accum, *x.shape[1:]).swapaxes(0, 1)
    micro = jax.tree.map(split, batch)

    def body(acc, mb):
        grads_acc, loss_acc, met_acc = acc
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb), has_aux=True)(params)
        grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
        met_acc = jax.tree.map(jnp.add, met_acc, metrics)
        return (grads_acc, loss_acc + loss, met_acc), None

    zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros_m = {"loss": 0.0, "lb_loss": 0.0, "router_z": 0.0,
               "drop_frac": 0.0}
    zeros_m = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), zeros_m)
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (zeros_g, jnp.zeros((), jnp.float32), zeros_m), micro)
    inv = 1.0 / accum
    return (jax.tree.map(lambda g: g * inv, grads), loss * inv,
            jax.tree.map(lambda m: m * inv, metrics))
