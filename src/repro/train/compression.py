"""Gradient compression for the slow inter-pod links: int8 quantised
all-reduce with error feedback.

At multi-pod scale the only cross-pod traffic is the gradient
all-reduce; quantising it to int8 cuts the wire bytes 4× vs f32 (2× vs
bf16).  Error feedback (Seide et al. / Karimireddy et al.) carries the
quantisation residual into the next step, keeping SGD/Adam convergence
unbiased in the long run.

Design for the psum wire format: with n pods summing, each pod quantises
to ±(127 // n) so the int8 sum cannot overflow — the collective itself
runs on int8 payloads.  The shared scale is agreed with one scalar pmax
per tensor (negligible traffic).

Usage inside a shard_map over the 'pod' axis:

    g_sum, err = ef_int8_psum(g_local, err, axis_name="pod")

Property-tested in tests/train/test_compression.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jnp.ndarray, n_peers: int):
    """Symmetric per-tensor int8 quantisation, overflow-safe for a sum of
    ``n_peers`` payloads.  Returns (q, scale)."""
    qmax = max(1, 127 // max(1, n_peers))
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def ef_int8_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 all-reduce of one gradient tensor.

    Must run inside shard_map/pmap with ``axis_name`` bound.
    Returns (summed fp32 gradient, new error-feedback residual).
    """
    n = lax.psum(1, axis_name)
    gf = g.astype(jnp.float32) + err
    # shared scale: every peer quantises against the global max
    amax = lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    qmax = 127 // jnp.maximum(1, n)
    scale = jnp.maximum(amax / qmax.astype(jnp.float32), 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale      # residual feedback
    total = lax.psum(q.astype(jnp.int8), axis_name)   # int8 on the wire
    return total.astype(jnp.float32) * scale, new_err


def ef_int8_psum_tree(grads: Any, err_tree: Any, axis_name: str
                      ) -> Tuple[Any, Any]:
    """Tree-mapped :func:`ef_int8_psum` (one scale per leaf)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_tree)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        s, ne = ef_int8_psum(g, e, axis_name)
        out_g.append(s)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
