"""Checkpointing: step-atomic, topology-free, elastic.

Format: one ``.npz`` of logical (unsharded) arrays + a JSON manifest with
step / dtypes / tree structure.  bf16 leaves are stored as uint16 views
(npz has no bf16) and restored from the manifest dtype tags.

* **step-atomic**: written to ``<dir>/.tmp-<step>`` then published via
  the rename-aside protocol in ``repro.resilience.recovery`` — a crash
  mid-write never corrupts the latest checkpoint, and re-saving an
  existing step never has a window with no copy on disk (the old dir is
  renamed aside before the new one is swapped in, then deleted).
  ``latest_step``/``restore`` tolerate stray ``.tmp-*``/``.old-*`` dirs
  left by a crash and promote an orphaned ``.old-*`` back to final.
* **topology-free / elastic**: arrays are logical; on restore they are
  ``device_put`` against whatever mesh/sharding the *new* job uses, so a
  run can restart on a different device count (elastic scaling).  At
  1000-node scale the same manifest format fans out to per-host shard
  files (one writer per data-parallel replica-0 host); see DESIGN.md.
* **retention**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience import recovery as _rec


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    tmp = _rec.fresh_tmp_dir(ckpt_dir, str(step))
    final = os.path.join(ckpt_dir, f"step_{step:010d}")

    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes[str(i)] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtypes[str(i)] = "bfloat16"
        arrays[str(i)] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "n_leaves": len(leaves),
                "dtypes": dtypes, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # rename-aside publish: on a same-step re-save the previous copy is
    # set aside (not rmtree'd) until the new one is in place, so a crash
    # at any point leaves at least one intact copy of the step.
    _rec.publish_dir(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    for s in _rec.list_steps(ckpt_dir)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _rec.list_steps(ckpt_dir)  # sweeps stray .tmp-*/.old-* dirs
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching pytree of NamedSharding — the elastic
    path: leaves are placed directly against the *current* mesh regardless
    of the topology that wrote the checkpoint.
    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    if not os.path.isdir(path):  # maybe orphaned mid-publish: promote .old
        _rec.sweep_strays(ckpt_dir)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    t_leaves, treedef = _flatten(template)
    assert manifest["n_leaves"] == len(t_leaves), \
        "checkpoint/model structure mismatch"
    s_leaves = (jax.tree_util.tree_leaves(shardings)
                if shardings is not None else [None] * len(t_leaves))
    out = []
    for i, (tl, sh) in enumerate(zip(t_leaves, s_leaves)):
        arr = data[str(i)]
        if manifest["dtypes"][str(i)] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest["step"], manifest.get("extra", {})
