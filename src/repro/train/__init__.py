from .trainer import Trainer, TrainConfig
from .objective import lm_loss, grad_accum_step
from . import checkpoint

__all__ = ["Trainer", "TrainConfig", "lm_loss", "grad_accum_step",
           "checkpoint"]
