"""Serving engine — autoregressive decode as Loop-of-stencil-reduce-s.

The decode loop is the -s variant verbatim (DESIGN.md §4):
    stencil step : one `decode_step` (attention over the KV-cache
                   neighbourhood — the sliding-window layers are literal
                   sequence stencils)
    reduce /⊕    : `all` monoid over per-sequence done flags
    state s      : position counter + PRNG key
    condition c  : every sequence hit EOS ∨ token budget

The whole generation lowers to ONE on-device while_loop: the KV cache is
the paper's persistent device memory — it never leaves HBM, and the
done-reduce feeding the condition runs on device (beyond the paper, which
still bounced the reduce result to the host each iteration).

In stream-tier terms (:mod:`repro.core.streaming`) a generate batch IS a
lane farm: each sequence is a lane of the done-masked loop, running to
its own EOS trip count while the KV cache plays the persistent lane
frame.  The host side composes accordingly — :class:`repro.serve.
batcher.Batcher` drives batches through the FarmEngine's double-buffered
read ∥ decode ∥ write protocol.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.pattern import LoopOfStencilReduce
from repro.models import transformer as T


@dataclasses.dataclass
class GenerateConfig:
    max_new_tokens: int = 64
    eos_id: int = 1
    temperature: float = 0.0       # 0 → greedy
    seed: int = 0


def prefill(cfg: ArchConfig, params, tokens, *, max_seq: int,
            cache_dtype=jnp.bfloat16, patch_embeds=None, enc_out=None,
            cross_caches=None):
    """Run the prompt through the model, returning (last_logits, caches)."""
    B = tokens.shape[0]
    caches = T.init_cache(cfg, B, max_seq, cache_dtype)
    logits, caches = T.step_with_cache(
        cfg, params, caches, tokens, 0, patch_embeds=patch_embeds,
        enc_out=enc_out, cross_caches=cross_caches)
    return logits[:, -1], caches


def generate(cfg: ArchConfig, params, prompt, gcfg: GenerateConfig, *,
             max_seq: Optional[int] = None, cache_dtype=jnp.bfloat16,
             enc_out=None, cross_caches=None, patch_embeds=None,
             budgets=None):
    """Batched generation.  Returns (tokens (B, max_new), lengths, iters).

    ``budgets`` is an optional (B,) int vector of per-sequence
    ``max_new_tokens`` (each in [1, gcfg.max_new_tokens]): the
    done-mask retires a sequence at its OWN budget, mirroring the
    continuous engine's per-request budgets, so round-mode batches honor
    ``Request.max_new_tokens`` identically.  ``lengths`` is clipped to
    the budget (post-done positions are eos-padded in ``out``).
    """
    B, S0 = prompt.shape
    P = cfg.vision_patches or 0
    max_seq = max_seq or (S0 + P + gcfg.max_new_tokens)

    last_logits, caches = prefill(
        cfg, params, prompt, max_seq=max_seq, cache_dtype=cache_dtype,
        patch_embeds=patch_embeds, enc_out=enc_out,
        cross_caches=cross_caches)

    def sample(logits, key):
        if gcfg.temperature > 0:
            return jax.random.categorical(key, logits / gcfg.temperature,
                                          axis=-1)
        return jnp.argmax(logits, axis=-1)

    bud = (jnp.full((B,), gcfg.max_new_tokens, jnp.int32)
           if budgets is None else jnp.asarray(budgets, jnp.int32))
    key0 = jax.random.PRNGKey(gcfg.seed)
    first = sample(last_logits, key0)                     # (B,)
    out0 = jnp.zeros((B, gcfg.max_new_tokens), jnp.int32)
    out0 = out0.at[:, 0].set(first)
    done0 = jnp.logical_or(first == gcfg.eos_id, bud <= 1)

    def step_fn(carry):
        caches, out, done, t, key = carry
        tok = jax.lax.dynamic_slice_in_dim(out, t - 1, 1, axis=1)
        logits, caches = T.decode_step(
            cfg, params, caches, tok, S0 + P + t - 1,
            enc_out=enc_out, cross_caches=cross_caches)
        key, sub = jax.random.split(key)
        nxt = sample(logits[:, 0], sub)
        nxt = jnp.where(done, jnp.full_like(nxt, gcfg.eos_id), nxt)
        if gcfg.max_new_tokens > 1:
            # cap == 1: the repeat/until still runs its one mandatory
            # body step, whose write index (t=1) would CLIP onto column
            # 0 and eos-pad over the only real token — skip it (every
            # lane is already done0-retired at cap 1)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, nxt[:, None].astype(out.dtype), t, axis=1)
        done = done | (nxt == gcfg.eos_id) | (t + 1 >= bud)
        return (caches, out, done, t + 1, key)

    loop = LoopOfStencilReduce(
        f=step_fn, mode="step", combine="all", identity=True,
        measure=lambda c: c[2],                   # per-sequence done flags
        cond=lambda r, s: jnp.logical_or(r, s >= gcfg.max_new_tokens),
        state_init=lambda: jnp.asarray(1, jnp.int32),
        state_update=lambda s, a, it: s + 1,
        max_iters=gcfg.max_new_tokens)

    res = loop.run((caches, out0, done0, jnp.asarray(1, jnp.int32), key0))
    _, out, done, _, _ = res.a
    lengths = jnp.where(
        (out == gcfg.eos_id).any(axis=1),
        (out == gcfg.eos_id).argmax(axis=1) + 1, gcfg.max_new_tokens)
    # a budget-retired sequence has eos PADS from its budget on — clip
    # so the pad never counts as a sampled token
    lengths = jnp.minimum(lengths, bud)
    return out, lengths, res.iters


def generate_jit(cfg: ArchConfig, gcfg: GenerateConfig, **kw):
    """Jit-compiled generate closure (static cfg/gcfg)."""
    return jax.jit(functools.partial(generate, cfg, gcfg=gcfg, **kw))


# ---------------------------------------------------------------------------
# Continuous batching — per-sequence KV-slot refill.
# ---------------------------------------------------------------------------


def request_budget(req, cap: int) -> int:
    """Resolve a request's per-sequence token budget against the engine
    cap — the ONE validation rule shared by the round path
    (:meth:`repro.serve.batcher.Batcher.run_all`) and the continuous
    engine, so the two paths cannot drift (their budget parity is
    regression-tested)."""
    bud = getattr(req, "max_new_tokens", None)
    bud = cap if bud is None else bud
    if not 1 <= bud <= cap:
        raise ValueError(
            f"request budget {bud} outside [1, max_new_tokens={cap}] "
            "(the slot width)")
    return bud


@dataclasses.dataclass
class _RestoredRequest:
    """A request rebuilt from a :meth:`ContinuousEngine.snapshot` tree —
    duck-typed like :class:`repro.serve.batcher.Request` (kept here to
    avoid an engine→batcher import cycle).  ``deadline`` is re-anchored
    to the RESUMED process's clock from the snapshot's stored remaining
    time."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: Optional[int] = None
    deadline: Optional[float] = None


def _arch_has_ssm(cfg: ArchConfig) -> bool:
    """Whether the stack carries SSM layers — their sequential state
    updates have no pad-masking path, so ragged (padded) prefill is
    attention-only."""
    pattern = (cfg.decoder_pattern() if cfg.is_encoder_decoder
               else cfg.block_pattern())
    prefix, unit, _ = pattern
    return any(s.kind == "ssm" for s in (*prefix, *unit))


class ContinuousEngine:
    """Continuous-batching decode: persistent KV-cache slots with
    per-sequence refill — the serve-side twin of the farm tier's
    continuous lane refill (:meth:`repro.core.streaming.FarmEngine.
    run_continuous`).

    ``slots`` KV-cache lanes persist on device.  Decode advances in
    bounded *segments* (the :func:`repro.core.pattern.segmented_while`
    tier: control returns to the dispatcher as soon as any sequence
    newly finishes, or after ``segment`` steps).  A finished sequence's
    tokens are emitted immediately — not at the batch barrier — and its
    KV slot is handed to the next queued request mid-batch: the
    newcomer's prompt is prefilled into the slot (one whole-slot cache
    write, which also evicts any stale keys of the previous occupant)
    while the other sequences keep decoding at their own depths
    (per-sequence cache positions, RoPE and masks — see
    :func:`repro.models.transformer.step_with_cache`).

    One compilation serves every segment and every slot prefill of a
    stream (``stats["segment_traces"]`` / ``stats["prefill_traces"]``
    count trace events; both stay 1 after the first request).

    Prompts may be RAGGED: the engine binds ONE slot pool at
    ``max_prompt_len`` (given, or the longest prompt of the first run)
    and admits each request through a right-padded per-slot prefill with
    a prompt-length mask (:func:`repro.models.transformer.
    step_with_cache` ``prompt_len=``) — pad keys never enter an
    attention window or a ring cache, the first token is sampled at the
    prompt's own last REAL row, and decode continues from each slot's
    own depth.  ``stats["idle_slot_steps"]`` (the farm tier's
    ``wasted_lane_steps`` analogue) counts slot-steps burned on retired
    or done-masked slots; draining a ragged queue through one pool keeps
    it strictly below exact-length grouping, which idles a whole cohort
    at every group tail.

    Constraints: per-request ``max_new_tokens`` is capped by the
    engine-level ``gcfg.max_new_tokens`` (the slot width); models with
    absolute position embeddings, encoders or vision prefixes are not
    supported (their position bookkeeping is not per-sequence); ragged
    admission needs an attention-only stack (SSM state updates are
    sequential and have no pad-masking path — group those by exact
    length upstream, as :meth:`repro.serve.batcher.Batcher.
    run_continuous` does automatically).
    """

    def __init__(self, cfg: ArchConfig, params, gcfg: GenerateConfig, *,
                 slots: int = 8, cache_dtype=jnp.bfloat16,
                 segment: int = 8, max_prompt_len: Optional[int] = None):
        if cfg.abs_pos_embed or cfg.is_encoder_decoder or \
                cfg.vision_patches:
            raise ValueError(
                "continuous batching needs per-sequence positions; "
                "absolute position embeddings, encoder-decoder and "
                "vision-prefix models are round-based only")
        if segment < 1:
            raise ValueError(f"segment must be >= 1; got {segment}")
        self.cfg, self.params, self.gcfg = cfg, params, gcfg
        self.slots, self.cache_dtype = slots, cache_dtype
        self.segment = segment
        self.max_prompt_len = max_prompt_len
        self._bound = False
        self._segment_fn = jax.jit(self._segment_impl,
                                   donate_argnums=(1, 2, 3, 4, 5, 6, 7))
        self._prefill_fn = jax.jit(self._prefill_impl,
                                   donate_argnums=(1, 2, 3, 4, 5, 6, 7))
        # the chained-dispatch twins (run(..., chained=True)): only the
        # KV pool is donated — the small carry rows (out/done/t/...)
        # must SURVIVE each call, because the async drain still holds
        # the previous segments' captures and reads them only after the
        # next segment is in flight
        self._chain_seg_fn = jax.jit(self._chain_seg_impl,
                                     donate_argnums=(1,))
        self._chain_prefill_fn = jax.jit(self._prefill_impl,
                                         donate_argnums=(1,))
        self._chain_restore_fn = jax.jit(self._restore_slot_impl,
                                         donate_argnums=(0,))
        self._chain_retire_fn = jax.jit(
            lambda done, idx: done.at[idx].set(True))
        # deadline eviction with an empty queue: retire the slot in
        # place (same compilation for every eviction, done donated)
        self._retire_fn = jax.jit(
            lambda done, idx: done.at[idx].set(True),
            donate_argnums=(0,))
        # snapshot/restore of ONE slot's whole state (per-slot cache
        # slices + carry row): the reader is un-donated (safe on the
        # live buffers mid-stream), the writer donates like a prefill
        self._snap_slot_fn = jax.jit(self._snap_slot_impl)
        self._restore_slot_fn = jax.jit(
            self._restore_slot_impl,
            donate_argnums=(0, 1, 2, 3, 4, 5, 6))
        self.stats = {"requests": 0, "segments": 0, "prefills": 0,
                      "emitted": 0, "segment_traces": 0,
                      "chain_traces": 0,
                      "prefill_traces": 0, "slot_steps": 0,
                      "idle_slot_steps": 0, "evicted": 0, "shed": 0,
                      "snapshots": 0, "replayed_items": 0,
                      "recovered_occupants": 0, "recovery_seconds": 0.0}
        self._resume_state = None       # staged by restore()
        self._rt_capture = None         # live snapshot closure

    # -- static geometry (first run binds the shapes) --------------------
    def _bind(self, prompt_len: int):
        B, cap = self.slots, self.gcfg.max_new_tokens
        self._S0 = prompt_len                   # slot (max) prompt width
        self._max_seq = prompt_len + cap
        self._caches = T.init_cache(self.cfg, B, self._max_seq,
                                    self.cache_dtype)
        self._out = jnp.zeros((B, cap), jnp.int32)
        self._done = jnp.ones((B,), bool)
        self._t = jnp.ones((B,), jnp.int32)     # tokens generated
        self._budget = jnp.ones((B,), jnp.int32)
        self._keys = jnp.zeros((B, 2), jnp.uint32)
        self._plen = jnp.full((B,), prompt_len, jnp.int32)
        self._bound = True

    def _sample(self, logits, key):
        if self.gcfg.temperature > 0:
            return jax.random.categorical(
                key, logits / self.gcfg.temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    # -- slot prefill: hand a finished slot to the next request ----------
    def _prefill_impl(self, params, caches, out, done, t, budget, keys,
                      plens, idx, prompt, plen, bud, key):
        """Admit one request into slot ``idx`` (dynamic): prefill its
        RIGHT-PADDED prompt into a fresh single-sequence cache under the
        ``plen`` prompt-length mask, write that cache over the slot (one
        whole-slot dynamic_update_slice per leaf — this is the slot
        hand-off, and it evicts the previous occupant's stale keys
        wholesale), sample the first token at the prompt's own last REAL
        row, and re-arm the slot's carry.  One compilation serves every
        admission — the padded prompt width is the bound
        ``max_prompt_len``, whatever the request's true length."""
        self.stats["prefill_traces"] += 1       # traced once per stream
        fresh = T.init_cache(self.cfg, 1, self._max_seq, self.cache_dtype)
        logits, fresh = T.step_with_cache(self.cfg, params, fresh,
                                          prompt[None], 0,
                                          prompt_len=plen[None])
        last = jax.lax.dynamic_index_in_dim(logits[0], plen - 1, axis=0,
                                            keepdims=True)     # (1, V)
        first = self._sample(last, key)[0]

        def slot_write(axis):
            return lambda b, f: jax.lax.dynamic_update_slice_in_dim(
                b, f.astype(b.dtype), idx, axis=axis)
        caches = {"prefix": jax.tree.map(slot_write(0), caches["prefix"],
                                         fresh["prefix"]),
                  "unit": jax.tree.map(slot_write(1), caches["unit"],
                                       fresh["unit"])}
        out = out.at[idx].set(0).at[idx, 0].set(first.astype(jnp.int32))
        done = done.at[idx].set(
            jnp.logical_or(first == self.gcfg.eos_id, bud <= 1))
        t = t.at[idx].set(1)
        budget = budget.at[idx].set(bud)
        keys = keys.at[idx].set(key)
        plens = plens.at[idx].set(plen)
        return caches, out, done, t, budget, keys, plens

    # -- slot snapshot / restore (preemption recovery) -------------------
    def _snap_slot_impl(self, caches, idx):
        """Slice ONE slot's cache state out (prefix leaves carry the
        sequence on axis 0, unit leaves on axis 1 — the same convention
        ``_prefill_impl``'s slot_write uses)."""
        pfx = jax.tree.map(
            lambda b: jax.lax.dynamic_slice_in_dim(b, idx, 1, axis=0),
            caches["prefix"])
        unit = jax.tree.map(
            lambda b: jax.lax.dynamic_slice_in_dim(b, idx, 1, axis=1),
            caches["unit"])
        return pfx, unit

    def _restore_slot_impl(self, caches, out, done, t, budget, keys,
                           plens, idx, pfx, unit, out_row, dn, tv, budv,
                           keyv, plenv):
        """Re-seat one snapshotted in-flight decode into slot ``idx``
        (possibly a different slot index than it occupied pre-crash —
        the resumed engine may have a different slot count): the saved
        cache slices write through the SAME whole-slot paths a prefill
        uses, and the carry row (out/done/t/budget/key/plen) re-arms
        with the saved values so decoding continues mid-generation,
        sampling included (the PRNG key is part of the carry)."""
        def slot_write(axis):
            return lambda b, f: jax.lax.dynamic_update_slice_in_dim(
                b, f.astype(b.dtype), idx, axis=axis)
        caches = {"prefix": jax.tree.map(slot_write(0), caches["prefix"],
                                         pfx),
                  "unit": jax.tree.map(slot_write(1), caches["unit"],
                                       unit)}
        out = out.at[idx].set(out_row.astype(out.dtype))
        done = done.at[idx].set(dn)
        t = t.at[idx].set(jnp.asarray(tv, t.dtype))
        budget = budget.at[idx].set(jnp.asarray(budv, budget.dtype))
        keys = keys.at[idx].set(keyv.astype(keys.dtype))
        plens = plens.at[idx].set(jnp.asarray(plenv, plens.dtype))
        return caches, out, done, t, budget, keys, plens

    def snapshot(self) -> dict:
        """The in-flight serve state as ONE logical tree: every occupied
        slot's KV-cache slices, output row, position/budget/PRNG-key
        carry, its request (deadline stored as REMAINING seconds — it
        re-anchors to the resumed process's clock), the not-yet-admitted
        queue, and the admission-key cursor (``stats["prefills"]`` — so
        post-resume admissions sample the same keys an uninterrupted run
        would).  Topology-free over ``slots``: restore onto a pool of
        any size.  Only meaningful at a segment boundary (use
        ``on_segment``, or pass ``recovery=`` to :meth:`run`)."""
        if self._rt_capture is None:
            raise ValueError(
                "snapshot() captures in-flight serve state; nothing has "
                "run yet — call run() (pass recovery= to persist "
                "snapshots automatically)")
        return self._rt_capture()

    def restore(self, state: dict) -> "ContinuousEngine":
        """Stage a :meth:`snapshot` tree; the next :meth:`run` resumes
        from it (in-flight decodes continue mid-generation, queued
        requests re-queue ahead of new ones).  The engine's ``slots``
        may differ from the snapshotting engine's; the generation cap
        and bound prompt width may not."""
        if not isinstance(state, dict) or state.get("kind") != "serve":
            raise ValueError("not a ContinuousEngine snapshot tree")
        if int(state.get("version", -1)) != 1:
            raise ValueError("unsupported ContinuousEngine snapshot "
                             f"version {state.get('version')!r}")
        if int(state["cap"]) != self.gcfg.max_new_tokens:
            raise ValueError(
                f"snapshot generation cap {state['cap']} != engine cap "
                f"{self.gcfg.max_new_tokens} (the out-buffer width is "
                "part of the slot geometry)")
        if self._bound and int(state["S0"]) != self._S0:
            raise ValueError(
                f"snapshot prompt width {state['S0']} != bound slot "
                f"width {self._S0}")
        self._resume_state = state
        return self

    # -- one bounded decode segment --------------------------------------
    def _segment_impl(self, params, caches, out, done, t, budget, keys,
                      plens):
        """Advance every live slot up to ``segment`` decode steps,
        returning as soon as any sequence newly finishes (EOS or its own
        token budget).  Per-sequence positions: slot b reads its last
        token at out[b, t_b-1] and writes the cache at plen_b + t_b - 1
        (each slot decodes from its OWN prompt depth — ragged prompts
        share the pool)."""
        self.stats["segment_traces"] += 1       # traced once per stream
        return self._segment_core(params, caches, out, done, t, budget,
                                  keys, plens)

    def _chain_seg_impl(self, params, caches, out, done, t, budget,
                        keys, plens):
        """The chained path's segment: the SAME decode core, jitted with
        only the KV pool donated — the returned carry rows double as the
        drain's captures (read asynchronously, one pipeline stage
        later), so their buffers must outlive the next dispatch."""
        self.stats["segment_traces"] += 1       # traced once per stream
        self.stats["chain_traces"] += 1
        return self._segment_core(params, caches, out, done, t, budget,
                                  keys, plens)

    def _segment_core(self, params, caches, out, done, t, budget, keys,
                      plens):
        from repro.core.pattern import segmented_while

        B, cap = self.slots, self.gcfg.max_new_tokens
        eos = self.gcfg.eos_id

        def body(carry):
            caches, out, done, t, keys = carry
            live = jnp.logical_not(done)
            tok = jnp.take_along_axis(out, (t - 1)[:, None], axis=1)
            pos = (plens + t - 1)[:, None]               # (B, 1)
            logits, caches = T.decode_step(self.cfg, params, caches,
                                           tok, pos)
            if self.gcfg.temperature > 0:
                nk = jax.vmap(jax.random.split)(keys)    # (B, 2, 2)
                keys = jnp.where(live[:, None], nk[:, 0], keys)
                nxt = jax.vmap(
                    lambda lg, kk: self._sample(lg, kk))(logits[:, 0],
                                                         nk[:, 1])
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)
            nxt = jnp.where(live, nxt, jnp.full_like(nxt, eos))
            tw = jnp.minimum(t, cap - 1)
            row = jnp.arange(B)
            out = out.at[row, tw].set(
                jnp.where(live, nxt.astype(jnp.int32), out[row, tw]))
            t = jnp.where(live, t + 1, t)
            done = jnp.logical_or(
                done, jnp.logical_and(
                    live, jnp.logical_or(nxt == eos, t >= budget)))
            return caches, out, done, t, keys

        (caches, out, done, t, keys), steps = segmented_while(
            body, (caches, out, done, t, keys),
            finished=lambda c: c[2], segment=self.segment)
        return caches, out, done, t, budget, keys, plens, steps

    # -- the dispatcher ---------------------------------------------------
    def run(self, requests, emit, *, clock=None, recovery=None,
            resume: bool = False,
            on_segment: Optional[Callable] = None,
            chained: bool = False) -> int:
        """Serve ``requests`` (RAGGED prompt lengths and wildly
        different ``.max_new_tokens`` welcome) through the slots,
        calling ``emit(rid, tokens, status)`` the moment each finishes —
        completion order, mid-batch.  Returns the number of emissions.

        A request may carry an absolute ``.deadline`` (on ``clock``'s
        timeline; default ``time.monotonic`` — tests inject a fake
        clock for determinism).  A request whose deadline has already
        passed at admission is SHED: emitted immediately with
        ``status="timed_out"`` and no tokens, never touching a slot
        (``stats["shed"]``).  A slot whose occupant's deadline passes
        mid-decode is EVICTED after the current segment: its partial
        tokens emit with ``status="timed_out"`` and the KV slot is
        freed for the next queued request through the ordinary refill
        path — or retired in place when the queue is empty
        (``stats["evicted"]``).  No deadline → the request always runs
        to EOS or budget (``status="ok"``).

        Preemption recovery (DESIGN.md §Recovery): with ``recovery=``
        (a :class:`repro.resilience.recovery.RecoveryConfig`) every
        emission is write-ahead journaled (fsync'd, CRC-framed) BEFORE
        the ``emit`` callback runs, and the whole in-flight serve state
        — see :meth:`snapshot` — publishes atomically every
        ``snapshot_every`` segments.  ``resume=True`` restarts a killed
        run: the journal replays pre-crash emissions (each ``rid``
        suppressed from re-emission — rids must be unique per request,
        they are the exactly-once key), snapshotted in-flight decodes
        re-seat into slots and continue mid-generation (on a pool of
        ANY slot count — elastic resume; extras wait their turn ahead
        of the queue), queued requests re-queue ahead of new ones, and
        deadlines re-anchor to this process's clock from their stored
        remaining time.  ``on_segment`` is called with the cumulative
        segment count at every segment boundary — the seam
        ``FaultPlan.preempt_hook`` kills through.

        ``chained=True`` switches the dispatcher to the chained
        pipeline (the serve twin of the farm tier's device-resident
        dispatch): segment t+1 is dispatched BEFORE segment t's
        done/token metadata is read back, so the per-segment
        admission/eviction round trip comes off the device's critical
        path.  Admissions land on the latest carry and therefore LAG
        one in-flight segment — a freed slot idles one extra segment
        before its next occupant decodes (counted in
        ``idle_slot_steps``), the price of never blocking the chain.
        Snapshot boundaries drain the pipeline explicitly; emission
        order, exactly-once and token bit-identity match the
        synchronous path.
        """
        clock = time.monotonic if clock is None else clock
        t_resume0 = time.perf_counter()
        if self._resume_state is None and recovery is not None and resume:
            from repro.resilience.recovery import load_snapshot
            st = load_snapshot(recovery.snap_dir)
            if st is not None:
                self.restore(st)    # validates kind / version / cap / S0
        state = None
        if self._resume_state is not None:
            state, self._resume_state = self._resume_state, None

        cap = self.gcfg.max_new_tokens
        journal = None
        emitted_pre: set = set()
        n_emit = 0

        def deliver(rid, tokens, status, journal_rec=True):
            """WAL-ordered emission: journal (fsync'd) FIRST, then the
            ``emit`` callback — a crash between the two re-delivers
            from the journal on resume, never re-decodes."""
            nonlocal n_emit
            if journal is not None and journal_rec:
                journal.append({"rid": rid,
                                "tokens": [int(x) for x in tokens],
                                "status": status})
            emit(rid, tokens, status)
            n_emit += 1

        if recovery is not None and resume:
            from repro.resilience.recovery import Journal
            for rec in Journal.replay(recovery.journal_path):
                rid = rec["rid"]
                if rid in emitted_pre:
                    continue
                emitted_pre.add(rid)
                deliver(rid, np.asarray(rec["tokens"], np.int32),
                        rec.get("status", "ok"), journal_rec=False)
                self.stats["replayed_items"] += 1
        if recovery is not None:
            from repro.resilience.recovery import Journal
            journal = Journal(recovery.journal_path,
                              fsync=recovery.fsync)

        queue = list(requests)
        restore_q: list = []
        if state is not None:
            # segment counter restores so snapshot step numbering (and
            # preempt thresholds) stay monotonic across restarts; the
            # prefill counter is the admission-key cursor — restoring
            # it makes post-resume admissions sample the same keys an
            # uninterrupted run would
            self.stats["segments"] = int(state.get("segments", 0))
            self.stats["prefills"] = int(state.get("prefills", 0))
            restore_q = [dict(e) for e in state.get("occupants") or ()]
            now0 = clock()
            requeued = []
            for q in state.get("queue") or ():
                rem = q.get("deadline_remaining")
                requeued.append(_RestoredRequest(
                    rid=q["rid"],
                    prompt=np.asarray(q["prompt"], np.int32),
                    max_new_tokens=q.get("max_new_tokens"),
                    deadline=(now0 + float(rem)) if rem is not None
                    else None))
            queue = requeued + queue    # pre-crash admissions first
        if not queue and not restore_q:
            if journal is not None:
                journal.close()
            if state is not None or resume:
                self.stats["recovery_seconds"] += (
                    time.perf_counter() - t_resume0)
            return n_emit
        lens = [len(r.prompt) for r in queue]
        if state is not None:
            bound = int(state["S0"])
            if self.max_prompt_len and self.max_prompt_len != bound:
                raise ValueError(
                    f"engine max_prompt_len={self.max_prompt_len} != "
                    f"snapshot prompt width {bound} (the restored cache "
                    "slices carry the snapshotting pool's width)")
        else:
            bound = (self._S0 if self._bound
                     else (self.max_prompt_len or max(lens, default=1)))
        for r, L in zip(queue, lens):
            if not 1 <= L <= bound:
                raise ValueError(
                    f"prompt length {L} outside [1, max_prompt_len="
                    f"{bound}] (the slot pool's bound prompt width; "
                    "build the engine with a larger max_prompt_len)")
            request_budget(r, cap)
        if any(L != bound for L in lens) and _arch_has_ssm(self.cfg):
            raise ValueError(
                "ragged prompts need an attention-only stack (an SSM "
                "layer's state update is sequential — a pad token would "
                "corrupt it); group requests by exact prompt length "
                "upstream, as Batcher.run_continuous does for SSM archs")
        if not self._bound:
            self._bind(bound)
        queue = queue[::-1]                     # pop() = FIFO order
        caches, out, done = self._caches, self._out, self._done
        t, budget, keys = self._t, self._budget, self._keys
        plens = self._plen
        pfx_def = jax.tree.structure(caches["prefix"])
        unit_def = jax.tree.structure(caches["unit"])
        occupants = [None] * self.slots
        base_key = jax.random.PRNGKey(self.gcfg.seed)
        prev_t = np.asarray(t).astype(np.int64)

        def deadline_of(req):
            return getattr(req, "deadline", None)

        def pull():
            """Next admissible request — requests already past their
            deadline are shed here, without ever touching a slot, and
            requests whose emission was journaled pre-crash are dropped
            (the replay already re-delivered them)."""
            while queue:
                req = queue.pop()
                if req.rid in emitted_pre:
                    continue
                dl = deadline_of(req)
                if dl is not None and clock() >= dl:
                    deliver(req.rid, np.zeros((0,), np.int32),
                            "timed_out")
                    self.stats["shed"] += 1
                    self.stats["requests"] += 1
                    continue
                return req
            return None

        prefill_fn = (self._chain_prefill_fn if chained
                      else self._prefill_fn)
        restore_fn = (self._chain_restore_fn if chained
                      else self._restore_slot_fn)

        def admit(slot, req):
            nonlocal caches, out, done, t, budget, keys, plens
            bud = request_budget(req, cap)
            ptoks = np.asarray(req.prompt, np.int32)
            prompt = np.zeros((self._S0,), np.int32)    # right-padded
            prompt[:len(ptoks)] = ptoks
            key = jax.random.fold_in(base_key, self.stats["prefills"])
            (caches, out, done, t, budget, keys,
             plens) = prefill_fn(
                self.params, caches, out, done, t, budget, keys, plens,
                jnp.asarray(slot, jnp.int32), jnp.asarray(prompt),
                jnp.asarray(len(ptoks), jnp.int32),
                jnp.asarray(bud, jnp.int32), key)
            occupants[slot] = req
            prev_t[slot] = 1            # the prefilled first token is
                                        # not a segment step
            self.stats["prefills"] += 1
            self.stats["requests"] += 1

        def fill(slot):
            """Seat the next unit of work into a free slot: snapshotted
            in-flight decodes first (they re-enter mid-generation,
            whatever slot index they held pre-crash), then the queue.
            Returns False when there is nothing left to seat."""
            nonlocal caches, out, done, t, budget, keys, plens
            while restore_q:
                e = restore_q.pop(0)
                if e["rid"] in emitted_pre:
                    continue
                rem = e.get("deadline_remaining")
                req = _RestoredRequest(
                    rid=e["rid"],
                    prompt=np.asarray(e["prompt"], np.int32),
                    max_new_tokens=e.get("max_new_tokens"),
                    deadline=(clock() + float(rem)) if rem is not None
                    else None)
                pfx = jax.tree.unflatten(
                    pfx_def, [jnp.asarray(l) for l in e["prefix"]])
                unit = jax.tree.unflatten(
                    unit_def, [jnp.asarray(l) for l in e["unit"]])
                (caches, out, done, t, budget, keys,
                 plens) = restore_fn(
                    caches, out, done, t, budget, keys, plens,
                    jnp.asarray(slot, jnp.int32), pfx, unit,
                    jnp.asarray(e["out"], jnp.int32),
                    jnp.asarray(bool(e["done"])),
                    jnp.asarray(int(e["t"]), jnp.int32),
                    jnp.asarray(int(e["budget"]), jnp.int32),
                    jnp.asarray(e["key"], jnp.uint32),
                    jnp.asarray(int(e["plen"]), jnp.int32))
                occupants[slot] = req
                prev_t[slot] = int(e["t"])
                self.stats["recovered_occupants"] += 1
                return True
            req = pull()
            if req is None:
                return False
            admit(slot, req)
            return True

        def capture(complete=None):
            """Build the :meth:`snapshot` tree from the live run state
            (the slot reader is un-donated — the pool stays intact)."""
            out_h = np.asarray(out)
            done_h = np.asarray(done)
            t_h = np.asarray(t).astype(np.int64)
            bud_h = np.asarray(budget)
            keys_h = np.asarray(keys)
            plen_h = np.asarray(plens)
            now = clock()
            occ = []
            for s in range(self.slots):
                req = occupants[s]
                if req is None:
                    continue
                pfx, unit = self._snap_slot_fn(
                    caches, jnp.asarray(s, jnp.int32))
                dl = deadline_of(req)
                occ.append({
                    "rid": req.rid,
                    "prompt": np.asarray(req.prompt, np.int32),
                    "max_new_tokens": getattr(req, "max_new_tokens",
                                              None),
                    "deadline_remaining": (float(dl - now)
                                           if dl is not None else None),
                    "done": bool(done_h[s]), "out": out_h[s].copy(),
                    "t": int(t_h[s]), "budget": int(bud_h[s]),
                    "key": keys_h[s].copy(), "plen": int(plen_h[s]),
                    "prefix": [np.asarray(l)
                               for l in jax.tree.leaves(pfx)],
                    "unit": [np.asarray(l)
                             for l in jax.tree.leaves(unit)]})
            # in-flight decodes a SMALLER resumed pool has not re-seated
            # yet survive verbatim — their slices are still topology-free
            occ.extend(restore_q)
            qs = []
            for req in reversed(queue):         # stored in FIFO order
                dl = deadline_of(req)
                qs.append({
                    "rid": req.rid,
                    "prompt": np.asarray(req.prompt, np.int32),
                    "max_new_tokens": getattr(req, "max_new_tokens",
                                              None),
                    "deadline_remaining": (float(dl - now)
                                           if dl is not None else None)})
            if complete is None:
                complete = not occ and not qs
            return {"kind": "serve", "version": 1,
                    "S0": int(self._S0), "cap": int(cap),
                    "segments": int(self.stats["segments"]),
                    "prefills": int(self.stats["prefills"]),
                    "occupants": occ, "queue": qs,
                    "complete": bool(complete)}

        self._rt_capture = capture

        def persist(complete=None):
            if recovery is None:
                return
            from repro.resilience.recovery import save_snapshot
            save_snapshot(recovery.snap_dir, self.stats["segments"],
                          capture(complete), keep=recovery.keep)
            self.stats["snapshots"] += 1

        def run_chained():
            """The serve twin of the farm tier's chained dispatch:
            segment t+1 dispatches BEFORE segment t's metadata is read,
            so the admission/eviction round trip runs while the device
            decodes.  Seating lands on the LATEST carry — an occupant
            seated during the drain of segment t was not in segment
            t+1's flight, so every in-flight capture carries its
            dispatch ordinal and the drain skips slots whose occupant
            was seated at or after it (``seated_at`` epoch guard: the
            captured done/t/out rows there belong to the previous
            occupant)."""
            nonlocal caches, out, done, t, budget, keys, plens, prev_t
            from collections import deque
            inflight: deque = deque()   # (ordinal, done, t, out, steps)
            seated_at = np.zeros((self.slots,), np.int64)
            ndisp = 0

            def dispatch():
                nonlocal caches, out, done, t, budget, keys, plens
                nonlocal ndisp
                (caches, out, done, t, budget, keys, plens,
                 steps) = self._chain_seg_fn(self.params, caches, out,
                                             done, t, budget, keys,
                                             plens)
                ndisp += 1
                self.stats["segments"] += 1
                if on_segment is not None:
                    # the same preemption window as the classic loop:
                    # compute in flight, nothing delivered yet
                    on_segment(self.stats["segments"])
                inflight.append((ndisp, done, t, out, steps))

            def drain_one():
                nonlocal prev_t, done
                d, done_d, t_d, out_d, steps_d = inflight.popleft()
                done_h, t_h, out_h, steps_h = jax.device_get(
                    (done_d, t_d, out_d, steps_d))
                t_h = t_h.astype(np.int64)
                valid = seated_at < d
                steps_i = int(steps_h)
                self.stats["slot_steps"] += steps_i * self.slots
                useful = int((t_h - prev_t)[valid].sum())
                self.stats["idle_slot_steps"] += \
                    steps_i * self.slots - useful
                prev_t = np.where(valid, t_h, prev_t)
                now = clock()
                for slot in range(self.slots):
                    req = occupants[slot]
                    if req is None or not valid[slot]:
                        continue
                    if done_h[slot]:
                        deliver(req.rid,
                                out_h[slot, :int(t_h[slot])].copy(),
                                "ok")
                        self.stats["emitted"] += 1
                        occupants[slot] = None
                        if fill(slot):
                            seated_at[slot] = ndisp
                        continue
                    dl = deadline_of(req)
                    if dl is not None and now >= dl:
                        deliver(req.rid,
                                out_h[slot, :int(t_h[slot])].copy(),
                                "timed_out")
                        self.stats["evicted"] += 1
                        occupants[slot] = None
                        if fill(slot):
                            seated_at[slot] = ndisp
                        else:
                            done = self._chain_retire_fn(
                                done, jnp.asarray(slot, jnp.int32))

            while True:
                work = any(o is not None for o in occupants)
                if not work and not inflight:
                    break
                if work:
                    dispatch()
                # lag-1 drain: with a fresh dispatch in flight, consume
                # only the PREVIOUS segment — the metadata read overlaps
                # the device's current segment.  At the tail, flush.
                if len(inflight) > (1 if work else 0):
                    drain_one()
                if work and recovery is not None and \
                        self.stats["segments"] % \
                        recovery.snapshot_every == 0:
                    # snapshot boundary: ONE explicit pipeline drain —
                    # the capture below then reads a carry every
                    # seating has landed on
                    while inflight:
                        drain_one()
                    persist()

        try:
            for slot in range(self.slots):
                if not fill(slot):
                    break
            persist(complete=False)   # RPO anchor: recoverable before
                                      # the first segment even starts
            if state is not None or resume:
                self.stats["recovery_seconds"] += (
                    time.perf_counter() - t_resume0)

            if chained:
                run_chained()
            else:
                while any(o is not None for o in occupants):
                    (caches, out, done, t, budget, keys, plens,
                     steps) = self._segment_fn(self.params, caches, out,
                                               done, t, budget, keys,
                                               plens)
                    self.stats["segments"] += 1
                    if on_segment is not None:
                        # BEFORE emission — the harshest preemption
                        # window: compute done, nothing delivered (the
                        # journal replay + snapshot redo cover exactly
                        # this gap)
                        on_segment(self.stats["segments"])
                    done_h = np.asarray(done)
                    t_h = np.asarray(t).astype(np.int64)
                    out_h = np.asarray(out)
                    # idle-slot accounting (the wasted_lane_steps
                    # analogue): each body step advances every LIVE
                    # slot one token; retired/done-masked slots burn
                    # the step
                    steps_h = int(steps)
                    useful = int((t_h - prev_t).sum())
                    self.stats["slot_steps"] += steps_h * self.slots
                    self.stats["idle_slot_steps"] += \
                        steps_h * self.slots - useful
                    prev_t = t_h.copy()
                    now = clock()
                    for slot in range(self.slots):
                        req = occupants[slot]
                        if req is None:
                            continue
                        if done_h[slot]:
                            deliver(req.rid,
                                    out_h[slot, :int(t_h[slot])].copy(),
                                    "ok")
                            self.stats["emitted"] += 1
                            occupants[slot] = None
                            fill(slot)
                            continue
                        dl = deadline_of(req)
                        if dl is not None and now >= dl:
                            # deadline eviction: the partial output
                            # emits now and the KV slot is freed
                            # mid-batch — the next request prefills
                            # over it (the ordinary refill path evicts
                            # the stale keys wholesale), or the slot
                            # retires in place
                            deliver(req.rid,
                                    out_h[slot, :int(t_h[slot])].copy(),
                                    "timed_out")
                            self.stats["evicted"] += 1
                            occupants[slot] = None
                            if not fill(slot):
                                done = self._retire_fn(
                                    done, jnp.asarray(slot, jnp.int32))
                    if recovery is not None and \
                            self.stats["segments"] % \
                            recovery.snapshot_every == 0:
                        persist()
            persist(complete=True)
        finally:
            # locals always name the LIVE buffers (the donated inputs
            # were consumed by the calls that produced these), so a
            # raising emit callback cannot strand the engine on deleted
            # device buffers
            self._caches, self._out, self._done = caches, out, done
            self._t, self._budget, self._keys = t, budget, keys
            self._plen = plens
            if journal is not None:
                journal.close()
        return n_emit
