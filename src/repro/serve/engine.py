"""Serving engine — autoregressive decode as Loop-of-stencil-reduce-s.

The decode loop is the -s variant verbatim (DESIGN.md §4):
    stencil step : one `decode_step` (attention over the KV-cache
                   neighbourhood — the sliding-window layers are literal
                   sequence stencils)
    reduce /⊕    : `all` monoid over per-sequence done flags
    state s      : position counter + PRNG key
    condition c  : every sequence hit EOS ∨ token budget

The whole generation lowers to ONE on-device while_loop: the KV cache is
the paper's persistent device memory — it never leaves HBM, and the
done-reduce feeding the condition runs on device (beyond the paper, which
still bounced the reduce result to the host each iteration).

In stream-tier terms (:mod:`repro.core.streaming`) a generate batch IS a
lane farm: each sequence is a lane of the done-masked loop, running to
its own EOS trip count while the KV cache plays the persistent lane
frame.  The host side composes accordingly — :class:`repro.serve.
batcher.Batcher` drives batches through the FarmEngine's double-buffered
read ∥ decode ∥ write protocol.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.pattern import LoopOfStencilReduce
from repro.models import transformer as T


@dataclasses.dataclass
class GenerateConfig:
    max_new_tokens: int = 64
    eos_id: int = 1
    temperature: float = 0.0       # 0 → greedy
    seed: int = 0


def prefill(cfg: ArchConfig, params, tokens, *, max_seq: int,
            cache_dtype=jnp.bfloat16, patch_embeds=None, enc_out=None,
            cross_caches=None):
    """Run the prompt through the model, returning (last_logits, caches)."""
    B = tokens.shape[0]
    caches = T.init_cache(cfg, B, max_seq, cache_dtype)
    logits, caches = T.step_with_cache(
        cfg, params, caches, tokens, 0, patch_embeds=patch_embeds,
        enc_out=enc_out, cross_caches=cross_caches)
    return logits[:, -1], caches


def generate(cfg: ArchConfig, params, prompt, gcfg: GenerateConfig, *,
             max_seq: Optional[int] = None, cache_dtype=jnp.bfloat16,
             enc_out=None, cross_caches=None, patch_embeds=None):
    """Batched generation.  Returns (tokens (B, max_new), lengths, iters)."""
    B, S0 = prompt.shape
    P = cfg.vision_patches or 0
    max_seq = max_seq or (S0 + P + gcfg.max_new_tokens)

    last_logits, caches = prefill(
        cfg, params, prompt, max_seq=max_seq, cache_dtype=cache_dtype,
        patch_embeds=patch_embeds, enc_out=enc_out,
        cross_caches=cross_caches)

    def sample(logits, key):
        if gcfg.temperature > 0:
            return jax.random.categorical(key, logits / gcfg.temperature,
                                          axis=-1)
        return jnp.argmax(logits, axis=-1)

    key0 = jax.random.PRNGKey(gcfg.seed)
    first = sample(last_logits, key0)                     # (B,)
    out0 = jnp.zeros((B, gcfg.max_new_tokens), jnp.int32)
    out0 = out0.at[:, 0].set(first)
    done0 = first == gcfg.eos_id

    def step_fn(carry):
        caches, out, done, t, key = carry
        tok = jax.lax.dynamic_slice_in_dim(out, t - 1, 1, axis=1)
        logits, caches = T.decode_step(
            cfg, params, caches, tok, S0 + P + t - 1,
            enc_out=enc_out, cross_caches=cross_caches)
        key, sub = jax.random.split(key)
        nxt = sample(logits[:, 0], sub)
        nxt = jnp.where(done, jnp.full_like(nxt, gcfg.eos_id), nxt)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, nxt[:, None].astype(out.dtype), t, axis=1)
        done = done | (nxt == gcfg.eos_id)
        return (caches, out, done, t + 1, key)

    loop = LoopOfStencilReduce(
        f=step_fn, mode="step", combine="all", identity=True,
        measure=lambda c: c[2],                   # per-sequence done flags
        cond=lambda r, s: jnp.logical_or(r, s >= gcfg.max_new_tokens),
        state_init=lambda: jnp.asarray(1, jnp.int32),
        state_update=lambda s, a, it: s + 1,
        max_iters=gcfg.max_new_tokens)

    res = loop.run((caches, out0, done0, jnp.asarray(1, jnp.int32), key0))
    _, out, done, _, _ = res.a
    lengths = jnp.where(
        (out == gcfg.eos_id).any(axis=1),
        (out == gcfg.eos_id).argmax(axis=1) + 1, gcfg.max_new_tokens)
    return out, lengths, res.iters


def generate_jit(cfg: ArchConfig, gcfg: GenerateConfig, **kw):
    """Jit-compiled generate closure (static cfg/gcfg)."""
    return jax.jit(functools.partial(generate, cfg, gcfg=gcfg, **kw))
