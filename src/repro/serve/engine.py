"""Serving engine — autoregressive decode as Loop-of-stencil-reduce-s.

The decode loop is the -s variant verbatim (DESIGN.md §4):
    stencil step : one `decode_step` (attention over the KV-cache
                   neighbourhood — the sliding-window layers are literal
                   sequence stencils)
    reduce /⊕    : `all` monoid over per-sequence done flags
    state s      : position counter + PRNG key
    condition c  : every sequence hit EOS ∨ token budget

The whole generation lowers to ONE on-device while_loop: the KV cache is
the paper's persistent device memory — it never leaves HBM, and the
done-reduce feeding the condition runs on device (beyond the paper, which
still bounced the reduce result to the host each iteration).

In stream-tier terms (:mod:`repro.core.streaming`) a generate batch IS a
lane farm: each sequence is a lane of the done-masked loop, running to
its own EOS trip count while the KV cache plays the persistent lane
frame.  The host side composes accordingly — :class:`repro.serve.
batcher.Batcher` drives batches through the FarmEngine's double-buffered
read ∥ decode ∥ write protocol.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.pattern import LoopOfStencilReduce
from repro.models import transformer as T


@dataclasses.dataclass
class GenerateConfig:
    max_new_tokens: int = 64
    eos_id: int = 1
    temperature: float = 0.0       # 0 → greedy
    seed: int = 0


def prefill(cfg: ArchConfig, params, tokens, *, max_seq: int,
            cache_dtype=jnp.bfloat16, patch_embeds=None, enc_out=None,
            cross_caches=None):
    """Run the prompt through the model, returning (last_logits, caches)."""
    B = tokens.shape[0]
    caches = T.init_cache(cfg, B, max_seq, cache_dtype)
    logits, caches = T.step_with_cache(
        cfg, params, caches, tokens, 0, patch_embeds=patch_embeds,
        enc_out=enc_out, cross_caches=cross_caches)
    return logits[:, -1], caches


def generate(cfg: ArchConfig, params, prompt, gcfg: GenerateConfig, *,
             max_seq: Optional[int] = None, cache_dtype=jnp.bfloat16,
             enc_out=None, cross_caches=None, patch_embeds=None,
             budgets=None):
    """Batched generation.  Returns (tokens (B, max_new), lengths, iters).

    ``budgets`` is an optional (B,) int vector of per-sequence
    ``max_new_tokens`` (each in [1, gcfg.max_new_tokens]): the
    done-mask retires a sequence at its OWN budget, mirroring the
    continuous engine's per-request budgets, so round-mode batches honor
    ``Request.max_new_tokens`` identically.  ``lengths`` is clipped to
    the budget (post-done positions are eos-padded in ``out``).
    """
    B, S0 = prompt.shape
    P = cfg.vision_patches or 0
    max_seq = max_seq or (S0 + P + gcfg.max_new_tokens)

    last_logits, caches = prefill(
        cfg, params, prompt, max_seq=max_seq, cache_dtype=cache_dtype,
        patch_embeds=patch_embeds, enc_out=enc_out,
        cross_caches=cross_caches)

    def sample(logits, key):
        if gcfg.temperature > 0:
            return jax.random.categorical(key, logits / gcfg.temperature,
                                          axis=-1)
        return jnp.argmax(logits, axis=-1)

    bud = (jnp.full((B,), gcfg.max_new_tokens, jnp.int32)
           if budgets is None else jnp.asarray(budgets, jnp.int32))
    key0 = jax.random.PRNGKey(gcfg.seed)
    first = sample(last_logits, key0)                     # (B,)
    out0 = jnp.zeros((B, gcfg.max_new_tokens), jnp.int32)
    out0 = out0.at[:, 0].set(first)
    done0 = jnp.logical_or(first == gcfg.eos_id, bud <= 1)

    def step_fn(carry):
        caches, out, done, t, key = carry
        tok = jax.lax.dynamic_slice_in_dim(out, t - 1, 1, axis=1)
        logits, caches = T.decode_step(
            cfg, params, caches, tok, S0 + P + t - 1,
            enc_out=enc_out, cross_caches=cross_caches)
        key, sub = jax.random.split(key)
        nxt = sample(logits[:, 0], sub)
        nxt = jnp.where(done, jnp.full_like(nxt, gcfg.eos_id), nxt)
        if gcfg.max_new_tokens > 1:
            # cap == 1: the repeat/until still runs its one mandatory
            # body step, whose write index (t=1) would CLIP onto column
            # 0 and eos-pad over the only real token — skip it (every
            # lane is already done0-retired at cap 1)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, nxt[:, None].astype(out.dtype), t, axis=1)
        done = done | (nxt == gcfg.eos_id) | (t + 1 >= bud)
        return (caches, out, done, t + 1, key)

    loop = LoopOfStencilReduce(
        f=step_fn, mode="step", combine="all", identity=True,
        measure=lambda c: c[2],                   # per-sequence done flags
        cond=lambda r, s: jnp.logical_or(r, s >= gcfg.max_new_tokens),
        state_init=lambda: jnp.asarray(1, jnp.int32),
        state_update=lambda s, a, it: s + 1,
        max_iters=gcfg.max_new_tokens)

    res = loop.run((caches, out0, done0, jnp.asarray(1, jnp.int32), key0))
    _, out, done, _, _ = res.a
    lengths = jnp.where(
        (out == gcfg.eos_id).any(axis=1),
        (out == gcfg.eos_id).argmax(axis=1) + 1, gcfg.max_new_tokens)
    # a budget-retired sequence has eos PADS from its budget on — clip
    # so the pad never counts as a sampled token
    lengths = jnp.minimum(lengths, bud)
    return out, lengths, res.iters


def generate_jit(cfg: ArchConfig, gcfg: GenerateConfig, **kw):
    """Jit-compiled generate closure (static cfg/gcfg)."""
    return jax.jit(functools.partial(generate, cfg, gcfg=gcfg, **kw))


# ---------------------------------------------------------------------------
# Continuous batching — per-sequence KV-slot refill.
# ---------------------------------------------------------------------------


def request_budget(req, cap: int) -> int:
    """Resolve a request's per-sequence token budget against the engine
    cap — the ONE validation rule shared by the round path
    (:meth:`repro.serve.batcher.Batcher.run_all`) and the continuous
    engine, so the two paths cannot drift (their budget parity is
    regression-tested)."""
    bud = getattr(req, "max_new_tokens", None)
    bud = cap if bud is None else bud
    if not 1 <= bud <= cap:
        raise ValueError(
            f"request budget {bud} outside [1, max_new_tokens={cap}] "
            "(the slot width)")
    return bud


def _arch_has_ssm(cfg: ArchConfig) -> bool:
    """Whether the stack carries SSM layers — their sequential state
    updates have no pad-masking path, so ragged (padded) prefill is
    attention-only."""
    pattern = (cfg.decoder_pattern() if cfg.is_encoder_decoder
               else cfg.block_pattern())
    prefix, unit, _ = pattern
    return any(s.kind == "ssm" for s in (*prefix, *unit))


class ContinuousEngine:
    """Continuous-batching decode: persistent KV-cache slots with
    per-sequence refill — the serve-side twin of the farm tier's
    continuous lane refill (:meth:`repro.core.streaming.FarmEngine.
    run_continuous`).

    ``slots`` KV-cache lanes persist on device.  Decode advances in
    bounded *segments* (the :func:`repro.core.pattern.segmented_while`
    tier: control returns to the dispatcher as soon as any sequence
    newly finishes, or after ``segment`` steps).  A finished sequence's
    tokens are emitted immediately — not at the batch barrier — and its
    KV slot is handed to the next queued request mid-batch: the
    newcomer's prompt is prefilled into the slot (one whole-slot cache
    write, which also evicts any stale keys of the previous occupant)
    while the other sequences keep decoding at their own depths
    (per-sequence cache positions, RoPE and masks — see
    :func:`repro.models.transformer.step_with_cache`).

    One compilation serves every segment and every slot prefill of a
    stream (``stats["segment_traces"]`` / ``stats["prefill_traces"]``
    count trace events; both stay 1 after the first request).

    Prompts may be RAGGED: the engine binds ONE slot pool at
    ``max_prompt_len`` (given, or the longest prompt of the first run)
    and admits each request through a right-padded per-slot prefill with
    a prompt-length mask (:func:`repro.models.transformer.
    step_with_cache` ``prompt_len=``) — pad keys never enter an
    attention window or a ring cache, the first token is sampled at the
    prompt's own last REAL row, and decode continues from each slot's
    own depth.  ``stats["idle_slot_steps"]`` (the farm tier's
    ``wasted_lane_steps`` analogue) counts slot-steps burned on retired
    or done-masked slots; draining a ragged queue through one pool keeps
    it strictly below exact-length grouping, which idles a whole cohort
    at every group tail.

    Constraints: per-request ``max_new_tokens`` is capped by the
    engine-level ``gcfg.max_new_tokens`` (the slot width); models with
    absolute position embeddings, encoders or vision prefixes are not
    supported (their position bookkeeping is not per-sequence); ragged
    admission needs an attention-only stack (SSM state updates are
    sequential and have no pad-masking path — group those by exact
    length upstream, as :meth:`repro.serve.batcher.Batcher.
    run_continuous` does automatically).
    """

    def __init__(self, cfg: ArchConfig, params, gcfg: GenerateConfig, *,
                 slots: int = 8, cache_dtype=jnp.bfloat16,
                 segment: int = 8, max_prompt_len: Optional[int] = None):
        if cfg.abs_pos_embed or cfg.is_encoder_decoder or \
                cfg.vision_patches:
            raise ValueError(
                "continuous batching needs per-sequence positions; "
                "absolute position embeddings, encoder-decoder and "
                "vision-prefix models are round-based only")
        if segment < 1:
            raise ValueError(f"segment must be >= 1; got {segment}")
        self.cfg, self.params, self.gcfg = cfg, params, gcfg
        self.slots, self.cache_dtype = slots, cache_dtype
        self.segment = segment
        self.max_prompt_len = max_prompt_len
        self._bound = False
        self._segment_fn = jax.jit(self._segment_impl,
                                   donate_argnums=(1, 2, 3, 4, 5, 6, 7))
        self._prefill_fn = jax.jit(self._prefill_impl,
                                   donate_argnums=(1, 2, 3, 4, 5, 6, 7))
        # deadline eviction with an empty queue: retire the slot in
        # place (same compilation for every eviction, done donated)
        self._retire_fn = jax.jit(
            lambda done, idx: done.at[idx].set(True),
            donate_argnums=(0,))
        self.stats = {"requests": 0, "segments": 0, "prefills": 0,
                      "emitted": 0, "segment_traces": 0,
                      "prefill_traces": 0, "slot_steps": 0,
                      "idle_slot_steps": 0, "evicted": 0, "shed": 0}

    # -- static geometry (first run binds the shapes) --------------------
    def _bind(self, prompt_len: int):
        B, cap = self.slots, self.gcfg.max_new_tokens
        self._S0 = prompt_len                   # slot (max) prompt width
        self._max_seq = prompt_len + cap
        self._caches = T.init_cache(self.cfg, B, self._max_seq,
                                    self.cache_dtype)
        self._out = jnp.zeros((B, cap), jnp.int32)
        self._done = jnp.ones((B,), bool)
        self._t = jnp.ones((B,), jnp.int32)     # tokens generated
        self._budget = jnp.ones((B,), jnp.int32)
        self._keys = jnp.zeros((B, 2), jnp.uint32)
        self._plen = jnp.full((B,), prompt_len, jnp.int32)
        self._bound = True

    def _sample(self, logits, key):
        if self.gcfg.temperature > 0:
            return jax.random.categorical(
                key, logits / self.gcfg.temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    # -- slot prefill: hand a finished slot to the next request ----------
    def _prefill_impl(self, params, caches, out, done, t, budget, keys,
                      plens, idx, prompt, plen, bud, key):
        """Admit one request into slot ``idx`` (dynamic): prefill its
        RIGHT-PADDED prompt into a fresh single-sequence cache under the
        ``plen`` prompt-length mask, write that cache over the slot (one
        whole-slot dynamic_update_slice per leaf — this is the slot
        hand-off, and it evicts the previous occupant's stale keys
        wholesale), sample the first token at the prompt's own last REAL
        row, and re-arm the slot's carry.  One compilation serves every
        admission — the padded prompt width is the bound
        ``max_prompt_len``, whatever the request's true length."""
        self.stats["prefill_traces"] += 1       # traced once per stream
        fresh = T.init_cache(self.cfg, 1, self._max_seq, self.cache_dtype)
        logits, fresh = T.step_with_cache(self.cfg, params, fresh,
                                          prompt[None], 0,
                                          prompt_len=plen[None])
        last = jax.lax.dynamic_index_in_dim(logits[0], plen - 1, axis=0,
                                            keepdims=True)     # (1, V)
        first = self._sample(last, key)[0]

        def slot_write(axis):
            return lambda b, f: jax.lax.dynamic_update_slice_in_dim(
                b, f.astype(b.dtype), idx, axis=axis)
        caches = {"prefix": jax.tree.map(slot_write(0), caches["prefix"],
                                         fresh["prefix"]),
                  "unit": jax.tree.map(slot_write(1), caches["unit"],
                                       fresh["unit"])}
        out = out.at[idx].set(0).at[idx, 0].set(first.astype(jnp.int32))
        done = done.at[idx].set(
            jnp.logical_or(first == self.gcfg.eos_id, bud <= 1))
        t = t.at[idx].set(1)
        budget = budget.at[idx].set(bud)
        keys = keys.at[idx].set(key)
        plens = plens.at[idx].set(plen)
        return caches, out, done, t, budget, keys, plens

    # -- one bounded decode segment --------------------------------------
    def _segment_impl(self, params, caches, out, done, t, budget, keys,
                      plens):
        """Advance every live slot up to ``segment`` decode steps,
        returning as soon as any sequence newly finishes (EOS or its own
        token budget).  Per-sequence positions: slot b reads its last
        token at out[b, t_b-1] and writes the cache at plen_b + t_b - 1
        (each slot decodes from its OWN prompt depth — ragged prompts
        share the pool)."""
        self.stats["segment_traces"] += 1       # traced once per stream
        from repro.core.pattern import segmented_while

        B, cap = self.slots, self.gcfg.max_new_tokens
        eos = self.gcfg.eos_id

        def body(carry):
            caches, out, done, t, keys = carry
            live = jnp.logical_not(done)
            tok = jnp.take_along_axis(out, (t - 1)[:, None], axis=1)
            pos = (plens + t - 1)[:, None]               # (B, 1)
            logits, caches = T.decode_step(self.cfg, params, caches,
                                           tok, pos)
            if self.gcfg.temperature > 0:
                nk = jax.vmap(jax.random.split)(keys)    # (B, 2, 2)
                keys = jnp.where(live[:, None], nk[:, 0], keys)
                nxt = jax.vmap(
                    lambda lg, kk: self._sample(lg, kk))(logits[:, 0],
                                                         nk[:, 1])
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)
            nxt = jnp.where(live, nxt, jnp.full_like(nxt, eos))
            tw = jnp.minimum(t, cap - 1)
            row = jnp.arange(B)
            out = out.at[row, tw].set(
                jnp.where(live, nxt.astype(jnp.int32), out[row, tw]))
            t = jnp.where(live, t + 1, t)
            done = jnp.logical_or(
                done, jnp.logical_and(
                    live, jnp.logical_or(nxt == eos, t >= budget)))
            return caches, out, done, t, keys

        (caches, out, done, t, keys), steps = segmented_while(
            body, (caches, out, done, t, keys),
            finished=lambda c: c[2], segment=self.segment)
        return caches, out, done, t, budget, keys, plens, steps

    # -- the dispatcher ---------------------------------------------------
    def run(self, requests, emit, *, clock=None) -> int:
        """Serve ``requests`` (RAGGED prompt lengths and wildly
        different ``.max_new_tokens`` welcome) through the slots,
        calling ``emit(rid, tokens, status)`` the moment each finishes —
        completion order, mid-batch.  Returns the number of emissions.

        A request may carry an absolute ``.deadline`` (on ``clock``'s
        timeline; default ``time.monotonic`` — tests inject a fake
        clock for determinism).  A request whose deadline has already
        passed at admission is SHED: emitted immediately with
        ``status="timed_out"`` and no tokens, never touching a slot
        (``stats["shed"]``).  A slot whose occupant's deadline passes
        mid-decode is EVICTED after the current segment: its partial
        tokens emit with ``status="timed_out"`` and the KV slot is
        freed for the next queued request through the ordinary refill
        path — or retired in place when the queue is empty
        (``stats["evicted"]``).  No deadline → the request always runs
        to EOS or budget (``status="ok"``).
        """
        clock = time.monotonic if clock is None else clock
        queue = list(requests)
        if not queue:
            return 0
        cap = self.gcfg.max_new_tokens
        lens = [len(r.prompt) for r in queue]
        bound = (self._S0 if self._bound
                 else (self.max_prompt_len or max(lens)))
        for r, L in zip(queue, lens):
            if not 1 <= L <= bound:
                raise ValueError(
                    f"prompt length {L} outside [1, max_prompt_len="
                    f"{bound}] (the slot pool's bound prompt width; "
                    "build the engine with a larger max_prompt_len)")
            request_budget(r, cap)
        if any(L != bound for L in lens) and _arch_has_ssm(self.cfg):
            raise ValueError(
                "ragged prompts need an attention-only stack (an SSM "
                "layer's state update is sequential — a pad token would "
                "corrupt it); group requests by exact prompt length "
                "upstream, as Batcher.run_continuous does for SSM archs")
        if not self._bound:
            self._bind(bound)
        queue = queue[::-1]                     # pop() = FIFO order
        caches, out, done = self._caches, self._out, self._done
        t, budget, keys = self._t, self._budget, self._keys
        plens = self._plen
        occupants = [None] * self.slots
        base_key = jax.random.PRNGKey(self.gcfg.seed)
        n_emit = 0
        prev_t = np.asarray(t).astype(np.int64)

        def deadline_of(req):
            return getattr(req, "deadline", None)

        def pull():
            """Next admissible request — requests already past their
            deadline are shed here, without ever touching a slot."""
            nonlocal n_emit
            while queue:
                req = queue.pop()
                dl = deadline_of(req)
                if dl is not None and clock() >= dl:
                    emit(req.rid, np.zeros((0,), np.int32), "timed_out")
                    n_emit += 1
                    self.stats["shed"] += 1
                    self.stats["requests"] += 1
                    continue
                return req
            return None

        def admit(slot, req):
            nonlocal caches, out, done, t, budget, keys, plens
            bud = request_budget(req, cap)
            ptoks = np.asarray(req.prompt, np.int32)
            prompt = np.zeros((self._S0,), np.int32)    # right-padded
            prompt[:len(ptoks)] = ptoks
            key = jax.random.fold_in(base_key, self.stats["prefills"])
            (caches, out, done, t, budget, keys,
             plens) = self._prefill_fn(
                self.params, caches, out, done, t, budget, keys, plens,
                jnp.asarray(slot, jnp.int32), jnp.asarray(prompt),
                jnp.asarray(len(ptoks), jnp.int32),
                jnp.asarray(bud, jnp.int32), key)
            occupants[slot] = req
            prev_t[slot] = 1            # the prefilled first token is
                                        # not a segment step
            self.stats["prefills"] += 1
            self.stats["requests"] += 1

        try:
            for slot in range(self.slots):
                req = pull()
                if req is None:
                    break
                admit(slot, req)

            while any(o is not None for o in occupants):
                (caches, out, done, t, budget, keys, plens,
                 steps) = self._segment_fn(self.params, caches, out,
                                           done, t, budget, keys, plens)
                self.stats["segments"] += 1
                done_h = np.asarray(done)
                t_h = np.asarray(t).astype(np.int64)
                out_h = np.asarray(out)
                # idle-slot accounting (the wasted_lane_steps analogue):
                # each body step advances every LIVE slot one token;
                # retired/done-masked slots burn the step
                steps_h = int(steps)
                useful = int((t_h - prev_t).sum())
                self.stats["slot_steps"] += steps_h * self.slots
                self.stats["idle_slot_steps"] += \
                    steps_h * self.slots - useful
                prev_t = t_h.copy()
                now = clock()
                for slot in range(self.slots):
                    req = occupants[slot]
                    if req is None:
                        continue
                    if done_h[slot]:
                        emit(req.rid, out_h[slot, :int(t_h[slot])].copy(),
                             "ok")
                        n_emit += 1
                        self.stats["emitted"] += 1
                        occupants[slot] = None
                        nxt = pull()
                        if nxt is not None:
                            admit(slot, nxt)
                        continue
                    dl = deadline_of(req)
                    if dl is not None and now >= dl:
                        # deadline eviction: the partial output emits
                        # now and the KV slot is freed mid-batch — the
                        # next request prefills over it (the ordinary
                        # refill path evicts the stale keys wholesale),
                        # or the slot retires in place
                        emit(req.rid, out_h[slot, :int(t_h[slot])].copy(),
                             "timed_out")
                        n_emit += 1
                        self.stats["evicted"] += 1
                        occupants[slot] = None
                        nxt = pull()
                        if nxt is not None:
                            admit(slot, nxt)
                        else:
                            done = self._retire_fn(
                                done, jnp.asarray(slot, jnp.int32))
        finally:
            # locals always name the LIVE buffers (the donated inputs
            # were consumed by the calls that produced these), so a
            # raising emit callback cannot strand the engine on deleted
            # device buffers
            self._caches, self._out, self._done = caches, out, done
            self._t, self._budget, self._keys = t, budget, keys
            self._plen = plens
        return n_emit
