from .engine import GenerateConfig, generate, prefill

__all__ = ["GenerateConfig", "generate", "prefill"]
