from .engine import ContinuousEngine, GenerateConfig, generate, prefill

__all__ = ["ContinuousEngine", "GenerateConfig", "generate", "prefill"]
