"""Request batching for the serving engine (the stream tier, 1:1 mode).

Host-side dynamic batcher: requests arrive with ragged prompts; the
batcher groups them by EXACT prompt length (no padding enters the
attention window — pad tokens in the causal past would corrupt the
shorter prompts), forms FIFO batches up to ``max_batch`` per group, and
drives each batch through ONE fused generate loop (prefill +
Loop-of-stencil-reduce-s decode).

This is the paper's farm over stream items at serving scale: every
batch is an independent stream item for the device; done-masked decode
lets requests inside a batch finish at their own lengths.  The drain
loop uses the stream tier's host-side double buffering (the
:class:`repro.core.streaming.FarmEngine` protocol): batch i+1 is
dispatched asynchronously before batch i's tokens are pulled to the
host, so tokenisation/detokenisation overlaps device decode.  Length
bucketing with proper pad masking is the next step and is noted in
DESIGN.md; exact grouping keeps the compile cache small when clients
quantise prompt lengths themselves.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .engine import GenerateConfig, generate


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: Optional[int] = None   # per-request budget; None =
                                           # the engine's gcfg cap


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray           # (n_generated,) int32


class Batcher:
    """FIFO exact-length-grouped batcher over the generate engine."""

    def __init__(self, cfg: ArchConfig, params, gcfg: GenerateConfig, *,
                 max_batch: int = 8, cache_dtype=jnp.float32):
        self.cfg, self.params, self.gcfg = cfg, params, gcfg
        self.max_batch = max_batch
        self.cache_dtype = cache_dtype
        self._queue: List[Request] = []

    def submit(self, req: Request):
        self._queue.append(req)

    def _form_batch(self) -> Optional[List[Request]]:
        if not self._queue:
            return None
        L = len(self._queue[0].prompt)      # FIFO head sets the group
        batch, rest = [], []
        for r in self._queue:
            if len(batch) < self.max_batch and len(r.prompt) == L:
                batch.append(r)
            else:
                rest.append(r)
        self._queue = rest
        return batch

    def _dispatch(self, batch: List[Request]):
        """Launch one batch's generate loop (async dispatch — returns
        device futures, no host sync)."""
        toks = np.stack([r.prompt for r in batch]).astype(np.int32)
        gen, lengths, _ = generate(
            self.cfg, self.params, jnp.asarray(toks), self.gcfg,
            cache_dtype=self.cache_dtype)
        return batch, gen, lengths

    @staticmethod
    def _drain(inflight, out: List[Result]):
        batch, gen, lengths = inflight
        gen = np.asarray(gen)                # blocks on this batch only
        for i, r in enumerate(batch):
            out.append(Result(rid=r.rid, tokens=gen[i, :int(lengths[i])]))

    def run_all(self) -> List[Result]:
        """Drain the queue; returns results in completion order.

        Double-buffered: while the device decodes batch i, the host
        forms and dispatches batch i+1 and drains batch i-1's tokens —
        the stream tier's read ∥ compute ∥ write overlap.
        """
        out: List[Result] = []
        inflight = None
        while True:
            batch = self._form_batch()
            nxt = self._dispatch(batch) if batch else None
            if inflight is not None:
                self._drain(inflight, out)
            inflight = nxt
            if not batch:
                break
        if inflight is not None:
            self._drain(inflight, out)
        return out

    def run_continuous(self) -> List[Result]:
        """Drain the queue with continuous batching (per-sequence KV-slot
        refill, :class:`repro.serve.engine.ContinuousEngine`).

        Requests still group by EXACT prompt length (the no-pad
        contract), but within a group the whole queue streams through
        ``max_batch`` persistent slots: a finished sequence's result is
        emitted mid-batch — before the longest sequence of its cohort
        completes — and its KV slot is immediately prefilled with the
        next queued request.  Results arrive in completion order.  The
        engines used are kept on ``self.engines`` (one per prompt-length
        group) so callers can inspect ``stats`` — e.g. that segment and
        prefill trace counts stayed at 1.
        """
        from .engine import ContinuousEngine

        out: List[Result] = []
        self.engines: List[ContinuousEngine] = []
        while self._queue:
            L = len(self._queue[0].prompt)      # FIFO head sets the group
            group = [r for r in self._queue if len(r.prompt) == L]
            self._queue = [r for r in self._queue if len(r.prompt) != L]
            eng = ContinuousEngine(
                self.cfg, self.params, self.gcfg, slots=self.max_batch,
                cache_dtype=self.cache_dtype)
            eng.run(group, lambda rid, toks: out.append(
                Result(rid=rid, tokens=toks)))
            self.engines.append(eng)
        return out
