"""Request batching for the serving engine (the stream tier, 1:1 mode).

Host-side dynamic batcher.  The ROUND path (:meth:`Batcher.run_all`)
groups ragged prompts by EXACT length (no padding enters the attention
window), forms FIFO batches up to ``max_batch`` per group, and drives
each batch through ONE fused generate loop (prefill +
Loop-of-stencil-reduce-s decode) with per-request ``max_new_tokens``
budgets threaded into the done-mask.

The CONTINUOUS path (:meth:`Batcher.run_continuous`) admits the WHOLE
ragged queue into one :class:`repro.serve.engine.ContinuousEngine` slot
pool bound at the queue's ``max_prompt_len`` — padded per-slot prefill
with a prompt-length mask (DESIGN.md §Serve), results emitted mid-batch
in completion order, ``stats["idle_slot_steps"]`` strictly below the
old one-engine-per-length-group scheme (which idled a whole cohort at
every group tail).  SSM/hybrid archs fall back to exact-length grouping
automatically (their state updates have no pad-masking path).

This is the paper's farm over stream items at serving scale: every
batch is an independent stream item for the device; done-masked decode
lets requests inside a batch finish at their own lengths.  The drain
loop uses the stream tier's host-side double buffering (the
:class:`repro.core.streaming.FarmEngine` protocol): batch i+1 is
dispatched asynchronously before batch i's tokens are pulled to the
host, so tokenisation/detokenisation overlaps device decode.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .engine import GenerateConfig, generate

_EMPTY = np.zeros((0,), np.int32)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: Optional[int] = None   # per-request budget; None =
                                           # the engine's gcfg cap
    deadline: Optional[float] = None       # absolute, on the batcher's
                                           # clock; None = no deadline


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray           # (n_generated,) int32
    status: str = "ok"           # ok | timed_out | shed | failed
    error: Optional[str] = None  # why, for non-ok statuses


class Batcher:
    """FIFO exact-length-grouped batcher over the generate engine.

    Admission control (DESIGN.md §Failure semantics): ``max_queue``
    bounds the submit queue — past it, :meth:`submit` SHEDS the request
    (returns a ``status="shed"`` :class:`Result` instead of ``None``)
    rather than queueing unbounded work.  With ``est_service_time`` set
    (seconds per dispatched batch), a deadline-carrying request whose
    PROJECTED queue delay already exceeds its deadline is shed at
    submit too — load shedding at the door beats eviction after the
    prefill is spent.  ``stats`` counts both shed reasons plus
    downstream failures/evictions for backpressure monitoring.
    """

    def __init__(self, cfg: ArchConfig, params, gcfg: GenerateConfig, *,
                 max_batch: int = 8, cache_dtype=jnp.float32,
                 max_queue: Optional[int] = None,
                 est_service_time: Optional[float] = None, clock=None):
        self.cfg, self.params, self.gcfg = cfg, params, gcfg
        self.max_batch = max_batch
        self.cache_dtype = cache_dtype
        self.max_queue = max_queue
        self.est_service_time = est_service_time
        self.clock = time.monotonic if clock is None else clock
        self._queue: List[Request] = []
        self.stats = {"submitted": 0, "accepted": 0,
                      "shed_queue_full": 0, "shed_deadline": 0,
                      "failed": 0, "evicted": 0, "shed": 0}

    def submit(self, req: Request) -> Optional[Result]:
        """Admit one request, or shed it with a reason.

        Returns ``None`` on acceptance; on rejection, a terminal
        ``status="shed"`` :class:`Result` whose ``error`` names the
        reason (queue full / projected delay exceeds the deadline) —
        the caller answers the client immediately instead of queueing
        work that cannot finish in time.
        """
        self.stats["submitted"] += 1
        if self.max_queue is not None and len(self._queue) >= \
                self.max_queue:
            self.stats["shed_queue_full"] += 1
            return Result(rid=req.rid, tokens=_EMPTY, status="shed",
                          error=f"admission queue full "
                                f"(max_queue={self.max_queue})")
        dl = getattr(req, "deadline", None)
        if dl is not None and self.est_service_time is not None:
            waves = len(self._queue) // self.max_batch + 1
            projected = self.clock() + waves * self.est_service_time
            if projected > dl:
                self.stats["shed_deadline"] += 1
                return Result(
                    rid=req.rid, tokens=_EMPTY, status="shed",
                    error=f"projected completion {projected:.3f} past "
                          f"deadline {dl:.3f} "
                          f"({waves} queued batch waves ahead)")
        self._queue.append(req)
        self.stats["accepted"] += 1
        return None

    def _form_batch(self) -> Optional[List[Request]]:
        if not self._queue:
            return None
        L = len(self._queue[0].prompt)      # FIFO head sets the group
        batch, rest = [], []
        for r in self._queue:
            if len(batch) < self.max_batch and len(r.prompt) == L:
                batch.append(r)
            else:
                rest.append(r)
        self._queue = rest
        return batch

    def _dispatch(self, batch: List[Request]):
        """Launch one batch's generate loop (async dispatch — returns
        device futures, no host sync).  Per-request ``max_new_tokens``
        budgets ride the done-mask through the SAME validation rule as
        the continuous engine's (`engine.request_budget` — their parity
        is regression-tested)."""
        from .engine import request_budget

        cap = self.gcfg.max_new_tokens
        toks = np.stack([r.prompt for r in batch]).astype(np.int32)
        budgets = np.asarray([request_budget(r, cap) for r in batch],
                             np.int32)
        gen, lengths, _ = generate(
            self.cfg, self.params, jnp.asarray(toks), self.gcfg,
            cache_dtype=self.cache_dtype, budgets=jnp.asarray(budgets))
        return batch, gen, lengths

    @staticmethod
    def _drain(inflight, out: List[Result]):
        batch, gen, lengths = inflight
        # ONE device→host pull per array per batch (this is where the
        # host blocks on the in-flight round) — indexing the
        # device-resident ``lengths`` element-by-element would issue one
        # blocking transfer per request
        try:
            gen = np.asarray(gen)
            lengths = np.asarray(lengths)
        except Exception as e:               # noqa: BLE001 — a poisoned
            # batch (device fault, NaN trap, cancelled buffer) must
            # degrade to per-request failed Results, not lose every
            # in-flight result of the stream
            for r in batch:
                out.append(Result(rid=r.rid, tokens=_EMPTY,
                                  status="failed", error=str(e)))
            return
        for i, r in enumerate(batch):
            out.append(Result(rid=r.rid, tokens=gen[i, :int(lengths[i])]))

    def run_all(self) -> List[Result]:
        """Drain the queue; returns results in completion order.

        Double-buffered: while the device decodes batch i, the host
        forms and dispatches batch i+1 and drains batch i-1's tokens —
        the stream tier's read ∥ compute ∥ write overlap.
        """
        out: List[Result] = []
        inflight = None
        while True:
            batch = self._form_batch()
            nxt = self._dispatch(batch) if batch else None
            if inflight is not None:
                self._drain(inflight, out)
            inflight = nxt
            if not batch:
                break
        if inflight is not None:
            self._drain(inflight, out)
        self.stats["failed"] += sum(r.status == "failed" for r in out)
        return out

    def run_continuous(self, exact_groups: Optional[bool] = None, *,
                       recovery=None, resume: bool = False,
                       on_segment=None,
                       chained: bool = False) -> List[Result]:
        """Drain the queue with continuous batching (per-sequence KV-slot
        refill, :class:`repro.serve.engine.ContinuousEngine`).

        The WHOLE ragged queue streams through ONE engine binding at the
        queue's max prompt length: each request is admitted by a padded
        per-slot prefill under its own prompt-length mask, a finished
        sequence's result is emitted mid-batch — before the longest
        sequence of its cohort completes — and its KV slot is
        immediately prefilled with the next queued request, whatever its
        length.  Results arrive in completion order.  The engine(s) used
        are kept on ``self.engines`` so callers can inspect ``stats`` —
        e.g. that segment and prefill trace counts stayed at 1, or the
        ``idle_slot_steps`` the single pool saves.

        ``exact_groups=True`` restores the old one-engine-per-exact-
        prompt-length scheme (each group idles its whole cohort at the
        group tail — kept as the measurable baseline for the
        ``idle_slot_steps`` comparison, and the automatic fallback for
        SSM/hybrid archs, whose sequential state updates have no
        pad-masking path).

        ``chained=True`` passes through to :meth:`ContinuousEngine.run`:
        each engine runs its group on the chained dispatch pipeline
        (segment t+1 in flight before segment t's metadata is read —
        see the engine docstring for the admission-lag trade).

        ``recovery=`` / ``resume=`` / ``on_segment=`` pass through to
        :meth:`ContinuousEngine.run` (single-pool path only — an exact
        group's engine identity is derived from the queue, which a
        snapshot cannot pin): a killed drain resumes exactly-once, with
        pre-crash emissions replayed from the journal and in-flight
        decodes continuing mid-generation, even on a different
        ``max_batch``.  On resume the submitted queue may be EMPTY —
        the engine re-binds from the snapshot's prompt width and picks
        up the snapshotted requests.
        """
        from .engine import ContinuousEngine, _arch_has_ssm

        out: List[Result] = []
        self.engines: List[ContinuousEngine] = []
        if exact_groups and recovery is not None:
            raise ValueError(
                "recovery= needs the single-pool path (exact_groups "
                "slices the queue into per-length engines — a snapshot "
                "cannot name which engine it belongs to)")
        if not self._queue and not (recovery is not None and resume):
            return out
        if exact_groups is None:
            exact_groups = (False if recovery is not None
                            else _arch_has_ssm(self.cfg))

        def serve(eng, group):
            """Drive one engine over one group, degrading a mid-stream
            exception to per-request failed Results instead of losing
            every in-flight result (results already emitted before the
            fault survive on ``out`` untouched)."""
            emitted = set()

            def sink(rid, toks, status):
                emitted.add(rid)
                out.append(Result(
                    rid=rid, tokens=toks, status=status,
                    error=None if status == "ok"
                    else f"engine status {status}"))

            try:
                eng.run(group, sink, clock=self.clock,
                        recovery=recovery, resume=resume,
                        on_segment=on_segment, chained=chained)
            except Exception as e:           # noqa: BLE001 — degrade
                survivors = [r for r in group if r.rid not in emitted]
                if not survivors:
                    # nothing to degrade INTO a failed Result (e.g. a
                    # resume with an empty submitted queue hitting a
                    # snapshot-validation error) — swallowing here
                    # would hide the fault entirely
                    self.engines.append(eng)
                    raise
                for r in survivors:
                    out.append(Result(rid=r.rid, tokens=_EMPTY,
                                      status="failed",
                                      error=str(e)))
                    self.stats["failed"] += 1
            self.stats["evicted"] += eng.stats["evicted"]
            self.stats["shed"] += eng.stats["shed"]
            self.engines.append(eng)

        if not exact_groups:
            # on resume the snapshot's prompt width wins (None lets the
            # engine bind from it; new prompts must fit within it)
            maxL = (max(len(r.prompt) for r in self._queue)
                    if self._queue and not resume else None)
            # construct BEFORE emptying the queue: an unsupported cfg
            # (abs-pos/enc-dec/vision) raises here and the submitted
            # requests stay queued for run_all()/exact groups
            eng = ContinuousEngine(
                self.cfg, self.params, self.gcfg, slots=self.max_batch,
                cache_dtype=self.cache_dtype, max_prompt_len=maxL)
            queue, self._queue = self._queue, []
            serve(eng, queue)
            return out
        while self._queue:
            L = len(self._queue[0].prompt)      # FIFO head sets the group
            group = [r for r in self._queue if len(r.prompt) == L]
            self._queue = [r for r in self._queue if len(r.prompt) != L]
            eng = ContinuousEngine(
                self.cfg, self.params, self.gcfg, slots=self.max_batch,
                cache_dtype=self.cache_dtype)
            serve(eng, group)
        return out
