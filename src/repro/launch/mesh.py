"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run driver sets XLA_FLAGS before any jax initialisation).

Topology (TPU v5e-class):
    single pod : (16, 16)     axes ("data", "model")   = 256 chips
    multi-pod  : (2, 16, 16)  axes ("pod", "data", "model") = 512 chips

The "model" axis is mapped innermost so tensor-parallel collectives stay
on the shortest ICI rings; the "pod" axis carries only the gradient
all-reduce (data-parallel across pods, over the slow inter-pod links).

Meshes are built through :func:`repro.sharding.specs.make_mesh`, the
version-portable shim (jax 0.4.x has no ``axis_types=`` kwarg).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.sharding.specs import make_mesh


def make_production_mesh(*, multi_pod: bool = False,
                         pod_shape: tuple[int, int] | None = None) -> Mesh:
    """``pod_shape`` overrides the (data, model) factorisation of the 256
    chips in a pod — the TP:DP trade is a first-class tuning knob (the
    §Perf hillclimb shows collective-bound dense models want less TP)."""
    dm = pod_shape or (16, 16)
    assert dm[0] * dm[1] == 256, "a pod is 256 chips"
    shape = (2, *dm) if multi_pod else dm
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (fake) devices the test process has."""
    return make_mesh((data, model), ("data", "model"))
