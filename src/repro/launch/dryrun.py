import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^^ MUST precede every other import: jax locks the device count at first
# initialisation.  The dry-run (and only the dry-run) builds the
# production meshes out of 512 host placeholder devices.

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
    jit(step).lower(**ShapeDtypeStructs)  →  .compile()
    → memory_analysis()                      (proves it fits)
    → cost_analysis() + HLO analyzer         (FLOPs / bytes / collectives,
                                              while-trip-corrected)
    → roofline terms                         (EXPERIMENTS.md §Roofline)

Artifacts: one JSON per cell under --out (incremental: finished cells are
skipped on re-run, so the 70+-compile sweep is restartable — the same
fault-tolerance posture the trainer has).

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all [--mesh both] [--out runs/dryrun]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             force: bool = False, verbose: bool = True,
             pod_shape=None, remat_policy=None,
             cache_quant: bool = False) -> dict:
    from repro.configs import get_config
    from repro.launch import cells as C
    from repro.launch import hlo_analysis as HA
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import Roofline

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}"
    if pod_shape:
        tag += f"_{pod_shape[0]}x{pod_shape[1]}"
    if remat_policy:
        tag += f"_{remat_policy}"
    if cache_quant:
        tag += "_int8kv"
    tag = tag.replace("/", "_")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    if remat_policy:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"),
                                pod_shape=pod_shape)
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "chips": chips,
           "ok": False}
    t0 = time.time()
    try:
        reason = C.skip_reason(cfg, shape)
        if reason:
            rec.update(skipped=True, reason=reason, ok=True)
            _write(path, rec)
            if verbose:
                print(f"[dryrun] {tag}: SKIP ({reason.split(':')[0]})")
            return rec

        kw = {"cache_quant": True} if (
            cache_quant and C.SHAPES[shape].kind == "decode") else {}
        jfn, args, meta = C.build_cell(cfg, shape, mesh, **kw)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        # ---- memory analysis (proves fit) -------------------------------
        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
        except Exception as e:        # pragma: no cover
            mem["error"] = str(e)
        # analytic per-device argument bytes from the shardings (exact)
        arg_bytes = _sharded_arg_bytes(args, mesh)
        mem["analytic_args_bytes_per_device"] = int(arg_bytes)

        # ---- cost analysis ----------------------------------------------
        ca = {}
        try:
            d = compiled.cost_analysis()
            ca = {k: float(d[k]) for k in ("flops", "bytes accessed")
                  if k in d}
        except Exception as e:        # pragma: no cover
            ca = {"error": str(e)}

        hlo = compiled.as_text()
        costs = HA.analyze(hlo, n_partitions=chips)
        model_fl = C.model_flops(cfg, shape, args[0])
        rf = Roofline(
            arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
            flops_per_device=costs.flops,
            bytes_per_device=costs.bytes_accessed,
            collective_bytes_per_device=costs.collective_bytes,
            model_flops_global=model_fl).finalize()

        rec.update(
            ok=True, skipped=False, meta=meta,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=mem, xla_cost=ca,
            analyzer={
                "flops_per_device": costs.flops,
                "bytes_per_device": costs.bytes_accessed,
                "collective_bytes_per_device": costs.collective_bytes,
                "per_collective": dict(costs.per_collective),
                "collective_count": dict(costs.collective_count),
                "trip_counts": dict(costs.trip_counts),
            },
            model_flops=model_fl,
            params=C.count_params(args[0]),
            active_params=C.active_params(cfg, args[0]),
            roofline=rf.asdict(),
            hlo_bytes=len(hlo),
        )
        if verbose:
            print(f"[dryrun] {tag}: OK compile={t_compile:.0f}s "
                  f"{rf.row()}", flush=True)
    except Exception as e:
        rec.update(error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {tag}: FAIL {type(e).__name__}: "
                  f"{str(e)[:300]}", flush=True)
    _write(path, rec)
    return rec


def _sharded_arg_bytes(args, mesh) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(args):
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        bts = n * leaf.dtype.itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "spec"):
            denom = 1
            for entry in sh.spec:
                for ax in ((entry,) if isinstance(entry, str)
                           else (entry or ())):
                    denom *= mesh.shape[ax]
            bts /= denom
        total += bts
    return total


def _write(path, rec):
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main(argv=None):
    from repro.configs import ALL_ARCHS
    from repro.launch.cells import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--pod-shape", default=None,
                    help="override (data,model) factorisation, e.g. 32,8")
    ap.add_argument("--remat-policy", default=None,
                    choices=["full", "block_outs"])
    ap.add_argument("--cache-int8", action="store_true",
                    help="int8-quantised KV caches for decode cells")
    args = ap.parse_args(argv)
    pod_shape = (tuple(int(x) for x in args.pod_shape.split(","))
                 if args.pod_shape else None)

    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    results = []
    for mk in meshes:
        for arch in archs:
            for shape in shapes:
                results.append(run_cell(arch, shape, mk, args.out,
                                        force=args.force,
                                        pod_shape=pod_shape,
                                        remat_policy=args.remat_policy,
                                        cache_quant=args.cache_int8))
    ok = sum(1 for r in results if r.get("ok"))
    skipped = sum(1 for r in results if r.get("skipped"))
    print(f"[dryrun] {ok}/{len(results)} ok ({skipped} documented skips), "
          f"{len(results) - ok} failed")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
