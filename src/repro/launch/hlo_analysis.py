"""Static HLO analyzer: FLOPs / bytes / collective traffic with correct
while-loop trip multipliers.

Why not ``compiled.cost_analysis()`` alone?  XLA's cost analysis visits a
while body ONCE, so anything under ``lax.scan`` (all our models scan their
layer stack; grad-accum scans microbatches) is undercounted by the trip
count.  This analyzer parses the optimized HLO text, builds the
computation call graph, extracts counted-loop trip counts from the loop
condition's comparison constant, and multiplies every instruction's cost
by the product of enclosing trip counts.

Costs extracted per instruction:
    dot            2 · |output| · contracted_size        (FLOPs)
    collectives    wire bytes with ring-algorithm factors per op type and
                   the replica-group size parsed from the op
    fusion/dot/... boundary bytes (operands + output) for the memory term
                   (matches XLA's own "bytes accessed" convention)

Validated against cost_analysis on loop-free modules (tests) and against
analytic 6·N·D for the LM cells (EXPERIMENTS.md table column).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:body|condition|to_apply|calls)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_shapes(type_str: str):
    """All (dtype, dims) tensors in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dt, shape))
    return out


def tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, shape in parse_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    params: Dict[str, str]          # param name -> type string
    symbols: Dict[str, str]         # instr name -> output type string


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    header = re.compile(
        r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
    comment = re.compile(r"/\*.*?\*/")
    for raw in hlo.splitlines():
        line = comment.sub("", raw.rstrip())
        h = header.match(line)
        if h and ("=" not in line.split("(")[0]):
            params = {}
            for pm in re.finditer(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                  h.group(2)):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(h.group(1), [], params, dict(params))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # "TYPE op(...)" — op is the first word after the type annotation
        om = re.match(r"((?:\([^=]*?\))|(?:[\w\[\],{}]+))\s+([\w\-]+)\(",
                      rest)
        if om:
            out_type, op = om.group(1), om.group(2)
        else:
            out_type, op = rest, "constant"
        cur.instrs.append(Instr(name, op, out_type, line))
        cur.symbols[name] = out_type
    return comps


def operand_names(argstr: str) -> List[str]:
    """Operand instruction names from an HLO call-site argument string.

    Two textual conventions exist: newer XLA prints bare names
    (``dot(lhs, rhs)``), older XLA (jax 0.4.x) prints inline types with
    %-prefixed names (``dot(f32[32,128]{1,0} %lhs, ...)``) — a naive
    comma split lands inside the shape brackets there.  With ``%``
    markers present, the names ARE the markers; otherwise fall back to
    the comma split.
    """
    if "%" in argstr:
        return re.findall(r"%([\w.\-]+)", argstr)
    return [tok.strip() for tok in argstr.split(",") if tok.strip()]


def loop_trip_count(cond: Computation) -> int:
    """Counted loops compare the induction var against a constant; take the
    largest scalar integer constant in the condition computation."""
    best = 1
    for ins in cond.instrs:
        cm = re.search(r"constant\((\d+)\)", ins.line)
        if cm and re.match(r"[su]\d+\[\]", ins.out_type.strip("%( ")):
            best = max(best, int(cm.group(1)))
        elif cm and ins.op == "constant":
            best = max(best, int(cm.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out = parse_shapes(ins.out_type)
    if not out:
        return 0.0
    out_elems = 1
    for d in out[0][1]:
        out_elems *= d
    # contracted size from the lhs operand's shape
    ops = re.search(r"\bdot\(([^)]*)\)", ins.line)
    lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not ops or not lm:
        return 2.0 * out_elems      # degenerate
    names = operand_names(ops.group(1))
    lhs_name = names[0] if names else ""
    lhs_type = comp.symbols.get(lhs_name, "")
    lhs_shapes = parse_shapes(lhs_type)
    if not lhs_shapes:
        return 2.0 * out_elems
    lhs_dims = lhs_shapes[0][1]
    contracted = 1
    for idx in [int(i) for i in lm.group(1).split(",") if i]:
        if idx < len(lhs_dims):
            contracted *= lhs_dims[idx]
    return 2.0 * out_elems * contracted


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split("{")[-1]
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


def _collective_wire_bytes(ins: Instr, comp: Computation,
                           n_default: int) -> float:
    """Ring-algorithm wire bytes per participating device."""
    n = max(2, _group_size(ins.line, n_default))
    out_b = tensor_bytes(ins.out_type)
    if ins.op == "all-reduce":
        return 2.0 * out_b * (n - 1) / n
    if ins.op == "all-gather":
        return out_b * (n - 1) / n
    if ins.op == "reduce-scatter":
        return out_b * (n - 1)          # input = out·n; wire = in·(n-1)/n
    if ins.op == "all-to-all":
        return out_b * (n - 1) / n
    if ins.op == "collective-permute":
        return out_b
    return 0.0


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    return sum(_operand_bytes_list(ins, comp))


def _operand_bytes_list(ins: Instr, comp: Computation):
    ops = re.search(r"[\w\-]+\((.*)\)", ins.line)
    if not ops:
        return []
    return [tensor_bytes(comp.symbols[nm])
            for nm in operand_names(ops.group(1))
            if nm in comp.symbols]


def _mem_bytes(ins: Instr, comp: Computation, comps, fusion_roots) -> float:
    """HBM traffic estimate for one top-level instruction.

    In-place-able ops are the big correction vs naive operand+output
    counting: a dynamic-update-slice in a loop writes only the slice (XLA
    aliases the buffer), and a dynamic-slice reads only the slice.  This
    matters enormously for scan-stacked caches/remat buffers.
    """
    out_b = tensor_bytes(ins.out_type)
    opnds = _operand_bytes_list(ins, comp)

    def dus_bytes(root_ins, root_comp):
        ops = _operand_bytes_list(root_ins, root_comp)
        upd = ops[1] if len(ops) > 1 else 0
        return 2.0 * upd                     # read-modify-write the slice

    if ins.op == "dynamic-update-slice":
        return dus_bytes(ins, comp)
    if ins.op == "dynamic-slice":
        return 2.0 * out_b                   # read + write the slice
    if ins.op == "fusion":
        m = re.search(r"calls=%([\w.\-]+)", ins.line)
        sub = comps.get(m.group(1)) if m else None
        if sub is not None and sub.instrs:
            return _fusion_mem_bytes(ins, sub)
    return out_b + sum(opnds)


def _fusion_mem_bytes(ins: Instr, sub: Computation) -> float:
    """Effective HBM traffic of a fusion call.

    Fusion-body params accessed only through a dynamic-slice cost the
    slice, not the full operand (scan xs!); a dynamic-update-slice root
    writes only the update (scan ys / cache write, aliased in place)."""
    # params that are sliced inside, and the slice sizes
    sliced: Dict[str, int] = {}
    dus_target = None
    dus_update = 0
    for fi in sub.instrs:
        if fi.op == "dynamic-slice":
            ops = re.search(r"dynamic-slice\(([^)]*)\)", fi.line)
            if ops:
                names = operand_names(ops.group(1))
                src = names[0] if names else ""
                if src in sub.params:
                    sliced[src] = sliced.get(src, 0) + tensor_bytes(
                        fi.out_type)
        if fi.op == "dynamic-update-slice":
            ops = re.search(r"dynamic-update-slice\(([^)]*)\)", fi.line)
            if ops:
                names = operand_names(ops.group(1))
                if names and names[0] in sub.params:
                    dus_target = names[0]
                if len(names) > 1 and names[1] in sub.symbols:
                    dus_update += tensor_bytes(sub.symbols[names[1]])
    root = sub.instrs[-1]
    root_is_dus = root.op == "dynamic-update-slice" or (
        root.op == "bitcast" and dus_target is not None)

    in_eff = 0.0
    for pname, ptype in sub.params.items():
        if pname == dus_target and root_is_dus:
            continue                          # aliased in-place buffer
        if pname in sliced:
            in_eff += sliced[pname]
        else:
            in_eff += tensor_bytes(ptype)
    out_eff = dus_update if root_is_dus else tensor_bytes(ins.out_type)
    return in_eff + out_eff


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    trip_counts: dict = dataclasses.field(default_factory=dict)


_MEM_OPS = {"fusion", "dot", "convolution", "copy", "dynamic-slice",
            "dynamic-update-slice", "scatter", "gather", "sort", "reduce",
            "broadcast", "transpose", "reshape", "concatenate", "select",
            "pad", "slice", "iota", "convert", "add", "multiply", "tanh",
            "exponential", "rsqrt", "divide", "subtract", "maximum",
            "minimum", "compare", "reduce-window", "custom-call"}


def analyze(hlo_text: str, *, n_partitions: int = 1,
            entry_hint: str = "main") -> HloCosts:
    comps = split_computations(hlo_text)
    entry = None
    for name in comps:
        if entry_hint in name:
            entry = name
            break
    if entry is None:                       # fall back: last computation
        entry = list(comps)[-1]

    # identify fusion-body computations (costs counted at call sites)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                cm = re.search(r"calls=%([\w.\-]+)", ins.line)
                if cm:
                    fusion_bodies.add(cm.group(1))

    costs = HloCosts()
    seen: Dict[str, float] = {}

    def visit(name: str, mult: float):
        if name not in comps:
            return
        # a computation may be visited from several sites; accumulate
        comp = comps[name]
        for ins in comp.instrs:
            if ins.op == "while":
                bm = re.search(r"body=%([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%([\w.\-]+)", ins.line)
                trips = 1
                if cm and cm.group(1) in comps:
                    trips = loop_trip_count(comps[cm.group(1)])
                costs.trip_counts[bm.group(1) if bm else ins.name] = trips
                if bm:
                    visit(bm.group(1), mult * trips)
                continue
            if ins.op in ("call", "conditional", "custom-call", "fusion",
                          "map", "reduce", "sort", "scatter",
                          "reduce-window", "select-and-scatter"):
                for cm in _CALLED_RE.finditer(ins.line):
                    sub = cm.group(1)
                    if sub in comps and sub not in fusion_bodies:
                        # reduce/sort combinators are tiny; fusion bodies
                        # handled below for dot flops only
                        pass
            if ins.op == "dot":
                costs.flops += mult * _dot_flops(ins, comp)
            if ins.op == "convolution":
                # rough: 2 · |out| · window  (document as approximation)
                out = parse_shapes(ins.out_type)
                if out:
                    n = 1
                    for d in out[0][1]:
                        n *= d
                    costs.flops += mult * 2.0 * n
            if ins.op in COLLECTIVES:
                wb = _collective_wire_bytes(ins, comp, n_partitions)
                costs.collective_bytes += mult * wb
                costs.per_collective[ins.op] += mult * wb
                costs.collective_count[ins.op] += int(mult)
            if ins.op in _MEM_OPS and name not in fusion_bodies:
                costs.bytes_accessed += mult * _mem_bytes(
                    ins, comp, comps, fusion_bodies)

        # dot flops hidden inside fusion bodies (rare, but count them)
        for ins in comp.instrs:
            if ins.op == "fusion":
                cm = re.search(r"calls=%([\w.\-]+)", ins.line)
                if cm and cm.group(1) in comps:
                    sub = comps[cm.group(1)]
                    for fi in sub.instrs:
                        if fi.op == "dot":
                            costs.flops += mult * _dot_flops(fi, sub)

    visit(entry, 1.0)
    return costs
