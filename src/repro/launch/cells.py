"""Dry-run cells: (architecture × input shape) → a lowerable step.

Each cell supplies:
    fn               — the step function (train_step / prefill_step /
                       decode_step)
    args             — ShapeDtypeStruct stand-ins (weak-type-correct,
                       shardable, **no device allocation**)
    in/out_shardings — NamedShardings against the target mesh
    donate           — realistic buffer donation (params+opt for train,
                       caches for decode)

Shapes (assignment):
    train_4k     seq 4,096   global_batch 256   (train_step)
    prefill_32k  seq 32,768  global_batch 32    (serve prefill)
    decode_32k   cache 32,768 batch 128         (serve_step, 1 new token)
    long_500k    cache 524,288 batch 1          (serve_step; sub-quadratic
                                                 archs only — see skips)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import AdamW
from repro.sharding import specs as SH
from repro.train.objective import grad_accum_step, lm_loss


@dataclasses.dataclass
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 500k decode requires "
                "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return None


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def pick_accum(cfg: ArchConfig, shape: ShapeCell, mesh: Mesh) -> int:
    """Grad-accum depth: keep the per-device microbatch ≈ 1–2 sequences
    for wide models (remat keeps one unit's activations live)."""
    dp = int(np.prod([SH.mesh_size(mesh, a) for a in SH.dp_axes(mesh)]))
    per_dev = max(1, shape.batch // dp)
    target = 1 if cfg.d_model >= 3584 else 2
    accum = max(1, per_dev // target)
    while shape.batch % (accum) or (shape.batch // accum) % dp:
        accum //= 2
        if accum <= 1:
            return 1
    return accum


def _text_len(cfg: ArchConfig, seq: int) -> int:
    return seq - (cfg.vision_patches or 0)


def install_sharding_hook(cfg: ArchConfig, mesh: Mesh,
                          moe_parallel: bool = True):
    """Launcher-side parallelism policies:

    * context-parallel attention — activations enter attention sharded on
      the sequence over the 'model' axis (weights replicated); enabled
      per-arch via ``cfg.attn_sequence_parallel`` (head counts not
      divisible by tp);
    * explicit expert-parallel MoE dispatch (shard_map, one psum/layer) —
      replaces the GSPMD-auto scatter that all-gathers the dispatch
      buffer (§Perf hillclimb, qwen3-moe/jamba).
    """
    if cfg.n_experts and moe_parallel and SH.mesh_size(mesh, "model") > 1 \
            and cfg.n_experts % SH.mesh_size(mesh, "model") == 0:
        import functools
        from repro.models.moe_parallel import expert_parallel_moe
        T.set_moe_parallel(functools.partial(
            expert_parallel_moe, mesh=mesh, dp_axes=SH.dp_axes(mesh)))
    else:
        T.set_moe_parallel(None)
    if not cfg.attn_sequence_parallel:
        T.set_sharding_hook(None)
        return
    dp = SH.dp_axes(mesh)
    tp = SH.mesh_size(mesh, "model")
    batch = NamedSharding(mesh, P(dp if dp else None, None, None))
    seq = NamedSharding(mesh, P(dp if dp else None, "model", None))

    def hook(tag, x):
        if x.ndim != 3:
            return x
        if tag == "attn_in" and x.shape[1] % tp == 0 and x.shape[1] >= tp:
            return jax.lax.with_sharding_constraint(x, seq)
        if tag == "attn_out" and x.shape[1] > 1:
            return jax.lax.with_sharding_constraint(x, batch)
        return x
    T.set_sharding_hook(hook)


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

def build_train_cell(cfg: ArchConfig, shape: ShapeCell, mesh: Mesh,
                     optimizer: Optional[AdamW] = None):
    install_sharding_hook(cfg, mesh)
    opt = optimizer or AdamW(lr=3e-4, weight_decay=0.1)
    accum = pick_accum(cfg, shape, mesh)

    p_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, max_position=shape.seq),
        jax.random.PRNGKey(0))
    o_shape = jax.eval_shape(opt.init, p_shape)
    p_shard = SH.params_shardings(cfg, p_shape, mesh)
    o_shard = SH.opt_shardings(cfg, o_shape, mesh)

    bspec = SH.batch_spec(mesh, shape.batch)
    bshard = NamedSharding(mesh, bspec)
    S_text = _text_len(cfg, shape.seq)
    batch = {"tokens": _sds((shape.batch, S_text), jnp.int32, bshard),
             "labels": _sds((shape.batch, S_text), jnp.int32, bshard)}
    b_shard = {"tokens": bshard, "labels": bshard}
    if cfg.is_encoder_decoder:
        fshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch, 3))
        batch["frames"] = _sds((shape.batch, cfg.encoder_seq, cfg.d_model),
                               jnp.bfloat16, fshard)
        b_shard["frames"] = fshard
    if cfg.vision_patches:
        pshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch, 3))
        batch["patch_embeds"] = _sds(
            (shape.batch, cfg.vision_patches, cfg.vision_embed_dim),
            jnp.bfloat16, pshard)
        b_shard["patch_embeds"] = pshard

    def train_step(params, opt_state, bt):
        grads, loss, metrics = grad_accum_step(cfg, params, bt,
                                               accum=accum)
        params, opt_state, stats = opt.update(grads, opt_state, params)
        return params, opt_state, dict(metrics, **stats)

    rep = SH.replicated(mesh)
    met_shape = jax.eval_shape(train_step, p_shape, o_shape, batch)[2]
    met_shard = jax.tree.map(lambda _: rep, met_shape)
    jfn = jax.jit(train_step,
                  in_shardings=(p_shard, o_shard, b_shard),
                  out_shardings=(p_shard, o_shard, met_shard),
                  donate_argnums=(0, 1))
    return jfn, (p_shape, o_shape, batch), {"accum": accum}


def build_prefill_cell(cfg: ArchConfig, shape: ShapeCell, mesh: Mesh):
    install_sharding_hook(cfg, mesh)
    p_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, max_position=shape.seq),
        jax.random.PRNGKey(0))
    p_shard = SH.params_shardings(cfg, p_shape, mesh)
    bshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch))
    S_text = _text_len(cfg, shape.seq)
    args = {"tokens": _sds((shape.batch, S_text), jnp.int32, bshard)}
    a_shard = {"tokens": bshard}
    if cfg.is_encoder_decoder:
        fshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch, 3))
        args["frames"] = _sds((shape.batch, cfg.encoder_seq, cfg.d_model),
                              jnp.bfloat16, fshard)
        a_shard["frames"] = fshard
    if cfg.vision_patches:
        pshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch, 3))
        args["patch_embeds"] = _sds(
            (shape.batch, cfg.vision_patches, cfg.vision_embed_dim),
            jnp.bfloat16, pshard)
        a_shard["patch_embeds"] = pshard

    c_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.batch, shape.seq))
    c_shard = SH.cache_shardings(cfg, c_shape, mesh, shape.batch)

    def prefill_step(params, a):
        caches = T.init_cache(cfg, shape.batch, shape.seq)
        enc_out = cross = None
        if cfg.is_encoder_decoder:
            enc_out = T.encode(cfg, params, a["frames"])
            cross = T.prefill_cross_caches(cfg, params, enc_out)
        logits, caches = T.step_with_cache(
            cfg, params, caches, a["tokens"], 0,
            patch_embeds=a.get("patch_embeds"), enc_out=enc_out,
            cross_caches=cross)
        return logits[:, -1], caches

    logit_shard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch))
    jfn = jax.jit(prefill_step,
                  in_shardings=(p_shard, a_shard),
                  out_shardings=(logit_shard, c_shard))
    return jfn, (p_shape, args), {}


def build_decode_cell(cfg: ArchConfig, shape: ShapeCell, mesh: Mesh,
                      cache_quant: bool = False):
    install_sharding_hook(cfg, mesh)
    p_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k, max_position=shape.seq),
        jax.random.PRNGKey(0))
    p_shard = SH.params_shardings(cfg, p_shape, mesh)
    c_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.batch, shape.seq,
                             quant=cache_quant))
    c_shard = SH.cache_shardings(cfg, c_shape, mesh, shape.batch)
    bshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch))
    rep = SH.replicated(mesh)

    args = {"tokens": _sds((shape.batch, 1), jnp.int32, bshard),
            "pos": _sds((), jnp.int32, rep)}
    a_shard = {"tokens": bshard, "pos": rep}
    extra = {}
    if cfg.is_encoder_decoder:
        eshard = NamedSharding(mesh, SH.batch_spec(mesh, shape.batch, 3))
        extra["enc_out"] = _sds(
            (shape.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
            eshard)
        x_shape = jax.eval_shape(
            lambda p, e: T.prefill_cross_caches(cfg, p, e),
            p_shape, extra["enc_out"])
        x_shard = SH.cache_shardings(cfg, x_shape, mesh, shape.batch,
                                     seq_shard=False)
        extra_shard = {"enc_out": eshard, "cross": x_shard}
        extra["cross"] = x_shape
    else:
        extra_shard = {}

    def decode_step(params, caches, a, ex):
        logits, caches = T.decode_step(
            cfg, params, caches, a["tokens"], a["pos"],
            enc_out=ex.get("enc_out"), cross_caches=ex.get("cross"))
        return logits, caches

    jfn = jax.jit(decode_step,
                  in_shardings=(p_shard, c_shard, a_shard, extra_shard),
                  out_shardings=(NamedSharding(mesh, SH.batch_spec(
                      mesh, shape.batch, 3)), c_shard),
                  donate_argnums=(1,))
    return jfn, (p_shape, c_shape, args, extra), {}


def build_cell(cfg: ArchConfig, shape_name: str, mesh: Mesh, **kw):
    """Returns (jitted_fn, example_args, meta) or raises on skip."""
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape_name)
    if reason:
        raise CellSkipped(reason)
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh)
    return build_decode_cell(cfg, shape, mesh, **kw)


class CellSkipped(Exception):
    pass


# ---------------------------------------------------------------------------
# analytic model FLOPs (for the roofline's usefulness ratio)
# ---------------------------------------------------------------------------

def count_params(shapes) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def active_params(cfg: ArchConfig, shapes) -> int:
    """Active parameters per token (MoE: top_k of n_experts routed)."""
    total = count_params(shapes)
    if not cfg.n_experts:
        return total
    routed = 0
    def visit(kp, leaf):
        nonlocal routed
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if any(w in path for w in ("w_up", "w_gate", "w_down")):
            routed += int(np.prod(leaf.shape))
        return leaf
    jax.tree_util.tree_map_with_path(visit, shapes)
    return total - routed + int(routed * cfg.top_k / cfg.n_experts)


def model_flops(cfg: ArchConfig, shape_name: str, shapes) -> float:
    """6·N_active·D for train; 2·N_active per generated token for decode;
    2·N_active·D for prefill (forward only)."""
    sh = SHAPES[shape_name]
    n_act = active_params(cfg, shapes)
    tokens = sh.batch * (sh.seq if sh.kind != "decode" else 1)
    mult = 6 if sh.kind == "train" else 2
    return float(mult) * n_act * tokens
