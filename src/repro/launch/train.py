"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        [--steps 100] [--reduced] [--dry-run] [--pod-shape 32,8]

Modes:
    --dry-run    lower+compile the full-scale train cell against the
                 production mesh (512 placeholder devices) and print the
                 memory/roofline summary — the cluster-submission check.
    --reduced    actually train the reduced config on the local devices
                 (CPU-runnable end-to-end path with checkpointing).
Full-scale execution uses the same code path with a real TPU mesh: the
jit'd step, shardings, checkpointing and fault handling are identical.
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--pod-shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args(argv)

    if args.dry_run:
        # re-exec through the dry-run entry (it must own XLA_FLAGS)
        from repro.launch import dryrun
        pod_shape = (tuple(int(x) for x in args.pod_shape.split(","))
                     if args.pod_shape else None)
        rec = dryrun.run_cell(args.arch, args.shape,
                              "multipod" if args.multi_pod else "pod",
                              out_dir="runs/dryrun_cli", force=True,
                              pod_shape=pod_shape)
        return 0 if rec.get("ok") else 1

    import jax
    from repro.configs import get_config, get_reduced
    from repro.data import SyntheticLM
    from repro.models import transformer as T
    from repro.optim import AdamW, cosine_with_warmup
    from repro.train import Trainer, TrainConfig

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=0)
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           max_position=args.seq)
    opt = AdamW(lr=cosine_with_warmup(args.lr, max(args.steps // 10, 1),
                                      args.steps), weight_decay=0.01)
    trainer = Trainer(cfg, TrainConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, log_every=10), opt)
    trainer.install_preemption_handler()
    _, _, info = trainer.run(params, lambda s: data.batches(s))
    print(f"[launch.train] {cfg.name}: {info['steps']} steps, "
          f"{info['faults']} faults")
    return 0


if __name__ == "__main__":
    sys.exit(main())
