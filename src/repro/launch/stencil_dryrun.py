import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# (standalone entry point: set the placeholder-device flag before jax)

"""Dry-run of the paper's OWN application at production scale: the
Helmholtz Loop-of-stencil-reduce on the (16,16) pod — 2-D halo
decomposition, while_loop inside shard_map, psum'd convergence — lowered
and compiled for the paper's largest grid (16384², Table 1) and beyond.

    PYTHONPATH=src python -m repro.launch.stencil_dryrun [--size 16384]
"""
import argparse
import json
import time


def main(argv=None):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import GridPartition
    from repro.core.halo import distributed_loop_of_stencil_reduce
    from repro.launch import hlo_analysis as HA
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=16384)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args(argv)

    mesh = make_production_mesh()
    part = GridPartition(mesh=mesh, axis_names=("data", "model"),
                         array_axes=(0, 1))

    def jac(get):
        return 0.25 * (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1))

    n = args.size
    u = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def run(u0):
        res = distributed_loop_of_stencil_reduce(
            jac, "max", lambda r: r < 1e-4, u0, k=1, part=part,
            identity=-jnp.inf,
            delta=lambda a, b: jnp.abs(a - b), max_iters=args.iters)
        return res.a, res.reduced, res.iters

    t0 = time.time()
    with mesh:
        compiled = jax.jit(run).lower(u).compile()
    hlo = compiled.as_text()
    chips = 256
    costs = HA.analyze(hlo, n_partitions=chips)
    ma = compiled.memory_analysis()

    # analytic per iteration per chip: 4 flops/cell; halo = 4 edges × k
    cells = (n * n) / chips
    t_c = costs.flops / PEAK_FLOPS
    t_m = costs.bytes_accessed / HBM_BW
    t_x = costs.collective_bytes / ICI_BW
    rec = {
        "app": "helmholtz_stencil", "grid": n, "iters": args.iters,
        "chips": chips, "ok": True,
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes_accessed,
        "collective_bytes_per_device": costs.collective_bytes,
        "per_collective": dict(costs.per_collective),
        "trip_counts": dict(costs.trip_counts),
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "compile_s": round(time.time() - t0, 1),
    }
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"stencil_{n}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[stencil-dryrun] {n}x{n} on 16x16 pod: compiled in "
          f"{rec['compile_s']}s; per-iter/chip "
          f"tc={t_c / args.iters * 1e6:.1f}us tm={t_m / args.iters * 1e6:.1f}us "
          f"tx={t_x / args.iters * 1e6:.1f}us "
          f"(halo permutes: {costs.collective_count.get('collective-permute', 0)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
