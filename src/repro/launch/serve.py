"""Production serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        [--reduced] [--dry-run --shape decode_32k]

--dry-run lowers the full-scale decode/prefill cell against the
production mesh; --reduced serves the reduced config locally (batched
requests through the Loop-of-stencil-reduce decode loop).
"""
import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        rec = dryrun.run_cell(args.arch, args.shape,
                              "multipod" if args.multi_pod else "pod",
                              out_dir="runs/dryrun_cli", force=True)
        return 0 if rec.get("ok") else 1

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import GenerateConfig, generate

    cfg = get_reduced(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (args.batch, 8)))
    gcfg = GenerateConfig(max_new_tokens=args.max_new, eos_id=1,
                          temperature=0.7)
    t0 = time.perf_counter()
    out, lengths, iters = generate(cfg, params, prompt, gcfg,
                                   cache_dtype=jnp.float32)
    jax.block_until_ready(out)
    total = int(lengths.sum())
    print(f"[launch.serve] {cfg.name} (reduced): {total} tokens in "
          f"{time.perf_counter() - t0:.2f}s over {args.batch} requests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
