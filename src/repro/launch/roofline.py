"""Roofline terms from the compiled dry-run artifact (TPU v5e targets).

    compute    t_c = per-device HLO FLOPs / peak FLOP/s
    memory     t_m = per-device HLO bytes accessed / HBM bandwidth
    collective t_x = per-device collective wire bytes / ICI link bandwidth

plus the "usefulness" ratio MODEL_FLOPS / HLO_FLOPS (catches remat and
redundancy waste) and the roofline fraction
    frac = t_model / max(t_c, t_m, t_x),   t_model = MODEL_FLOPS/(chips·peak)
which is 1.0 for a perfectly compute-bound, zero-waste program.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# hardware constants (assignment): TPU v5e-class
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0    # MODEL_FLOPS / global HLO FLOPs
    fraction: float = 0.0        # roofline fraction (see module docstring)

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops_per_device / PEAK_FLOPS
        self.t_memory = self.bytes_per_device / HBM_BW
        self.t_collective = self.collective_bytes_per_device / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.dominant = max(terms, key=terms.get)
        hlo_global = self.flops_per_device * self.chips
        self.useful_ratio = (self.model_flops_global / hlo_global
                             if hlo_global else 0.0)
        t_model = self.model_flops_global / (self.chips * PEAK_FLOPS)
        bound = max(terms.values())
        self.fraction = t_model / bound if bound else 0.0
        return self

    def asdict(self):
        return dataclasses.asdict(self)

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:9s} "
                f"tc={self.t_compute*1e3:9.3f}ms tm={self.t_memory*1e3:9.3f}ms "
                f"tx={self.t_collective*1e3:9.3f}ms dom={self.dominant:10s} "
                f"useful={self.useful_ratio:6.2f} frac={self.fraction:6.3f}")
