"""Persistent-halo execution engine — the backend axis of the pattern.

This is the seam between :class:`repro.core.pattern.LoopOfStencilReduce`
and its realisations.  Three backends:

``"jnp"``
    The shift-algebra path (:func:`repro.core.stencil.stencil_taps`): XLA
    fuses the shifts, padding happens per application.  Reference
    semantics; also the fallback for non-2D arrays and non-taps modes.

``"pallas"``
    The fused single-step Pallas kernel iterated on a **persistent halo
    frame**: the padded, block-rounded frame (:mod:`repro.core.frames`) is
    the ``while_loop`` carry, so no ``jnp.pad`` or full-grid slice appears
    inside the loop body — the paper's device-memory persistence taken to
    the HBM-traffic level.  Only the O(m+n) ghost ring is re-asserted
    between sweeps.

``"pallas-multistep"``
    Temporal blocking: the pattern's ``unroll=T`` becomes the fused sweep
    count of :func:`repro.kernels.multistep.stencil2d_multistep_framed`,
    cutting HBM traffic per iteration by ≈T at ~(1 + 2kT/b)² redundant
    compute.  The convergence reduce fires every T sweeps — exactly the
    pattern's unroll semantics.

``"pallas-sharded"``
    The 1:n deployment of the persistent engine
    (:class:`ShardedStencilEngine`): the whole loop runs *inside*
    ``shard_map``, each shard's while-carry is its local halo frame, the
    ghost refresh is a ppermute of O(pad·n) edge strips straight into the
    neighbour's ring, and the fused delta-reduce composes with the
    monoid's native collective (``psum``/``pmax``/``pmin``) so the
    condition is evaluated identically on every shard with no host in
    the loop.  ``unroll=T`` reuses the temporal-blocking kernel with a
    k·T-deep halo exchanged once per T fused sweeps — ICI messages drop
    ≈T× for ~(1 + 2kT/b)² redundant compute (communication-avoiding).

The engine is deliberately array-in/array-out and stateless across calls
(the :class:`FrameSpec` travels alongside the frame), so streaming
executors can drop in behind the same seam.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .frames import (FrameSpec, LaneFrameSpec, ShardedFrameSpec, ceil_mul,
                     frame_spec, make_frame, frame_env, frame_env_sharded,
                     lane_env_frames, make_frame_sharded, make_lane_frames,
                     refill_lane_env, refill_lane_env_sharded,
                     refill_lane_frames, refill_lane_frames_sharded,
                     refresh_frame, refresh_frame_sharded,
                     shard_domain_bounds, sharded_frame_spec, unframe,
                     unframe_lanes)
from .reduce import collective_combine, resolve_monoid
from .semantics import Boundary

BACKENDS = ("jnp", "pallas", "pallas-multistep", "pallas-sharded")


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def local_extents(m: int, n: int, part) -> tuple[int, int]:
    """Per-shard domain extents of an (m, n) grid under ``part`` (a
    :class:`repro.sharding.specs.GridPartition`); (m, n) when None."""
    lm, ln = m, n
    if part is not None:
        for name, ax in zip(part.axis_names, part.array_axes):
            nsh = part.mesh.shape[name]
            if ax == 0:
                lm = m // nsh
            elif ax == 1:
                ln = n // nsh
    return lm, ln


def auto_unroll(m: int, n: int, *, k: int = 1, block=(256, 256),
                part=None, cap: int = 8,
                redundancy_limit: float = 1.5,
                segment: Optional[int] = None,
                dispatch_amortize: int = 64) -> int:
    """Cost-heuristic temporal-blocking depth T for the persistent
    backends (``unroll="auto"``).

    Each extra fused sweep saves one ghost exchange — a full ICI
    latency·hop round on the sharded backend (per decomposed mesh axis),
    an HBM round-trip on "pallas-multistep" — at ~(1 + 2kT/bm)(1 + 2kT/bn)
    redundant compute per shard.  Exchanges are latency-bound and compute
    is throughput-bound, so deepening pays until the redundancy factor
    bites: take the largest T with

    * k·T < min(local m, local n)   (the frame_spec feasibility ceiling —
      a shard's halo cannot exceed its own domain), and
    * redundancy ≤ ``redundancy_limit``  (default 1.5: at most half the
      VPU throughput spent recomputing neighbour cells).

    The mesh shape enters through the LOCAL extents: more shards → smaller
    local domains → smaller feasible/profitable T, which is exactly the
    ceiling the ROADMAP notes (8 shards of a 64-row grid cap T at 4·k).

    With ``segment`` set (continuous farms: ``segment`` body steps per
    dispatch, so ``segment·T`` sweeps amortize one dispatch) the heuristic
    additionally folds the PER-DISPATCH cost in: when the tuned
    ``T·segment`` lands under ``dispatch_amortize`` sweeps, T is pushed
    back up toward ``ceil(dispatch_amortize / segment)`` — feasibility
    still binds (the halo must fit the local domain) but the redundancy
    limit is deliberately ignored, because in that regime the dispatch
    overhead, not the VPU, is the bottleneck: redundant ghost compute is
    free relative to a host round trip per segment.
    """
    lm, ln = local_extents(m, n, part)
    if min(lm, ln) <= k:
        raise ValueError(
            f"stencil radius k={k} does not fit the local domain "
            f"({lm}x{ln}): even T=1 needs k < min(local m, n); use a "
            f"coarser decomposition or a larger grid")
    bm = min(block[0], ceil_mul(lm, 8))
    bn = min(block[1], ceil_mul(ln, 128))
    best = 1
    for T in range(1, cap + 1):
        if k * T >= min(lm, ln):
            break
        if (1 + 2 * k * T / bm) * (1 + 2 * k * T / bn) > redundancy_limit:
            break
        best = T
    if segment is not None and best * segment < dispatch_amortize:
        want = -(-dispatch_amortize // segment)        # ceil division
        T = best
        while T < min(want, cap) and k * (T + 1) < min(lm, ln):
            T += 1
        best = T
    return best


def check_unroll_feasible(m: int, n: int, unroll: int, *, k: int = 1,
                          part=None) -> None:
    """Loud feasibility check for an explicit ``unroll=T`` — raises with
    the mesh context and the feasible ceiling instead of letting
    ``frame_spec`` fail with local-only numbers deep inside shard_map."""
    lm, ln = local_extents(m, n, part)
    if k * unroll < min(lm, ln):
        return
    tmax = max((min(lm, ln) - 1) // k, 0)
    where = (f"each of the {tuple(part.shards)} shards holds a local "
             f"{lm}x{ln} block of the {m}x{n} grid" if part is not None
             else f"the {m}x{n} grid")
    raise ValueError(
        f"unroll={unroll} is infeasible: the k*T={k * unroll}-deep halo "
        f"must fit inside the local domain, but {where} "
        f"(k*T < min(local m, n) = {min(lm, ln)} requires T <= {tmax}). "
        f"Lower unroll, pass unroll='auto', or use a coarser "
        f"decomposition.")


@dataclasses.dataclass
class StencilEngine:
    """Lowers fused stencil+reduce sweeps onto a chosen backend.

    ``delta``/``measure`` mirror the pattern's -d variant: the fused reduce
    folds ``delta(new, old)`` (elementwise, old = previous iterate) or
    ``measure(new)``; with neither, it folds ``new`` itself.
    """

    f: Callable
    k: int = 1
    boundary: Boundary | str = Boundary.ZERO
    combine: Any = "sum"
    identity: Any = None
    delta: Optional[Callable] = None
    measure: Optional[Callable] = None
    block: tuple[int, int] = (256, 256)
    unroll: int = 1
    backend: str = "pallas"
    interpret: Optional[bool] = None
    acc_dtype: Any = jnp.float32
    double_buffer: bool = True

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; choose from {BACKENDS}")
        self.boundary = Boundary(self.boundary)
        self._interp = _default_interpret(self.interpret)
        if self.delta is not None:
            self._kernel_measure = self.delta
        elif self.measure is not None:
            meas = self.measure
            self._kernel_measure = lambda new, old: meas(new)
        else:
            self._kernel_measure = None

    # -- frame staging (once, outside the loop) -------------------------
    def prepare(self, a: jnp.ndarray, env=()):
        """Stage ``a`` and the env fields into frames.  O(mn), runs once."""
        m, n = a.shape
        multistep = self.backend == "pallas-multistep"
        spec = frame_spec(m, n, k=self.k, block=self.block,
                          sweeps=self.unroll if multistep else 1)
        frame = make_frame(a, spec, self.boundary)
        env_frames = tuple(frame_env(e, spec, self.boundary, halo=multistep)
                           for e in env)
        return frame, env_frames, spec

    # -- the loop body (zero-copy) --------------------------------------
    def sweeps(self, frame: jnp.ndarray, env_frames, spec: FrameSpec):
        """``unroll`` stencil applications; returns (frame', reduced).

        The reduce covers the final application (measure against the
        second-to-last iterate).  The returned frame's ghost ring is
        already refreshed — it is a valid input for the next call.
        """
        from repro.kernels.multistep import stencil2d_multistep_framed
        from repro.kernels.stencil2d import stencil2d_fused_framed

        if self.backend == "pallas-multistep":
            frame, red = stencil2d_multistep_framed(
                frame, self.f, spec, T=self.unroll, env_framed=env_frames,
                combine=self.combine, identity=self.identity,
                measure=self._kernel_measure,
                boundary=self.boundary.value, acc_dtype=self.acc_dtype,
                double_buffer=self.double_buffer, interpret=self._interp)
            return refresh_frame(frame, spec, self.boundary), red
        red = None
        for s in range(self.unroll):
            # the condition only sees the final application's reduce —
            # intermediate sweeps skip the fused measure+fold entirely
            frame, red = stencil2d_fused_framed(
                frame, self.f, spec, env_framed=env_frames,
                combine=self.combine, identity=self.identity,
                measure=self._kernel_measure, acc_dtype=self.acc_dtype,
                double_buffer=self.double_buffer,
                do_reduce=(s == self.unroll - 1), interpret=self._interp)
            frame = refresh_frame(frame, spec, self.boundary)
        return frame, red

    def unframe(self, frame: jnp.ndarray, spec: FrameSpec) -> jnp.ndarray:
        """Slice the domain back out — once, after convergence."""
        return unframe(frame, spec)

    # -- the lane axis (1:1 streaming farm) ------------------------------
    @property
    def _halo_env(self) -> bool:
        return self.backend == "pallas-multistep"

    def lane_spec(self, lanes: int, m: int, n: int) -> LaneFrameSpec:
        """Frame geometry for ``lanes`` independent (m, n) stream items."""
        spec = frame_spec(m, n, k=self.k, block=self.block,
                          sweeps=self.unroll if self._halo_env else 1)
        return LaneFrameSpec(lanes=lanes, frame=spec)

    def prepare_lanes(self, a: jnp.ndarray, env=()):
        """Stage a (lanes, m, n) stack into lane frames — one-shot entry
        (:meth:`refill_lanes` is the streaming path that reuses slots)."""
        lanes, m, n = a.shape
        lspec = self.lane_spec(lanes, m, n)
        frames = make_lane_frames(a, lspec.frame, self.boundary)
        env_frames = tuple(
            lane_env_frames(e, lspec.frame, self.boundary,
                            halo=self._halo_env) for e in env)
        return frames, env_frames, lspec

    def refill_lanes(self, frames, env_frames, interiors, env_new,
                     lspec: LaneFrameSpec):
        """Refill the lane slots in place with the next stream items —
        O(interior) writes + O(m+n) ghost refresh per lane; no pad, no
        re-framing, no new allocation (donate the buffers under jit)."""
        frames = refill_lane_frames(frames, interiors, lspec.frame,
                                    self.boundary)
        env_frames = tuple(
            refill_lane_env(ef, e, lspec.frame, self.boundary,
                            halo=self._halo_env)
            for ef, e in zip(env_frames, env_new))
        return frames, env_frames

    def sweeps_lanes(self, frames, env_frames, lspec: LaneFrameSpec):
        """``unroll`` sweeps on every lane; returns (frames', (lanes,) r).

        One vmapped kernel launch covers the whole farm — the lane axis
        becomes an extra TPU grid dimension, not a Python loop.
        """
        return jax.vmap(
            lambda fr, *efs: self.sweeps(fr, tuple(efs), lspec.frame)
        )(frames, *env_frames)

    def unframe_lanes(self, frames, lspec: LaneFrameSpec):
        """Slice every lane's domain back out — the only per-item O(m·n)
        device→host candidate of the streaming path (the frames stay)."""
        return unframe_lanes(frames, lspec.frame)


@dataclasses.dataclass
class ShardedStencilEngine:
    """The 1:n persistent engine: per-shard frames, ppermute ghost swap.

    Every method runs *inside* ``shard_map`` (the mesh axes of ``part``
    must be bound).  The loop body is: kernel sweep(s) on the local frame
    → O(pad·n) ppermute edge-strip exchange → monoid collective of the
    fused partial reduce.  With ``unroll=T > 1`` the temporal-blocking
    kernel runs T sweeps per exchange over a k·T-deep halo
    (communication-avoiding: 1/T the ICI rounds per sweep).
    """

    f: Callable
    part: Any                        # GridPartition (mesh + decomposition)
    k: int = 1
    boundary: Boundary | str = Boundary.ZERO
    combine: Any = "sum"
    identity: Any = None
    delta: Optional[Callable] = None
    measure: Optional[Callable] = None
    block: tuple[int, int] = (256, 256)
    unroll: int = 1
    interpret: Optional[bool] = None
    acc_dtype: Any = jnp.float32
    double_buffer: bool = True

    def __post_init__(self):
        self.boundary = Boundary(self.boundary)
        self._interp = _default_interpret(self.interpret)
        self._op, self._id = resolve_monoid(self.combine, self.identity)
        if self.delta is not None:
            self._kernel_measure = self.delta
        elif self.measure is not None:
            meas = self.measure
            self._kernel_measure = lambda new, old: meas(new)
        else:
            self._kernel_measure = None

    @property
    def _multistep(self) -> bool:
        return self.unroll > 1

    # -- per-shard frame staging (once, inside shard_map) ---------------
    def prepare(self, a_local: jnp.ndarray, env_local=()):
        """Stage this shard's block and env slices into frames."""
        lm, ln = a_local.shape
        sspec = sharded_frame_spec(
            lm, ln, self.part, k=self.k, block=self.block,
            sweeps=self.unroll if self._multistep else 1)
        frame = make_frame_sharded(a_local, sspec, self.boundary)
        env_frames = tuple(
            frame_env_sharded(e, sspec, self.boundary,
                              halo=self._multistep)
            for e in env_local)
        return frame, env_frames, sspec

    # -- the loop body (zero-copy, communication-avoiding) --------------
    def sweeps(self, frame: jnp.ndarray, env_frames,
               sspec: ShardedFrameSpec):
        """``unroll`` sweeps + ONE ghost exchange + the global combine."""
        from repro.kernels.multistep import stencil2d_multistep_framed
        from repro.kernels.stencil2d import stencil2d_fused_framed

        spec = sspec.local
        if self._multistep:
            frame, red = stencil2d_multistep_framed(
                frame, self.f, spec, T=self.unroll,
                env_framed=env_frames, combine=self.combine,
                identity=self.identity, measure=self._kernel_measure,
                boundary=self.boundary.value,
                domain_bounds=shard_domain_bounds(sspec),
                acc_dtype=self.acc_dtype,
                double_buffer=self.double_buffer, interpret=self._interp)
        else:
            frame, red = stencil2d_fused_framed(
                frame, self.f, spec, env_framed=env_frames,
                combine=self.combine, identity=self.identity,
                measure=self._kernel_measure, acc_dtype=self.acc_dtype,
                double_buffer=self.double_buffer, interpret=self._interp)
        frame = refresh_frame_sharded(frame, sspec, self.boundary)
        red = collective_combine(self._op, red, self.part.axis_names)
        return frame, red

    def unframe(self, frame: jnp.ndarray,
                sspec: ShardedFrameSpec) -> jnp.ndarray:
        """Slice this shard's local domain back out, after convergence."""
        return unframe(frame, sspec.local)

    # -- the lane axis (lanes × spatial decomposition) -------------------
    # All lane methods run inside ``shard_map`` with the partition's mesh
    # axes bound; the lane stack holds this shard's LOCAL lanes and the
    # vmap batches the ppermute exchange + monoid collective per lane.

    def lane_sspec(self, lm: int, ln: int) -> ShardedFrameSpec:
        """Per-shard frame geometry for one lane's local (lm, ln) block."""
        return sharded_frame_spec(
            lm, ln, self.part, k=self.k, block=self.block,
            sweeps=self.unroll if self._multistep else 1)

    def prepare_lanes(self, a_local: jnp.ndarray, env_local=()):
        """Stage this shard's (lanes, lm, ln) stack into lane frames."""
        _, lm, ln = a_local.shape
        sspec = self.lane_sspec(lm, ln)
        frames = jax.vmap(
            lambda b: make_frame_sharded(b, sspec, self.boundary))(a_local)
        env_frames = tuple(
            jax.vmap(lambda e: frame_env_sharded(
                e, sspec, self.boundary, halo=self._multistep))(e)
            for e in env_local)
        return frames, env_frames, sspec

    def refill_lanes(self, frames, env_frames, interiors, env_new,
                     sspec: ShardedFrameSpec):
        """In-place lane-slot refill with this shard's next local blocks."""
        frames = refill_lane_frames_sharded(frames, interiors, sspec,
                                            self.boundary)
        env_frames = tuple(
            refill_lane_env_sharded(ef, e, sspec, self.boundary,
                                    halo=self._multistep)
            for ef, e in zip(env_frames, env_new))
        return frames, env_frames

    def sweeps_lanes(self, frames, env_frames, sspec: ShardedFrameSpec):
        """``unroll`` sweeps + ONE lane-batched ghost exchange + the
        global combine; returns (frames', (local_lanes,) r).  The combine
        makes r identical across the spatial shards of each lane, so a
        lane-done condition stays SPMD-uniform within its exchange group
        (the while trip counts may diverge across LANE shards — there are
        no collectives along the lane axis)."""
        return jax.vmap(
            lambda fr, *efs: self.sweeps(fr, tuple(efs), sspec)
        )(frames, *env_frames)

    def unframe_lanes(self, frames, sspec: ShardedFrameSpec):
        """Slice every local lane's domain back out."""
        return unframe_lanes(frames, sspec.local)


def sweep_once(a, f, *, env=(), k=1, combine="sum", identity=None,
               measure=None, boundary="zero", block=(256, 256),
               backend="pallas", unroll=1, interpret=None,
               double_buffer=True, acc_dtype=jnp.float32):
    """One fused stencil+reduce application through the backend axis.

    The fused-application entry point for non-iterative uses (Sobel, the
    AMF detection pass): returns ``(new, reduced)``.

    NOTE on naming: ``measure`` here is the *kernel* convention —
    a two-argument ``measure(new, old_center)`` (e.g. ``ref.abs_delta``),
    matching ``stencil2d_fused``.  The loop-level APIs
    (:class:`StencilEngine`, :class:`repro.core.pattern.
    LoopOfStencilReduce`) split this into ``delta`` (two-argument) and
    ``measure`` (one-argument, of the new iterate only) — pass a
    two-argument function as ``delta`` there, not ``measure``.

    ``unroll`` applies
    that many sweeps on every backend (fused into one kernel on
    "pallas-multistep", sequential otherwise), with the reduce taken on
    the final one — same contract as the pattern's unroll.
    ``backend="jnp"`` runs the oracle path; the Pallas backends
    frame/unframe per call, so a one-shot costs the same staging as the
    old per-iteration kernels — the persistent win applies to loops (use
    :class:`StencilEngine` / the pattern's ``backend=`` for those).
    """
    interp = _default_interpret(interpret)
    if backend == "pallas-multistep":
        from repro.kernels.multistep import stencil2d_multistep
        return stencil2d_multistep(
            a, f, env=env, k=k, T=unroll, combine=combine,
            identity=identity, measure=measure, boundary=boundary,
            block=block, acc_dtype=acc_dtype,
            double_buffer=double_buffer, interpret=interp)
    if backend == "jnp":
        from repro.kernels import ref as R
        step = lambda x: R.stencil2d_fused_ref(
            x, f, env=env, k=k, combine=combine, identity=identity,
            measure=measure, boundary=boundary, acc_dtype=acc_dtype)
    elif backend == "pallas":
        from repro.kernels.stencil2d import stencil2d_fused
        step = lambda x: stencil2d_fused(
            x, f, env=env, k=k, combine=combine, identity=identity,
            measure=measure, boundary=boundary, block=block,
            acc_dtype=acc_dtype, double_buffer=double_buffer,
            interpret=interp)
    else:
        # "pallas-sharded" is loop-only (it needs a mesh partition and a
        # while-carry); one-shot sweeps stay single-device
        raise ValueError(
            f"unknown backend {backend!r} for sweep_once; choose from "
            "('jnp', 'pallas', 'pallas-multistep')")
    new, red = step(a)
    for _ in range(unroll - 1):
        new, red = step(new)
    return new, red
