"""Persistent halo frames — the device-resident grid layout of the engine.

The paper's central performance claim is *device memory persistence*
(§3.3): the grid never leaves device memory between iterations.  The
original realisation still paid two full-grid passes per iteration on the
hot path — a ``jnp.pad`` before every sweep and an ``out[:m, :n]`` slice
after it.  This module hoists both out of the loop by making the *framed*
array the canonical loop-carried representation:

    ┌──────────────────────────────┐
    │ ghost ring (pad = k·T wide)  │   frame shape: (gm·bm + 2·pad,
    │  ┌────────────┬───────────┐  │                 gn·bn + 2·pad)
    │  │ domain     │ round-up  │  │
    │  │ (m, n)     │ (inert)   │  │   domain at [pad:pad+m, pad:pad+n]
    │  ├────────────┴───────────┤  │
    │  │ block round-up (inert) │  │
    │  └────────────────────────┘  │
    └──────────────────────────────┘

The frame is built **once** before the ``while_loop`` (:func:`make_frame`),
kernels read and write it directly, and only the ghost ring — O(m+n) edge
cells, not O(mn) — is re-asserted between sweeps (:func:`refresh_frame`).
The domain is sliced back out exactly once after convergence
(:func:`unframe`).

Boundary semantics match ``jnp.pad`` axis-sequential composition (corners
are boundary-of-boundary), which is what :class:`repro.core.stencil.
TapAccessor` and the formal semantics realise — so frames are drop-in for
the per-iteration padding they replace.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .semantics import Boundary


def ceil_mul(x: int, q: int) -> int:
    """Round ``x`` up to the next multiple of ``q``."""
    return -(-x // q) * q


@dataclasses.dataclass(frozen=True)
class FrameSpec:
    """Static geometry of a persistent halo frame."""

    m: int          # logical domain rows
    n: int          # logical domain cols
    k: int          # stencil radius per sweep
    pad: int        # ghost-ring width (= k·sweeps for temporal blocking)
    bm: int         # tile rows
    bn: int         # tile cols
    gm: int         # grid rows
    gn: int         # grid cols

    @property
    def interior(self) -> tuple[int, int]:
        """Block-rounded interior (domain + round-up)."""
        return self.gm * self.bm, self.gn * self.bn

    @property
    def shape(self) -> tuple[int, int]:
        mi, ni = self.interior
        return mi + 2 * self.pad, ni + 2 * self.pad


def frame_spec(m: int, n: int, *, k: int = 1, block=(256, 256),
               sweeps: int = 1) -> FrameSpec:
    """Build the frame geometry for an (m, n) domain.

    ``block`` is clipped to TPU-friendly rounded domain sizes (sublane
    multiple of 8, lane multiple of 128) exactly like the one-shot kernels;
    ``sweeps`` > 1 widens the ghost ring for temporal blocking.
    """
    bm = min(block[0], ceil_mul(m, 8))
    bn = min(block[1], ceil_mul(n, 128))
    gm, gn = -(-m // bm), -(-n // bn)
    pad = k * sweeps
    if pad >= min(m, n):
        raise ValueError(
            f"halo width k*sweeps={pad} must be < min(m, n)={min(m, n)}; "
            f"lower `unroll` or use a larger grid")
    return FrameSpec(m=m, n=n, k=k, pad=pad, bm=bm, bn=bn, gm=gm, gn=gn)


def make_frame(a: jnp.ndarray, spec: FrameSpec,
               boundary: Boundary | str) -> jnp.ndarray:
    """Embed ``a`` into a zero-initialised frame and refresh its ghosts.

    Runs once, before the loop — the only O(mn) staging cost of the
    persistent path.
    """
    frame = jnp.zeros(spec.shape, a.dtype)
    frame = jax.lax.dynamic_update_slice(frame, a, (spec.pad, spec.pad))
    return refresh_frame(frame, spec, boundary)


def frame_env(e: jnp.ndarray, spec: FrameSpec, boundary: Boundary | str,
              halo: bool = False) -> jnp.ndarray:
    """Stage a read-only ``env`` field for the frame, once, outside the loop.

    Without ``halo`` the field is block-rounded only (single-step kernels
    evaluate f strictly on interior cells).  With ``halo`` it gets the full
    frame layout — temporal blocking evaluates f on ghost cells too, and
    under a ``wrap`` boundary those evaluations must see the wrapped env
    (for the other models ghost outputs are re-asserted each sweep, so the
    ghost env values are inert and a zero ring suffices).
    """
    mi, ni = spec.interior
    if not halo:
        return jnp.pad(e, ((0, mi - spec.m), (0, ni - spec.n)))
    b = Boundary(boundary)
    return make_frame(e, spec, b if b is Boundary.WRAP else Boundary.ZERO)


def refresh_frame(frame: jnp.ndarray, spec: FrameSpec,
                  boundary: Boundary | str) -> jnp.ndarray:
    """Re-assert the ⊥ ghost ring around the (m, n) domain — O(m+n) cells.

    Column strips are filled from domain columns first, then row strips run
    full-width over the column-refreshed frame, so corners compose exactly
    like ``jnp.pad``'s axis-sequential modes.  Cells beyond the ``pad``-wide
    ring (deep round-up garbage) are never read by any domain dependency
    cone and are left untouched.
    """
    boundary = Boundary(boundary)
    p, m, n = spec.pad, spec.m, spec.n
    r0, r1 = p, p + m                      # domain rows in frame coords
    if boundary in (Boundary.ZERO, Boundary.NAN):
        fill = 0.0 if boundary is Boundary.ZERO else jnp.nan
        frame = frame.at[r0:r1, 0:p].set(fill)
        frame = frame.at[r0:r1, p + n:p + n + p].set(fill)
        frame = frame.at[0:p, :].set(fill)
        frame = frame.at[r1:r1 + p, :].set(fill)
        return frame
    if boundary is Boundary.REFLECT:
        # ghost col p-d mirrors domain col p+d (no edge repeat), as jnp.pad
        frame = frame.at[r0:r1, 0:p].set(
            jnp.flip(frame[r0:r1, p + 1:2 * p + 1], axis=1))
        frame = frame.at[r0:r1, p + n:p + n + p].set(
            jnp.flip(frame[r0:r1, p + n - 1 - p:p + n - 1], axis=1))
        frame = frame.at[0:p, :].set(
            jnp.flip(frame[p + 1:2 * p + 1, :], axis=0))
        frame = frame.at[r1:r1 + p, :].set(
            jnp.flip(frame[r1 - 1 - p:r1 - 1, :], axis=0))
        return frame
    if boundary is Boundary.WRAP:
        frame = frame.at[r0:r1, 0:p].set(frame[r0:r1, p + n - p:p + n])
        frame = frame.at[r0:r1, p + n:p + n + p].set(frame[r0:r1, p:2 * p])
        frame = frame.at[0:p, :].set(frame[r1 - p:r1, :])
        frame = frame.at[r1:r1 + p, :].set(frame[p:2 * p, :])
        return frame
    raise ValueError(boundary)


def unframe(frame: jnp.ndarray, spec: FrameSpec) -> jnp.ndarray:
    """Slice the (m, n) domain back out — once, after convergence."""
    p = spec.pad
    return frame[p:p + spec.m, p:p + spec.n]


# ---------------------------------------------------------------------------
# Lane-stacked frames — the 1:1 streaming deployment of the engine.
#
# A farm of convergence loops shares ONE done-masked while_loop whose
# carry is a stack of frames, one per lane *slot*.  The stack is
# allocated once per slot (zeros + first refill ≡ make_frame) and then
# *reused across stream items*: a finished lane's slot is refilled in
# place with the next item's (m, n) interior — an O(m·n) interior write
# plus the O(m+n) ghost refresh, with no jnp.pad, no re-allocation and
# no host round-trip of the frame.  Stale block-round-up cells from the
# previous item are inert by the same dependency-cone argument that lets
# :func:`refresh_frame` leave them untouched.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LaneFrameSpec:
    """Static geometry of a lane-stacked frame: ``lanes`` independent
    :class:`FrameSpec` frames carried as one (lanes, H, W) array."""

    lanes: int
    frame: FrameSpec

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.lanes, *self.frame.shape)


def alloc_lane_frames(lspec: LaneFrameSpec, dtype) -> jnp.ndarray:
    """Allocate the lane slots — once, at stream start (the only
    full-frame allocation of the streaming path)."""
    return jnp.zeros(lspec.shape, dtype)


def make_lane_frames(a: jnp.ndarray, spec: FrameSpec,
                     boundary: Boundary | str) -> jnp.ndarray:
    """Embed a (lanes, m, n) stack into lane frames (one-shot staging)."""
    return jax.vmap(lambda x: make_frame(x, spec, boundary))(a)


def refill_lane_frames(frames: jnp.ndarray, interiors: jnp.ndarray,
                       spec: FrameSpec,
                       boundary: Boundary | str) -> jnp.ndarray:
    """Refill lane slots in place with the next stream items' interiors.

    ``interiors`` is (lanes, m, n); the write lands at the domain offset
    of every slot via ONE dynamic_update_slice — O(lanes·m·n), strictly
    interior-sized — and the per-lane ghost rings are then re-asserted
    from the new interiors (O(lanes·(m+n))).  No pad primitive, no fresh
    frame allocation: under jit donation the slots update in place.
    """
    frames = jax.lax.dynamic_update_slice(
        frames, interiors.astype(frames.dtype), (0, spec.pad, spec.pad))
    return jax.vmap(lambda f: refresh_frame(f, spec, boundary))(frames)


def unframe_lanes(frames: jnp.ndarray, spec: FrameSpec) -> jnp.ndarray:
    """Slice every lane's (m, n) domain back out — once per round."""
    p = spec.pad
    return frames[:, p:p + spec.m, p:p + spec.n]


def refill_slot_frame(frames: jnp.ndarray, interior: jnp.ndarray,
                      idx, spec: FrameSpec,
                      boundary: Boundary | str) -> jnp.ndarray:
    """Refill ONE lane slot (dynamic index ``idx``) with the next item.

    The continuous-refill twin of :func:`refill_lane_frames`: the (m, n)
    interior lands at the slot's domain offset via one O(interior)
    dynamic_update_slice, then every lane's ghost ring is re-asserted —
    O(lanes·(m+n)), cheaper than slicing the one (H, W) frame out and
    back, and a no-op for untouched lanes (their ghosts already agree
    with their domains).  No pad, no full-frame copy, no re-framing; the
    same compilation serves every refill of the stream.
    """
    frames = jax.lax.dynamic_update_slice(
        frames, interior[None].astype(frames.dtype),
        (idx, spec.pad, spec.pad))
    return jax.vmap(lambda f: refresh_frame(f, spec, boundary))(frames)


def refill_slot_env(env_frames: jnp.ndarray, e: jnp.ndarray, idx,
                    spec: FrameSpec, boundary: Boundary | str,
                    halo: bool = False) -> jnp.ndarray:
    """Refill ONE lane's env slot (continuous twin of
    :func:`refill_lane_env`) — interior write at the dynamic index; with
    ``halo`` the ghost rings re-assert exactly as :func:`frame_env`."""
    if not halo:
        return jax.lax.dynamic_update_slice(
            env_frames, e[None].astype(env_frames.dtype), (idx, 0, 0))
    b = Boundary(boundary)
    ghost = b if b is Boundary.WRAP else Boundary.ZERO
    env_frames = jax.lax.dynamic_update_slice(
        env_frames, e[None].astype(env_frames.dtype),
        (idx, spec.pad, spec.pad))
    return jax.vmap(lambda f: refresh_frame(f, spec, ghost))(env_frames)


def refill_lanes_masked(frames: jnp.ndarray, take: jnp.ndarray,
                        interiors: jnp.ndarray, spec: FrameSpec,
                        boundary: Boundary | str) -> jnp.ndarray:
    """Masked BATCH refill of many lane slots in one shot — the fused
    chained-dispatch twin of :func:`refill_slot_frame`.

    ``take`` is a (lanes,) bool mask naming the slots that receive new
    interiors this segment boundary; unmasked lanes write their CURRENT
    interiors back (a no-op value-wise), so one O(lanes·interior)
    select + :func:`refill_lane_frames` replaces a host-driven sequence
    of per-slot refill dispatches.  The all-lane ghost refresh is
    idempotent for untouched lanes (their rings already agree with
    their domains) — the same argument the per-slot refill relies on.
    """
    p = spec.pad
    cur = frames[:, p:p + spec.m, p:p + spec.n]
    new = jnp.where(take[:, None, None], interiors.astype(frames.dtype),
                    cur)
    return refill_lane_frames(frames, new, spec, boundary)


def refill_lanes_env_masked(env_frames: jnp.ndarray, take: jnp.ndarray,
                            e: jnp.ndarray, spec: FrameSpec,
                            boundary: Boundary | str,
                            halo: bool = False) -> jnp.ndarray:
    """Masked batch env refill (chained twin of :func:`refill_slot_env`):
    taken slots receive the staged env interiors, the rest keep their
    own — one fused select + :func:`refill_lane_env` write."""
    if not halo:
        cur = env_frames[:, :spec.m, :spec.n]
        new = jnp.where(take[:, None, None], e.astype(env_frames.dtype),
                        cur)
        return refill_lane_env(env_frames, new, spec, boundary,
                               halo=False)
    p = spec.pad
    cur = env_frames[:, p:p + spec.m, p:p + spec.n]
    new = jnp.where(take[:, None, None], e.astype(env_frames.dtype), cur)
    return refill_lane_env(env_frames, new, spec, boundary, halo=True)


# ---------------------------------------------------------------------------
# Staging ring — the device-resident refill queue of the chained
# dispatch path.
#
# The host pre-device_puts the next K items' PREPPED interiors (and env
# leaves) into a (K, m, n) ring ahead of need; the fused
# segment+refill entry then hands finished slots their next occupants
# straight from the ring via a device-side read cursor — no fresh host
# transfer, no host round trip, at any segment boundary in steady
# state.  The ring holds logical (m, n) interiors, not frames: the
# masked refill above re-derives ghosts/round-up exactly as a
# host-admitted item would, so ring-seated and host-seated occupants
# are bit-identical.
# ---------------------------------------------------------------------------


def alloc_stage_ring(depth: int, entry_shape: tuple,
                     dtype) -> jnp.ndarray:
    """Allocate a depth-K staging ring of per-item entries — once, at
    stream start (host-side zeros; callers device_put with their own
    sharding)."""
    import numpy as np
    return np.zeros((depth, *entry_shape), dtype)


def stage_ring_write(ring: jnp.ndarray, entry: jnp.ndarray,
                     pos) -> jnp.ndarray:
    """Write one prepped entry at ring position ``pos`` (a traced
    scalar — one compilation serves every stage of the stream; under
    jit donation the ring updates in place)."""
    return jax.lax.dynamic_update_slice(
        ring, entry[None].astype(ring.dtype),
        (pos,) + (0,) * entry.ndim)


def lane_env_frames(e: jnp.ndarray, spec: FrameSpec,
                    boundary: Boundary | str,
                    halo: bool = False) -> jnp.ndarray:
    """Stage a (lanes, m, n) stack of per-lane env fields (one-shot)."""
    return jax.vmap(lambda x: frame_env(x, spec, boundary, halo))(e)


def alloc_lane_env(lspec: LaneFrameSpec, dtype, halo: bool = False):
    """Zero-allocate the per-lane env slots (layout matches
    :func:`frame_env`: block-rounded interior, or full frame with
    ``halo``)."""
    shape = lspec.frame.shape if halo else lspec.frame.interior
    return jnp.zeros((lspec.lanes, *shape), dtype)


def refill_lane_env(env_frames: jnp.ndarray, e: jnp.ndarray,
                    spec: FrameSpec, boundary: Boundary | str,
                    halo: bool = False) -> jnp.ndarray:
    """Refill the env slots for the next items — interior write only (the
    round-up/ghost cells are inert or re-asserted, as in
    :func:`frame_env`)."""
    if not halo:
        return jax.lax.dynamic_update_slice(
            env_frames, e.astype(env_frames.dtype), (0, 0, 0))
    b = Boundary(boundary)
    ghost = b if b is Boundary.WRAP else Boundary.ZERO
    env_frames = jax.lax.dynamic_update_slice(
        env_frames, e.astype(env_frames.dtype), (0, spec.pad, spec.pad))
    return jax.vmap(lambda f: refresh_frame(f, spec, ghost))(env_frames)


# ---------------------------------------------------------------------------
# Sharded frames — the 1:n deployment of the persistent-halo engine.
#
# Each shard carries its own frame; the ghost ring is re-asserted by a
# ppermute of O(pad·n) edge strips straight into the neighbour's ring
# (no concatenate, no jnp.pad, no full-block copy), with the global ⊥
# model applied locally only on shards that touch the global edge.  With
# temporal blocking (pad = k·T) one exchange feeds T fused sweeps —
# the communication-avoiding deep-halo schedule.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedFrameSpec:
    """Per-shard frame geometry plus its embedding in the device mesh.

    ``local`` is the shard's own :class:`FrameSpec` (``m``/``n`` are the
    LOCAL domain extents); ``axis_names[ax]`` is the mesh axis that
    decomposes array axis ``ax`` (None = not decomposed); ``sizes[ax]``
    its arity.  All functions below run *inside* ``shard_map``.
    """

    local: FrameSpec
    axis_names: tuple          # per array axis: mesh axis name or None
    sizes: tuple               # per array axis: mesh axis arity (1 if local)

    @property
    def decomposed(self):
        return tuple(n for n in self.axis_names if n is not None)


def sharded_frame_spec(lm: int, ln: int, part, *, k: int = 1,
                       block=(256, 256), sweeps: int = 1) -> ShardedFrameSpec:
    """Frame geometry for one shard of an (lm·P, ln·Q) global domain.

    ``part`` carries ``axis_names``/``array_axes`` and the mesh (a
    :class:`repro.sharding.specs.GridPartition`).  The ghost ring must fit
    inside the *local* domain (pad = k·sweeps < min(lm, ln)) — deep
    temporal blocking wants coarse shards.
    """
    names = [None, None]
    sizes = [1, 1]
    for name, ax in zip(part.axis_names, part.array_axes):
        if ax not in (0, 1):
            raise ValueError(f"sharded frames are 2-D; array axis {ax}")
        names[ax] = name
        sizes[ax] = part.mesh.shape[name]
    spec = frame_spec(lm, ln, k=k, block=block, sweeps=sweeps)
    return ShardedFrameSpec(local=spec, axis_names=tuple(names),
                            sizes=tuple(sizes))


def _axslice(frame, axis, lo, hi, olo, ohi):
    """Static strip frame[lo:hi] along ``axis``, [olo:ohi] along the other."""
    idx = [slice(olo, ohi)] * 2
    idx[axis] = slice(lo, hi)
    return frame[tuple(idx)]


def _axset(frame, axis, lo, hi, olo, ohi, val):
    idx = [slice(olo, ohi)] * 2
    idx[axis] = slice(lo, hi)
    return frame.at[tuple(idx)].set(val)


def _refresh_axis_local(frame, spec: FrameSpec, axis: int,
                        boundary: Boundary, olo: int, ohi: int):
    """Local ⊥ fill of one axis's ghost strips (non-decomposed axis),
    restricted to [olo:ohi] along the other axis."""
    p = spec.pad
    dom = spec.m if axis == 0 else spec.n
    d0, d1 = p, p + dom
    if boundary in (Boundary.ZERO, Boundary.NAN):
        fill = 0.0 if boundary is Boundary.ZERO else jnp.nan
        frame = _axset(frame, axis, 0, p, olo, ohi, fill)
        return _axset(frame, axis, d1, d1 + p, olo, ohi, fill)
    if boundary is Boundary.REFLECT:
        lo = jnp.flip(_axslice(frame, axis, d0 + 1, d0 + 1 + p, olo, ohi),
                      axis=axis)
        frame = _axset(frame, axis, 0, p, olo, ohi, lo)
        hi = jnp.flip(_axslice(frame, axis, d1 - 1 - p, d1 - 1, olo, ohi),
                      axis=axis)
        return _axset(frame, axis, d1, d1 + p, olo, ohi, hi)
    if boundary is Boundary.WRAP:
        frame = _axset(frame, axis, 0, p, olo, ohi,
                       _axslice(frame, axis, d1 - p, d1, olo, ohi))
        return _axset(frame, axis, d1, d1 + p, olo, ohi,
                      _axslice(frame, axis, d0, d0 + p, olo, ohi))
    raise ValueError(boundary)


def _refresh_axis_sharded(frame, sspec: ShardedFrameSpec, axis: int,
                          boundary: Boundary, olo: int, ohi: int):
    """ppermute one axis's ghost strips from the mesh neighbours.

    My last ``pad`` domain rows flow "down" into the next shard's leading
    ghost strip and vice versa — O(pad·width) cells on the wire, written
    straight into the ring.  Global-edge shards fill the missing side
    from the ⊥ model (constants / local mirror); WRAP closes the ring so
    the permutation is total.
    """
    spec = sspec.local
    name = sspec.axis_names[axis]
    nsh = sspec.sizes[axis]
    p = spec.pad
    dom = spec.m if axis == 0 else spec.n
    d0, d1 = p, p + dom

    fwd = [(i, i + 1) for i in range(nsh - 1)]
    bwd = [(i + 1, i) for i in range(nsh - 1)]
    if boundary is Boundary.WRAP:
        fwd.append((nsh - 1, 0))
        bwd.append((0, nsh - 1))

    from_prev = jax.lax.ppermute(
        _axslice(frame, axis, d1 - p, d1, olo, ohi), name, fwd)
    from_next = jax.lax.ppermute(
        _axslice(frame, axis, d0, d0 + p, olo, ohi), name, bwd)

    if boundary in (Boundary.ZERO, Boundary.WRAP):
        pass    # ppermute zero-fills non-receivers; WRAP perms are total
    else:
        me = jax.lax.axis_index(name)
        if boundary is Boundary.NAN:
            lo_fill = jnp.full_like(from_prev, jnp.nan)
            hi_fill = jnp.full_like(from_next, jnp.nan)
        elif boundary is Boundary.REFLECT:
            lo_fill = jnp.flip(
                _axslice(frame, axis, d0 + 1, d0 + 1 + p, olo, ohi),
                axis=axis)
            hi_fill = jnp.flip(
                _axslice(frame, axis, d1 - 1 - p, d1 - 1, olo, ohi),
                axis=axis)
        else:
            raise ValueError(boundary)
        from_prev = jnp.where(me == 0, lo_fill, from_prev)
        from_next = jnp.where(me == nsh - 1, hi_fill, from_next)

    frame = _axset(frame, axis, 0, p, olo, ohi, from_prev)
    return _axset(frame, axis, d1, d1 + p, olo, ohi, from_next)


def refresh_frame_sharded(frame: jnp.ndarray, sspec: ShardedFrameSpec,
                          boundary: Boundary | str) -> jnp.ndarray:
    """Re-assert a sharded frame's ghost ring — the loop-body exchange.

    Axis 0 strips span the domain's column extent; axis 1 strips then run
    the full frame height, so corner ghosts pick up the diagonal
    neighbour through the standard two-pass trick (and the local fills
    compose like ``jnp.pad``'s axis-sequential modes).  Decomposed axes
    exchange via ppermute; the rest fill locally.
    """
    boundary = Boundary(boundary)
    spec = sspec.local
    p, ln = spec.pad, spec.n
    H = spec.shape[0]
    extents = ((p, p + ln), (0, H))     # pass 1 restricted, pass 2 full
    for axis in (0, 1):
        olo, ohi = extents[axis]
        if sspec.axis_names[axis] is None:
            frame = _refresh_axis_local(frame, spec, axis, boundary,
                                        olo, ohi)
        else:
            frame = _refresh_axis_sharded(frame, sspec, axis, boundary,
                                          olo, ohi)
    return frame


def make_frame_sharded(a_local: jnp.ndarray, sspec: ShardedFrameSpec,
                       boundary: Boundary | str) -> jnp.ndarray:
    """Embed one shard's block into its frame and refresh the ghosts.

    Runs once per shard, inside ``shard_map``, before the loop.
    """
    spec = sspec.local
    frame = jnp.zeros(spec.shape, a_local.dtype)
    frame = jax.lax.dynamic_update_slice(frame, a_local,
                                         (spec.pad, spec.pad))
    return refresh_frame_sharded(frame, sspec, boundary)


def frame_env_sharded(e_local: jnp.ndarray, sspec: ShardedFrameSpec,
                      boundary: Boundary | str,
                      halo: bool = False) -> jnp.ndarray:
    """Stage one shard's slice of a read-only env field, once.

    With ``halo`` (temporal blocking) the ghost strips must hold the
    *neighbour's* env — intermediate sweeps evaluate f on ghost cells
    that are real domain cells of the adjacent shard — so the ring is
    filled by the same ppermute exchange; at global edges the env ghosts
    are inert (re-asserted each sweep) except under WRAP, which needs the
    torus continuation, exactly like :func:`frame_env`.
    """
    spec = sspec.local
    if not halo:
        mi, ni = spec.interior
        return jnp.pad(e_local, ((0, mi - spec.m), (0, ni - spec.n)))
    b = Boundary(boundary)
    frame = jnp.zeros(spec.shape, e_local.dtype)
    frame = jax.lax.dynamic_update_slice(frame, e_local,
                                         (spec.pad, spec.pad))
    return refresh_frame_sharded(
        frame, sspec, b if b is Boundary.WRAP else Boundary.ZERO)


def refill_lane_frames_sharded(frames: jnp.ndarray, interiors: jnp.ndarray,
                               sspec: ShardedFrameSpec,
                               boundary: Boundary | str) -> jnp.ndarray:
    """Per-shard lane-slot refill (runs inside ``shard_map``): each lane's
    LOCAL interior is written in place and the ghost rings re-assert via
    the lane-batched ppermute exchange — the sharded twin of
    :func:`refill_lane_frames`."""
    p = sspec.local.pad
    frames = jax.lax.dynamic_update_slice(
        frames, interiors.astype(frames.dtype), (0, p, p))
    return jax.vmap(
        lambda f: refresh_frame_sharded(f, sspec, boundary))(frames)


def refill_lane_env_sharded(env_frames: jnp.ndarray, e: jnp.ndarray,
                            sspec: ShardedFrameSpec,
                            boundary: Boundary | str,
                            halo: bool = False) -> jnp.ndarray:
    """Sharded twin of :func:`refill_lane_env` (inside ``shard_map``)."""
    if not halo:
        return jax.lax.dynamic_update_slice(
            env_frames, e.astype(env_frames.dtype), (0, 0, 0))
    b = Boundary(boundary)
    ghost = b if b is Boundary.WRAP else Boundary.ZERO
    p = sspec.local.pad
    env_frames = jax.lax.dynamic_update_slice(
        env_frames, e.astype(env_frames.dtype), (0, p, p))
    return jax.vmap(
        lambda f: refresh_frame_sharded(f, sspec, ghost))(env_frames)


def refill_slot_frame_sharded(frames: jnp.ndarray, interior: jnp.ndarray,
                              li, owns, sspec: ShardedFrameSpec,
                              boundary: Boundary | str) -> jnp.ndarray:
    """Owner-masked refill of ONE lane slot of a SHARDED frame stack
    (runs inside ``shard_map`` — the continuous-refill twin of
    :func:`refill_lane_frames_sharded`).

    ``interior`` is this shard's LOCAL (lm, ln) block of the next item;
    ``li`` is the slot's local lane index (pre-clipped into range) and
    ``owns`` masks the write — every lane shard executes the same
    O(interior) read/select/write so the program stays SPMD-uniform, but
    only the owner's slot actually changes (non-owners write their
    current values back).  The ghost rings then re-assert through the
    SAME lane-batched edge-strip ppermute the loop body uses — O(pad·n)
    strips along the spatial axes only; nothing crosses the lane axis.
    No pad, no full-frame copy, one compilation per stream.
    """
    spec = sspec.local
    p = spec.pad
    cur = jax.lax.dynamic_slice(frames, (li, p, p), (1, spec.m, spec.n))
    new = jnp.where(owns, interior[None].astype(frames.dtype), cur)
    frames = jax.lax.dynamic_update_slice(frames, new, (li, p, p))
    return jax.vmap(
        lambda f: refresh_frame_sharded(f, sspec, boundary))(frames)


def refill_slot_env_sharded(env_frames: jnp.ndarray, e: jnp.ndarray,
                            li, owns, sspec: ShardedFrameSpec,
                            boundary: Boundary | str,
                            halo: bool = False) -> jnp.ndarray:
    """Owner-masked single-slot env refill inside ``shard_map`` (the
    continuous twin of :func:`refill_lane_env_sharded`): the owner lane
    shard's slot takes this shard's LOCAL env block; with ``halo`` the
    ghost strips re-assert via the ppermute exchange as
    :func:`frame_env_sharded`."""
    spec = sspec.local
    if not halo:
        cur = jax.lax.dynamic_slice(env_frames, (li, 0, 0),
                                    (1, spec.m, spec.n))
        new = jnp.where(owns, e[None].astype(env_frames.dtype), cur)
        return jax.lax.dynamic_update_slice(env_frames, new, (li, 0, 0))
    b = Boundary(boundary)
    ghost = b if b is Boundary.WRAP else Boundary.ZERO
    p = spec.pad
    cur = jax.lax.dynamic_slice(env_frames, (li, p, p),
                                (1, spec.m, spec.n))
    new = jnp.where(owns, e[None].astype(env_frames.dtype), cur)
    env_frames = jax.lax.dynamic_update_slice(env_frames, new, (li, p, p))
    return jax.vmap(
        lambda f: refresh_frame_sharded(f, sspec, ghost))(env_frames)


def shard_domain_bounds(sspec: ShardedFrameSpec) -> jnp.ndarray:
    """(1, 4) int32 ``[row_lo, row_hi, col_lo, col_hi]`` of the GLOBAL
    domain in this shard's frame coordinates.

    Sides that continue into a neighbour shard get ±2^30 sentinels so the
    kernel's per-sweep ⊥ re-assertion never fires there — interior ghost
    cells are real cells of the adjacent shard and must evolve freely
    (the shrinking-window containment argument).  Traced (axis_index
    dependent): feeds the kernel through SMEM.
    """
    spec = sspec.local
    big = jnp.int32(2 ** 30)
    p = spec.pad
    vals = []
    for ax, dom in enumerate((spec.m, spec.n)):
        name = sspec.axis_names[ax]
        if name is None:
            lo = jnp.int32(p)
            hi = jnp.int32(p + dom)
        else:
            me = jax.lax.axis_index(name)
            nsh = sspec.sizes[ax]
            lo = jnp.where(me == 0, jnp.int32(p), -big)
            hi = jnp.where(me == nsh - 1, jnp.int32(p + dom), big)
        vals += [lo, hi]
    return jnp.stack(vals).astype(jnp.int32).reshape(1, 4)
