"""Persistent halo frames — the device-resident grid layout of the engine.

The paper's central performance claim is *device memory persistence*
(§3.3): the grid never leaves device memory between iterations.  The
original realisation still paid two full-grid passes per iteration on the
hot path — a ``jnp.pad`` before every sweep and an ``out[:m, :n]`` slice
after it.  This module hoists both out of the loop by making the *framed*
array the canonical loop-carried representation:

    ┌──────────────────────────────┐
    │ ghost ring (pad = k·T wide)  │   frame shape: (gm·bm + 2·pad,
    │  ┌────────────┬───────────┐  │                 gn·bn + 2·pad)
    │  │ domain     │ round-up  │  │
    │  │ (m, n)     │ (inert)   │  │   domain at [pad:pad+m, pad:pad+n]
    │  ├────────────┴───────────┤  │
    │  │ block round-up (inert) │  │
    │  └────────────────────────┘  │
    └──────────────────────────────┘

The frame is built **once** before the ``while_loop`` (:func:`make_frame`),
kernels read and write it directly, and only the ghost ring — O(m+n) edge
cells, not O(mn) — is re-asserted between sweeps (:func:`refresh_frame`).
The domain is sliced back out exactly once after convergence
(:func:`unframe`).

Boundary semantics match ``jnp.pad`` axis-sequential composition (corners
are boundary-of-boundary), which is what :class:`repro.core.stencil.
TapAccessor` and the formal semantics realise — so frames are drop-in for
the per-iteration padding they replace.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .semantics import Boundary


def ceil_mul(x: int, q: int) -> int:
    """Round ``x`` up to the next multiple of ``q``."""
    return -(-x // q) * q


@dataclasses.dataclass(frozen=True)
class FrameSpec:
    """Static geometry of a persistent halo frame."""

    m: int          # logical domain rows
    n: int          # logical domain cols
    k: int          # stencil radius per sweep
    pad: int        # ghost-ring width (= k·sweeps for temporal blocking)
    bm: int         # tile rows
    bn: int         # tile cols
    gm: int         # grid rows
    gn: int         # grid cols

    @property
    def interior(self) -> tuple[int, int]:
        """Block-rounded interior (domain + round-up)."""
        return self.gm * self.bm, self.gn * self.bn

    @property
    def shape(self) -> tuple[int, int]:
        mi, ni = self.interior
        return mi + 2 * self.pad, ni + 2 * self.pad


def frame_spec(m: int, n: int, *, k: int = 1, block=(256, 256),
               sweeps: int = 1) -> FrameSpec:
    """Build the frame geometry for an (m, n) domain.

    ``block`` is clipped to TPU-friendly rounded domain sizes (sublane
    multiple of 8, lane multiple of 128) exactly like the one-shot kernels;
    ``sweeps`` > 1 widens the ghost ring for temporal blocking.
    """
    bm = min(block[0], ceil_mul(m, 8))
    bn = min(block[1], ceil_mul(n, 128))
    gm, gn = -(-m // bm), -(-n // bn)
    pad = k * sweeps
    if pad >= min(m, n):
        raise ValueError(
            f"halo width k*sweeps={pad} must be < min(m, n)={min(m, n)}; "
            f"lower `unroll` or use a larger grid")
    return FrameSpec(m=m, n=n, k=k, pad=pad, bm=bm, bn=bn, gm=gm, gn=gn)


def make_frame(a: jnp.ndarray, spec: FrameSpec,
               boundary: Boundary | str) -> jnp.ndarray:
    """Embed ``a`` into a zero-initialised frame and refresh its ghosts.

    Runs once, before the loop — the only O(mn) staging cost of the
    persistent path.
    """
    frame = jnp.zeros(spec.shape, a.dtype)
    frame = jax.lax.dynamic_update_slice(frame, a, (spec.pad, spec.pad))
    return refresh_frame(frame, spec, boundary)


def frame_env(e: jnp.ndarray, spec: FrameSpec, boundary: Boundary | str,
              halo: bool = False) -> jnp.ndarray:
    """Stage a read-only ``env`` field for the frame, once, outside the loop.

    Without ``halo`` the field is block-rounded only (single-step kernels
    evaluate f strictly on interior cells).  With ``halo`` it gets the full
    frame layout — temporal blocking evaluates f on ghost cells too, and
    under a ``wrap`` boundary those evaluations must see the wrapped env
    (for the other models ghost outputs are re-asserted each sweep, so the
    ghost env values are inert and a zero ring suffices).
    """
    mi, ni = spec.interior
    if not halo:
        return jnp.pad(e, ((0, mi - spec.m), (0, ni - spec.n)))
    b = Boundary(boundary)
    return make_frame(e, spec, b if b is Boundary.WRAP else Boundary.ZERO)


def refresh_frame(frame: jnp.ndarray, spec: FrameSpec,
                  boundary: Boundary | str) -> jnp.ndarray:
    """Re-assert the ⊥ ghost ring around the (m, n) domain — O(m+n) cells.

    Column strips are filled from domain columns first, then row strips run
    full-width over the column-refreshed frame, so corners compose exactly
    like ``jnp.pad``'s axis-sequential modes.  Cells beyond the ``pad``-wide
    ring (deep round-up garbage) are never read by any domain dependency
    cone and are left untouched.
    """
    boundary = Boundary(boundary)
    p, m, n = spec.pad, spec.m, spec.n
    r0, r1 = p, p + m                      # domain rows in frame coords
    if boundary in (Boundary.ZERO, Boundary.NAN):
        fill = 0.0 if boundary is Boundary.ZERO else jnp.nan
        frame = frame.at[r0:r1, 0:p].set(fill)
        frame = frame.at[r0:r1, p + n:p + n + p].set(fill)
        frame = frame.at[0:p, :].set(fill)
        frame = frame.at[r1:r1 + p, :].set(fill)
        return frame
    if boundary is Boundary.REFLECT:
        # ghost col p-d mirrors domain col p+d (no edge repeat), as jnp.pad
        frame = frame.at[r0:r1, 0:p].set(
            jnp.flip(frame[r0:r1, p + 1:2 * p + 1], axis=1))
        frame = frame.at[r0:r1, p + n:p + n + p].set(
            jnp.flip(frame[r0:r1, p + n - 1 - p:p + n - 1], axis=1))
        frame = frame.at[0:p, :].set(
            jnp.flip(frame[p + 1:2 * p + 1, :], axis=0))
        frame = frame.at[r1:r1 + p, :].set(
            jnp.flip(frame[r1 - 1 - p:r1 - 1, :], axis=0))
        return frame
    if boundary is Boundary.WRAP:
        frame = frame.at[r0:r1, 0:p].set(frame[r0:r1, p + n - p:p + n])
        frame = frame.at[r0:r1, p + n:p + n + p].set(frame[r0:r1, p:2 * p])
        frame = frame.at[0:p, :].set(frame[r1 - p:r1, :])
        frame = frame.at[r1:r1 + p, :].set(frame[p:2 * p, :])
        return frame
    raise ValueError(boundary)


def unframe(frame: jnp.ndarray, spec: FrameSpec) -> jnp.ndarray:
    """Slice the (m, n) domain back out — once, after convergence."""
    p = spec.pad
    return frame[p:p + spec.m, p:p + spec.n]
