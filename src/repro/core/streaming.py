"""Stream-parallel tier: pipe / farm / ofarm, and 1:1 vs 1:n deployments.

The paper's two-tier model [1]: data-parallel patterns (stencil, reduce,
Loop-of-stencil-reduce) nest inside stream-parallel ones (pipe, farm).  The
experiments use exactly two compositions:

    pipe(read, sobel, write)                       (§4.2)
    pipe(read, detect, ofarm(restore), write)      (§4.3)

JAX realisation:

* ``pipe``  — function composition per item, with *async dispatch* giving
  pipeline overlap between host-side stages (read/write) and device compute
  (the OpenCL-events analogue).
* ``farm``  — independent items processed concurrently.  On-device this is
  ``vmap`` (1:1 mode: many items, one device program each lane) or batch
  sharding over the ``data`` mesh axis (many items across devices).
* ``ofarm`` — order-preserving farm; JAX's batched execution is
  deterministic and order-preserving by construction, so ofarm == farm with
  the ordering guarantee documented.

Because :class:`repro.core.pattern.LoopOfStencilReduce` is done-masked, a
farm of convergence loops is safe: each lane runs to its own trip count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Iterator, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipe(*stages: Callable) -> Callable:
    """pipe(a, b, ...) — functional composition b∘a, per stream item."""
    def run(x):
        for s in stages:
            x = s(x)
        return x
    return run


def farm(worker: Callable, *, lanes_axis: int = 0) -> Callable:
    """1:1 mode — apply ``worker`` to every item of a stacked stream batch.

    ``worker`` may itself be a Loop-of-stencil-reduce ``run``; done-masking
    makes the vmapped while_loop per-lane correct.
    """
    return jax.vmap(worker, in_axes=lanes_axis, out_axes=lanes_axis)


def ofarm(worker: Callable, *, lanes_axis: int = 0) -> Callable:
    """Order-preserving farm.  vmap is deterministic + order-preserving, so
    this is ``farm`` with the paper's ordering contract made explicit."""
    return farm(worker, lanes_axis=lanes_axis)


def sharded_farm(worker: Callable, mesh: Mesh, axis: str = "data") -> Callable:
    """Farm whose lanes are spread over a mesh axis (items across devices).

    The jit wrapper is built ONCE here — constructing ``jax.jit(vw)``
    inside ``run`` would mint a fresh wrapper (and compilation cache) per
    call, retracing the worker on every batch (regression-tested by
    trace counting in tests/core/test_streaming.py).
    """
    jvw = jax.jit(jax.vmap(worker))
    sharding = NamedSharding(mesh, P(axis))

    def run(batch):
        batch = jax.device_put(batch, sharding)
        return jvw(batch)
    return run


@dataclasses.dataclass
class StreamRunner:
    """Host-side streaming driver: feeds batches of stream items through a
    (jitted) worker with double-buffered async dispatch.

    This is the runtime glue of the paper's streaming experiments: while the
    device processes batch i, the host 'read' stage prepares batch i+1 and
    the 'write' stage consumes batch i-1 (JAX async dispatch provides the
    overlap; ``block_until_ready`` only at the sink).
    """

    worker: Callable                  # jitted device stage
    source: Callable[[], Iterator]    # read stage: yields host items
    sink: Callable[[Any], None]       # write stage: consumes results
    batch: int = 1

    def run(self) -> int:
        it = self.source()
        n = 0
        inflight = None
        while True:
            chunk = []
            for _ in range(self.batch):
                try:
                    chunk.append(next(it))
                except StopIteration:
                    break
            if not chunk and inflight is None:
                break
            nxt = None
            if chunk:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *chunk) if len(chunk) > 1 \
                    else jax.tree.map(lambda x: jnp.asarray(x)[None], chunk[0])
                nxt = self.worker(stacked)   # async dispatch
            if inflight is not None:
                for item in _unstack(inflight):
                    self.sink(item)
                    n += 1
            inflight = nxt
            if not chunk:
                break
        if inflight is not None:
            for item in _unstack(inflight):
                self.sink(item)
                n += 1
        return n


def _unstack(batched):
    leaves = jax.tree.leaves(batched)
    if not leaves:
        return []
    b = leaves[0].shape[0]
    return [jax.tree.map(lambda x: x[i], batched) for i in range(b)]
