"""Stream-parallel tier: pipe / farm / ofarm on the persistent engine.

The paper's two-tier model [1]: data-parallel patterns (stencil, reduce,
Loop-of-stencil-reduce) nest inside stream-parallel ones (pipe, farm).  The
experiments use exactly two compositions:

    pipe(read, sobel, write)                       (§4.2)
    pipe(read, detect, ofarm(restore), write)      (§4.3)

JAX realisation, two tiers of its own:

* the *generic* tier — :func:`pipe`, :func:`farm`, :func:`ofarm`,
  :func:`sharded_farm`, :class:`StreamRunner` — maps arbitrary workers
  over stream items (vmap / batch sharding / async double-buffered
  dispatch).  Kept for map-style stages (Sobel) and as the reference
  path; every batch re-enters the worker from the host.

* the *engine* tier — :class:`FarmEngine` — the FastFlow-style
  persistent-device deployment for farms whose worker is a
  Loop-of-stencil-reduce.  L lane *slots* hold persistent halo frames
  (:mod:`repro.core.frames`), the whole farm advances as ONE done-masked
  ``while_loop`` over the stacked (lanes, frame) carry
  (:meth:`repro.core.pattern.LoopOfStencilReduce.farm_run` semantics),
  and a finished round's slots are *refilled in place* with the next
  items' interiors — no re-pad, no re-allocation, no host round-trip of
  the frame; only new input and extracted output cross the host
  boundary, exactly the paper's device-buffer-persistence-across-stream-
  items design point.  Host-side double buffering (the read stage
  prepares round i+1 and the write stage drains round i-1 while the
  device runs round i) rides on JAX async dispatch.

  The engine tier's *continuous* mode (``run(..., continuous=True)``)
  removes the round barrier itself: the while becomes a bounded
  early-exit segment loop, finished lanes hand their slots to the next
  items mid-flight (the FastFlow farm's worker refill), and results
  emit in completion order — throughput independent of the per-item
  trip-count spread.  ``stats["wasted_lane_steps"]`` counts the
  done-masked sweeps the barrier would have burned.  Continuous mode
  covers EVERY deployment the round path does, including the composed
  lanes × spatial ``pallas-sharded`` farm: there the refill scatters
  each finished lane's LOCAL interior blocks inside ``shard_map`` with
  owner masking (:func:`repro.core.frames.refill_slot_frame_sharded`)
  and the ghost rings re-assert through the same O(k·n) edge-strip
  ppermute the loop body uses — per-shard segments, no cross-lane
  collectives.

``ofarm`` ordering comes for free in the round modes: lanes are
positional and batched execution is deterministic.  Continuous mode
emits :class:`StreamResult` (completion order, stream index attached)
instead.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from itertools import islice
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .reduce import HEALTH_CONVERGED, HEALTH_DIVERGED, HEALTH_POISONED


class NonFiniteItemError(ValueError):
    """A stream item carried NaN/Inf leaves at the prep boundary.  Round
    mode raises it (loudly, at admission — not as an opaque NaN cascade
    ten sweeps downstream); continuous mode routes the item to the
    dead-letter list with ``status="rejected"`` and keeps streaming."""


def item_status(hw: int, iters: int, max_iters: int) -> str:
    """The streaming status taxonomy of one finished item, from its
    packed health word + trip count: ``ok`` (condition fired, no fault),
    ``poisoned`` (NaN/Inf reduce), ``nonconverged`` (sentinel divergence
    quarantine), ``timed_out`` (iteration budget exhausted)."""
    hw = int(hw)
    if hw & HEALTH_POISONED:
        return "poisoned"
    if hw & HEALTH_DIVERGED:
        return "nonconverged"
    if hw & HEALTH_CONVERGED:
        return "ok"
    return "timed_out" if int(iters) >= max_iters else "nonconverged"


def pipe(*stages: Callable) -> Callable:
    """pipe(a, b, ...) — functional composition b∘a, per stream item."""
    def run(x):
        for s in stages:
            x = s(x)
        return x
    return run


def farm(worker: Callable, *, lanes_axis: int = 0) -> Callable:
    """1:1 mode, generic tier — apply ``worker`` to every item of a
    stacked stream batch via vmap.

    ``worker`` may itself be a Loop-of-stencil-reduce ``run``; done-
    masking makes the vmapped while_loop per-lane correct.  For a farm of
    loops on the persistent engine (one kernel launch per sweep for the
    whole farm, lane slots reusable across stream items) use
    :meth:`~repro.core.pattern.LoopOfStencilReduce.farm_run` /
    :class:`FarmEngine` instead.
    """
    return jax.vmap(worker, in_axes=lanes_axis, out_axes=lanes_axis)


def ofarm(worker: Callable, *, lanes_axis: int = 0) -> Callable:
    """Order-preserving farm.  vmap is deterministic + order-preserving, so
    this is ``farm`` with the paper's ordering contract made explicit."""
    return farm(worker, lanes_axis=lanes_axis)


def sharded_farm(worker: Callable, mesh: Mesh, axis: str = "data") -> Callable:
    """Generic-tier farm whose lanes are spread over a mesh axis.

    The jit wrapper is built ONCE here — constructing ``jax.jit(vw)``
    inside ``run`` would mint a fresh wrapper (and compilation cache) per
    call, retracing the worker on every batch (regression-tested by
    trace counting in tests/core/test_streaming.py).  Every batch still
    ``device_put``s its items and re-enters the worker from the host —
    :class:`FarmEngine` (with ``mesh=``) is the engine-tier replacement
    that keeps per-lane halo frames device-resident across batches.
    """
    jvw = jax.jit(jax.vmap(worker))
    sharding = NamedSharding(mesh, P(axis))

    def run(batch):
        batch = jax.device_put(batch, sharding)
        return jvw(batch)
    return run


@dataclasses.dataclass
class StreamRunner:
    """Host-side streaming driver: feeds batches of stream items through a
    (jitted) worker with double-buffered async dispatch.

    This is the runtime glue of the paper's streaming experiments: while the
    device processes batch i, the host 'read' stage prepares batch i+1 and
    the 'write' stage consumes batch i-1 (JAX async dispatch provides the
    overlap; ``block_until_ready`` only at the sink).

    Generic tier: the worker re-enters from the host per batch.  Farms of
    convergence loops should ride :class:`FarmEngine`, which shares this
    host protocol but keeps the loop state (the halo frames) on device
    between batches.
    """

    worker: Callable                  # jitted device stage
    source: Callable[[], Iterator]    # read stage: yields host items
    sink: Callable[[Any], None]       # write stage: consumes results
    batch: int = 1

    def run(self) -> int:
        it = self.source()
        n = 0
        inflight = None
        while True:
            chunk = []
            for _ in range(self.batch):
                try:
                    chunk.append(next(it))
                except StopIteration:
                    break
            if not chunk and inflight is None:
                break
            nxt = None
            if chunk:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *chunk) if len(chunk) > 1 \
                    else jax.tree.map(lambda x: jnp.asarray(x)[None], chunk[0])
                nxt = self.worker(stacked)   # async dispatch
            if inflight is not None:
                for item in self._unstack(inflight):
                    self.sink(item)
                    n += 1
            inflight = nxt
            if not chunk:
                break
        if inflight is not None:
            for item in self._unstack(inflight):
                self.sink(item)
                n += 1
        return n

    @staticmethod
    def _unstack(batched) -> Iterator:
        """Yield per-item views of a stacked result LAZILY — the sink runs
        on item i before item i+1 is sliced, so a sink that consumes (or
        discards) items incrementally never holds the whole batch of
        slices at once."""
        leaves = jax.tree.leaves(batched)
        if not leaves:
            return
        for i in range(leaves[0].shape[0]):
            yield jax.tree.map(lambda x: x[i], batched)


# ---------------------------------------------------------------------------
# FarmEngine — the lane-resident streaming engine (engine tier).
# ---------------------------------------------------------------------------


def _default_prep(item):
    """Identity prep.  A bare array IS the loop input; a TUPLE stream
    item carries its own read-only env fields along — ``(a, *env)`` —
    for streams whose env is produced upstream (an external detector)
    rather than derived from the item on device."""
    if isinstance(item, tuple):
        return item[0], tuple(item[1:])
    return item, ()


def _as_item(item):
    """Normalise one stream item to ndarray leaves (tuple items keep
    their env leaves alongside the main array)."""
    if isinstance(item, (tuple, list)):
        return tuple(np.asarray(leaf) for leaf in item)
    return np.asarray(item)


def _item_leaves(item) -> tuple:
    return item if isinstance(item, tuple) else (item,)


def _item_nbytes(item) -> int:
    return sum(leaf.nbytes for leaf in _item_leaves(item))


def _stack_items(batch: list):
    """Stack a list of (normalised) stream items leaf-wise."""
    batch = [_as_item(it) for it in batch]
    if isinstance(batch[0], tuple):
        return tuple(np.stack([it[j] for it in batch])
                     for j in range(len(batch[0])))
    return np.stack(batch)


@dataclasses.dataclass
class StreamResult:
    """One continuous-mode emission: the item's stream position plus the
    fields of :class:`~repro.core.pattern.LoopResult`.  Continuous farms
    emit in COMPLETION order (that is the point — a 1-sweep item must not
    wait behind a 200-sweep straggler), so the index carries the ofarm
    identity the positional contract used to.

    ``status`` is the failure-semantics verdict (see :func:`item_status`
    plus ``"rejected"`` for items that failed the admission-time finite
    check); ``attempts`` counts slot occupations (> 1 means the item was
    retried on a fresh slot after a non-ok finish).  ``error`` carries
    the host-side exception text when a result had to be degraded (a
    raising sink — the result lives on ``dead_letter`` instead of being
    lost with the stream)."""
    index: int
    a: Any
    reduced: Any
    iters: Any
    status: str = "ok"
    attempts: int = 1
    error: Optional[str] = None


@dataclasses.dataclass
class FarmEngine:
    """Lane-resident streaming farm: persistent-frame lane slots with
    device-side slot refill and host-side double buffering.

    ``loop`` is the per-item worker (a :class:`~repro.core.pattern.
    LoopOfStencilReduce`); ``lanes`` is the number of device-resident
    slots.  Two execution modes share the slots:

    * **Round-based** (default): L items are staged into the slots (an
      O(interior) in-place refill — the frames were allocated once, at
      stream start), the whole farm runs as ONE done-masked while_loop
      to each lane's own trip count, and the (m, n) results are sliced
      out.  A round completes when its *slowest* lane converges — fast
      lanes idle behind the straggler (their done-masked sweeps are
      counted in ``stats["wasted_lane_steps"]``).

    * **Continuous** (``run(..., continuous=True)``): the while_loop
      becomes a *segmented* loop (:meth:`~repro.core.pattern.
      LoopOfStencilReduce.lane_segment`) that returns to the dispatcher
      as soon as any lane converges (bounded by ``segment`` body steps);
      the dispatcher refills ONLY the finished lanes' slots in place —
      one O(interior) dynamic_update_slice each, no re-pad, no
      re-framing — and resumes the SAME carry.  Results are emitted as
      :class:`StreamResult` (completion order, stream index attached)
      the moment their lane finishes, and throughput becomes independent
      of the trip-count spread.  One compilation serves every segment
      and every refill of the stream.

    ``prep`` optionally maps a raw stream item to ``(a0, env_tuple)`` on
    device (vmapped over lanes in round mode, per item in continuous
    mode) — the farm's per-item read stage (e.g. the §4.3 detection pass
    feeding restoration).  ``prep`` runs on the WHOLE item before any
    spatial decomposition, so stencil-shaped preps (halo-dependent, like
    AMF detection) see their full neighbourhood even under the composed
    sharded deployment.  Stream items may also be TUPLES
    ``(a, *env_items)`` carrying externally produced env fields; the
    default prep splits them, a user ``prep`` receives the whole tuple.
    Every leaf — main and env alike — is shape/dtype-guarded against
    mid-stream drift (build a fresh engine per item geometry).

    Deployments:

    * ``mesh=None`` — single device, lanes on the vmapped kernel grid.
    * ``mesh=`` with a single-device backend ("jnp"/"pallas"/
      "pallas-multistep") — lanes spread over ``mesh[lane_axis]`` via
      ``shard_map`` (the 1:1 mode across devices: each shard owns
      lanes/P slots and its own while trip count — no collectives cross
      the lane axis).  Both modes support this deployment.
    * ``loop.backend == "pallas-sharded"`` — the two-tier composition:
      lanes over ``lane_axis`` × each lane's frame spatially decomposed
      over ``loop.partition``'s axes (all on the same ``mesh``), with the
      lane-batched ppermute ghost exchange inside the shared while body.
      Both modes run here too: continuous refill scatters a finished
      lane's LOCAL interior blocks per shard (owner-masked, inside
      ``shard_map``) and re-asserts the ghosts through the same
      edge-strip ppermute — the segmented while runs per lane shard with
      no cross-lane collectives.

    Use :meth:`run` for the full source→sink stream protocol, or
    :meth:`round` to push one stacked batch through the slots.
    """

    loop: Any                          # LoopOfStencilReduce worker
    lanes: int = 4
    prep: Optional[Callable] = None    # item -> (a0, env tuple), on device
    mesh: Optional[Mesh] = None
    lane_axis: str = "data"
    segment: int = 16                  # continuous mode: max body steps
                                       # between dispatcher check-ins
    max_attempts: int = 1              # slot occupations per item: a
                                       # non-ok item re-enters the retry
                                       # queue (fresh slot) until this
                                       # cap, then dead-letters
    slot_patience: int = 3             # consecutive non-ok finishes on
                                       # one slot before the slot itself
                                       # is quarantined (retired from
                                       # the refill rotation)
    check_finite: bool = True          # admission-time NaN/Inf guard on
                                       # every item leaf (host-side
                                       # O(item) scan)
    chained: bool = True               # continuous mode: chain segments
                                       # through the fused segment+refill
                                       # entry (device staging ring, no
                                       # blocking host sync in steady
                                       # state); False restores the
                                       # classic dispatch→sync→per-slot-
                                       # refill loop.  The composed
                                       # pallas-sharded deployment always
                                       # runs the classic loop (its
                                       # fixed-step segments have no
                                       # early exit to chain past, and
                                       # its refill must stay inside the
                                       # spatial shard_map).
    stage_depth: Optional[int] = None  # staging-ring depth K (chained
                                       # mode); None = max(2*lanes, 2)

    def __post_init__(self):
        loop = self.loop
        if loop.state_init is not None:
            raise ValueError("FarmEngine does not support the -s variant "
                             "(per-lane loop states are ambiguous)")
        if loop.mode != "taps" and loop.backend != "jnp":
            raise ValueError("FarmEngine needs mode='taps' on the pallas "
                             f"backends; got mode={loop.mode!r}")
        if self.mesh is not None:
            if self.lane_axis not in self.mesh.axis_names:
                raise ValueError(
                    f"lane_axis {self.lane_axis!r} not in mesh axes "
                    f"{self.mesh.axis_names}")
            if self.lanes % self.mesh.shape[self.lane_axis]:
                raise ValueError(
                    f"lanes={self.lanes} must divide evenly over mesh "
                    f"axis {self.lane_axis!r} "
                    f"(size {self.mesh.shape[self.lane_axis]})")
        if loop.backend == "pallas-sharded":
            if self.mesh is None:
                raise ValueError(
                    "backend='pallas-sharded' lanes need mesh= (carrying "
                    "the lane axis AND the partition's spatial axes)")
            part = loop.partition
            for name in part.axis_names:
                if name == self.lane_axis:
                    raise ValueError(
                        f"partition axis {name!r} collides with "
                        f"lane_axis; use distinct mesh axes for lanes "
                        "and the spatial decomposition")
                if name not in self.mesh.axis_names:
                    raise ValueError(
                        f"partition axis {name!r} missing from mesh "
                        f"axes {self.mesh.axis_names}")
        if self.segment < 1:
            raise ValueError(f"segment must be >= 1; got {self.segment}")
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1; got {self.max_attempts}")
        if self.slot_patience < 1:
            raise ValueError(
                f"slot_patience must be >= 1; got {self.slot_patience}")
        if self.stage_depth is not None and self.stage_depth < 1:
            raise ValueError(
                f"stage_depth must be >= 1; got {self.stage_depth}")
        self.dead_letter: list = []     # items that exhausted retries /
                                        # were rejected at admission
                                        # (their emitted StreamResults)
        self._prep1 = self.prep or _default_prep
        self._vprep = jax.vmap(self._prep1)
        self._bound = False
        self._mode = None               # "round" | "continuous" once used
        self._frames = None
        self._env_frames = ()
        # one jit wrapper per entry point for the stream's lifetime:
        # every round / segment / refill hits the same compilation
        # (trace-count regression-tested); the slot buffers are donated
        # so refills update them in place
        self._round_fn = jax.jit(self._round_impl, donate_argnums=(0, 1))
        self._segment_fn = jax.jit(self._segment_entry,
                                   donate_argnums=(0, 1, 2, 3, 4, 5))
        self._refill_fn = jax.jit(self._refill_impl,
                                  donate_argnums=(0, 1, 2, 3, 4, 5))
        self._restore_fn = jax.jit(self._restore_impl,
                                   donate_argnums=(0, 1, 2, 3, 4, 5))
        self._extract_fn = jax.jit(self._extract_impl)
        # the chained dispatch path: ONE fused segment + masked batch
        # refill + emission-capture entry (slot buffers AND the staging
        # ring donated — everything updates in place, segment to
        # segment, with only async metadata reads on the host side)
        self._chain_fn = jax.jit(self._chain_entry,
                                 donate_argnums=(0, 1, 2, 3, 4, 5, 6, 7))
        self._stage_fn = jax.jit(self._stage_impl, donate_argnums=(0, 1))
        self._waste_buf: list = []      # (waste, iters, hw, count)
                                        # device tuples, converted
                                        # lazily (no sync in the
                                        # double-buffered hot path)
        self.stats = {"items": 0, "rounds": 0, "h2d_bytes": 0,
                      "d2h_bytes": 0, "segments": 0, "refills": 0,
                      "lane_steps": 0, "wasted_lane_steps": 0,
                      "quarantined_lane_steps": 0, "retries": 0,
                      "rejected": 0, "quarantined_slots": 0,
                      "segment_traces": 0, "refill_traces": 0,
                      "chain_traces": 0, "stage_traces": 0,
                      "sink_errors": 0, "snapshots": 0,
                      "replayed_items": 0, "recovered_occupants": 0,
                      "recovery_seconds": 0.0}
        self._resume_state = None       # staged by restore()
        self._rt_capture = None         # live snapshot closure, set by
                                        # run_continuous for snapshot()

    # -- static geometry (first item binds the shapes) -------------------
    def _bind(self, item):
        L = self.lanes
        item = _as_item(item)
        self._item_avals = tuple(
            jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
            for leaf in _item_leaves(item))
        items_aval = jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct((L, *leaf.shape),
                                              leaf.dtype), item)
        a_aval, env_avals = jax.eval_shape(self._vprep, items_aval)
        if len(a_aval.shape) != 3:
            raise ValueError(
                f"stream items must be 2-D grids; prep produced "
                f"{a_aval.shape}")
        m, n = a_aval.shape[1:]
        # continuous mode folds the segment length into unroll="auto":
        # the tuned segment (T·segment sweeps per dispatch) amortizes
        # the remaining per-dispatch cost of the chained path
        self._loop = self.loop._resolve_unroll(
            (m, n),
            segment=self.segment if self._mode == "continuous" else None)
        loop = self._loop
        self._prep_avals = (a_aval, env_avals)
        self._nshards = (1 if self.mesh is None
                         else self.mesh.shape[self.lane_axis])

        if loop.backend == "jnp":
            self._eng, self._lspec = None, None
            self._frames = jnp.zeros((), a_aval.dtype)
            self._env_frames = ()
        elif loop.backend == "pallas-sharded":
            from .executor import ShardedStencilEngine, local_extents

            part = loop.partition
            for name, ax in zip(part.axis_names, part.array_axes):
                nsh = part.mesh.shape[name]
                if (m, n)[ax] % nsh:
                    raise ValueError(
                        f"array axis {ax} (size {(m, n)[ax]}) must "
                        f"divide evenly over mesh axis {name!r} "
                        f"(size {nsh})")
            lm, ln = local_extents(m, n, part)
            self._eng = ShardedStencilEngine(
                f=loop.f, part=part, k=loop.k, boundary=loop.boundary,
                combine=loop.combine, identity=loop.identity,
                delta=loop.delta, measure=loop.measure, block=loop.block,
                unroll=loop.unroll, interpret=loop.interpret)
            self._lspec = self._eng.lane_sspec(lm, ln)
            spatial = [None, None]
            for name, ax in zip(part.axis_names, part.array_axes):
                spatial[ax] = name
            self._spatial = tuple(spatial)
            arity = tuple(part.mesh.shape[s] if s else 1 for s in spatial)

            def stitched(local_shape):
                """Global shape of a lane-stacked per-shard buffer."""
                return (L, local_shape[0] * arity[0],
                        local_shape[1] * arity[1])

            self._frames = jax.device_put(
                np.zeros(stitched(self._lspec.local.shape), a_aval.dtype),
                NamedSharding(self.mesh, self._fspec()))
            # env slots: per-shard layout matches frame_env_sharded
            # (block-rounded interior, or full frame under temporal
            # blocking) — prep produced the avals from WHOLE items, the
            # spatial split happens at the shard_map boundary
            env_local = (self._lspec.local.shape if self._eng._multistep
                         else self._lspec.local.interior)
            self._env_frames = tuple(
                jax.device_put(np.zeros(stitched(env_local), e.dtype),
                               NamedSharding(self.mesh, self._fspec()))
                for e in env_avals)
        else:
            from .executor import StencilEngine
            from .frames import alloc_lane_env

            self._eng = StencilEngine(
                f=loop.f, k=loop.k, boundary=loop.boundary,
                combine=loop.combine, identity=loop.identity,
                delta=loop.delta, measure=loop.measure, block=loop.block,
                unroll=loop.unroll, backend=loop.backend,
                interpret=loop.interpret)
            self._lspec = self._eng.lane_spec(L // self._nshards, m, n)
            frames = np.zeros((L, *self._lspec.frame.shape), a_aval.dtype)
            envs = tuple(
                np.zeros((L,) + tuple(
                    alloc_lane_env(self._lspec, e.dtype,
                                   self._eng._halo_env).shape[1:]),
                    e.dtype)
                for e in env_avals)
            if self.mesh is None:
                self._frames = jnp.asarray(frames)
                self._env_frames = tuple(jnp.asarray(e) for e in envs)
            else:
                lane_sh = NamedSharding(self.mesh, P(self.lane_axis))
                self._frames = jax.device_put(frames, lane_sh)
                self._env_frames = tuple(
                    jax.device_put(e, lane_sh) for e in envs)
        self._bound = True

    def _fspec(self) -> P:
        """PartitionSpec of the lane-stacked frames/interiors (composed
        sharded mode: lanes × spatial)."""
        return P(self.lane_axis, *self._spatial)

    # -- one round: refill slots, run the farm, slice results ------------
    def _round_impl(self, frames, env_frames, items, active):
        a0s, envs = self._vprep(items)
        if self.mesh is None:
            return self._local_round(frames, env_frames, a0s, envs,
                                     active)
        from repro.sharding.specs import shard_map

        loop = self._loop
        if loop.backend == "pallas-sharded":
            data_spec = self._fspec()
        else:
            data_spec = P(self.lane_axis)
        fr_spec = P() if loop.backend == "jnp" else data_spec
        env_specs = tuple(data_spec for _ in env_frames)
        fn = shard_map(
            self._local_round, mesh=self.mesh,
            in_specs=(fr_spec, env_specs, data_spec,
                      tuple(data_spec for _ in envs), P(self.lane_axis)),
            out_specs=(fr_spec, env_specs, data_spec, P(self.lane_axis),
                       P(self.lane_axis), P(self.lane_axis),
                       P(self.lane_axis)))
        return fn(frames, env_frames, a0s, envs, active)

    @staticmethod
    def _round_waste(iters):
        """Done-masked lane sweeps of one round: the barrier runs every
        lane to the round's slowest trip count, so a lane that finished
        at ``it_i`` idled for ``max(it) - it_i`` sweeps (premasked
        padding lanes idle the whole round).  Shape (1,): per-shard under
        shard_map, summed on the host."""
        lanes = iters.shape[0]
        return (lanes * jnp.max(iters) - jnp.sum(iters))[None]

    def _round_waste_composed(self, iters):
        """Composed-mode round waste: the barrier is MESH-global (see
        :meth:`_lane_cond_fold`), so every lane idles behind the
        slowest lane of ANY lane shard — fold the per-shard max over the
        lane axis before differencing."""
        lanes = iters.shape[0]
        gmax = jax.lax.pmax(jnp.max(iters), self.lane_axis)
        return (lanes * gmax - jnp.sum(iters))[None]

    def _lane_cond_fold(self):
        """Composed backend only: fold the round's any-live predicate
        over the lane axis (ONE scalar pmax per body step) so every lane
        shard runs the same trip count.  The loop body exchanges ghost
        strips by ppermute along the spatial axes; lane shards pacing
        their whiles independently would desynchronise those exchange
        rendezvous (a latent deadlock on runtimes with global collective
        rendezvous).  Single-device-backend lane farms carry no body
        collectives and keep their per-shard trip counts."""
        if self._loop.backend != "pallas-sharded":
            return None
        axis = self.lane_axis

        def fold(live_any):
            return jax.lax.pmax(live_any.astype(jnp.int32), axis) > 0
        return fold

    def _local_round(self, frames, env_frames, interiors, envs, active):
        """The device-side round (directly, or per-shard inside
        shard_map): in-place slot refill → ONE done-masked lane
        while_loop → O(interior) result slices.  Returns
        (frames', env_frames', outs, reduced, iters, health, waste)."""
        loop = self._loop
        done0 = jnp.logical_not(active)
        if loop.backend == "jnp":
            res = loop.farm_run(interiors, env=envs, done0=done0)
            return (frames, env_frames, res.a, res.reduced, res.iters,
                    res.health, self._round_waste(res.iters))
        eng, lspec = self._eng, self._lspec
        frames, env_frames = eng.refill_lanes(frames, env_frames,
                                              interiors, envs, lspec)
        fold = self._lane_cond_fold()
        res = loop._drive_lanes(
            frames,
            step=lambda fr: eng.sweeps_lanes(fr, env_frames, lspec),
            finalize=lambda fr: fr, done0=done0, cond_fold=fold)
        outs = eng.unframe_lanes(res.a, lspec)
        waste = (self._round_waste(res.iters) if fold is None
                 else self._round_waste_composed(res.iters))
        return (res.a, env_frames, outs, res.reduced, res.iters,
                res.health, waste)

    def round(self, items, count: Optional[int] = None):
        """Push one stacked (≤ lanes, ...) batch through the slots.

        ``items`` is a stacked array, a LIST of stream items, or — for
        tuple stream items ``(a, *env)`` — a TUPLE of per-leaf stacks
        (stack each leaf across the batch; a tuple argument is always
        read this way, so pass a list, not a tuple, of items).
        Returns per-item ``(a, reduced, iters, health)`` stacks of
        length ``count`` (short batches are padded to the lane count on
        the host and masked out on device — the shapes, and therefore
        the compilation, never change).  Decode ``health`` with
        :func:`repro.core.reduce.health_status`.
        """
        if isinstance(items, list):
            items = _stack_items(items)
        elif isinstance(items, tuple):
            items = tuple(np.asarray(leaf) for leaf in items)
        else:
            items = np.asarray(items)
        leaves = _item_leaves(items)
        B = leaves[0].shape[0]
        if any(leaf.shape[0] != B for leaf in leaves):
            raise ValueError(
                f"per-leaf stacks of a tuple batch must share the "
                f"leading batch dim; got "
                f"{tuple(leaf.shape[0] for leaf in leaves)} (a tuple "
                "argument is read as (main, *env) per-leaf stacks — "
                "pass a list of items to stack leaf-wise)")
        count = B if count is None else count
        if count > self.lanes:
            raise ValueError(f"batch of {count} items exceeds "
                             f"lanes={self.lanes}")
        if self._mode == "continuous":
            raise ValueError("engine already streamed in continuous mode;"
                             " build a fresh FarmEngine for rounds")
        self._mode = "round"
        rep = jax.tree.map(lambda leaf: leaf[0], items)
        if not self._bound:
            self._bind(rep)
        else:
            self._check_item(_as_item(rep))
        if self.check_finite:
            # the drift check above reads only the representative item;
            # the finite guard must sweep the WHOLE stack — round mode
            # has no per-slot quarantine to catch a poisoned lane later
            for i, leaf in enumerate(leaves):
                if np.issubdtype(leaf.dtype, np.floating) \
                        and not np.isfinite(leaf[:count]).all():
                    which = ("stream batch" if i == 0
                             else f"env stream batch (leaf {i - 1})")
                    raise NonFiniteItemError(
                        f"{which} carries NaN/Inf input values — "
                        "rejected at the prep boundary before any lane "
                        "is dirtied (pass check_finite=False to admit "
                        "it anyway under sentinel quarantine)")
        # payload accounting, symmetric with _drain's d2h: the zero
        # lanes padding a ragged round are implementation overhead, not
        # per-item traffic
        self.stats["h2d_bytes"] += \
            sum(leaf.nbytes // B for leaf in leaves) * count
        if B < self.lanes:
            items = jax.tree.map(
                lambda leaf: np.concatenate(
                    [leaf, np.zeros((self.lanes - B, *leaf.shape[1:]),
                                    leaf.dtype)], axis=0), items)
        if count == self.lanes:
            if getattr(self, "_active_full", None) is None:
                self._active_full = jnp.ones((self.lanes,), bool)
            active = self._active_full
        else:
            active = jnp.asarray(np.arange(self.lanes) < count)
        self.stats["rounds"] += 1
        self.stats["items"] += count
        (self._frames, self._env_frames, outs, red, iters, hw,
         waste) = self._round_fn(
            self._frames, self._env_frames,
            jax.tree.map(jnp.asarray, items), active)
        self._waste_buf.append((waste, iters, hw, count))  # lazy convert
        if len(self._waste_buf) > 64:            # bound the buffer on
            self._flush_waste(keep=2)            # long streams; the old
                                                 # rounds are long done
        return outs[:count], red[:count], iters[:count], hw[:count]

    # -- lane-step/waste accounting shared by both modes -----------------
    def _flush_waste(self, keep: int = 0):
        """Fold buffered per-round (waste, iters, health, count) device
        tuples into the stats — deferred so ``round()`` never forces a
        host sync inside the double-buffered stream.  ``keep`` leaves
        the newest entries buffered (their rounds may still be in
        flight).  A non-ok lane's sweeps are additionally booked as
        ``quarantined_lane_steps`` — work burned on an item that never
        produced a usable result (the waste axis the fault plan's
        round-vs-continuous comparison reads)."""
        while len(self._waste_buf) > keep:
            waste, iters, hw, count = self._waste_buf.pop(0)
            w = int(np.asarray(waste).sum())
            it_h = np.asarray(iters)
            hw_h = np.asarray(hw)
            u = int(it_h.sum())
            self.stats["wasted_lane_steps"] += w
            self.stats["lane_steps"] += w + u
            for i in range(count):
                if item_status(hw_h[i], it_h[i],
                               self._loop.max_iters) != "ok":
                    self.stats["quarantined_lane_steps"] += int(it_h[i])

    @property
    def wasted_lane_steps(self) -> int:
        """Total done-masked / idle-slot lane sweeps executed so far —
        the straggler-barrier metric continuous mode exists to shrink."""
        self._flush_waste()
        return self.stats["wasted_lane_steps"]

    @property
    def lane_steps(self) -> int:
        """Total lane sweeps executed (useful + wasted)."""
        self._flush_waste()
        return self.stats["lane_steps"]

    @property
    def quarantined_lane_steps(self) -> int:
        """Lane sweeps burned on occupants that finished non-ok
        (poisoned / diverged / timed out) — the fault-waste axis next
        to ``wasted_lane_steps``."""
        self._flush_waste()
        return self.stats["quarantined_lane_steps"]

    # -- continuous mode: segmented loop + per-slot refill ---------------
    def _lane_step(self, env_frames):
        """The per-body-step farm advance for the resident carry: the
        vmapped persistent-kernel sweep (pallas backends) or the vmapped
        shift-algebra step (jnp — the (lanes, m, n) stack IS the carry).
        """
        loop = self._loop
        if loop.backend == "jnp":
            return loop._lane_step_jnp(env_frames)
        return lambda fr: self._eng.sweeps_lanes(fr, env_frames,
                                                 self._lspec)

    def _local_segment(self, frames, env_frames, r, it, done, hw):
        """One bounded early-exit slice of the resident lane loop
        (directly, or per-shard inside shard_map).  Returns the resumed
        carry plus the (1,) body-step count — per shard, because lane
        shards exit their segments independently (no collectives cross
        the lane axis).  The composed backend runs the uniform-schedule
        variant instead (exactly ``segment`` done-masked steps): its
        body ppermutes ghost strips along the spatial axes, and a
        data-dependent early exit on one lane shard would leave the
        other shards' exchange rendezvous waiting — a fixed step count
        keeps every shard's collective schedule aligned with still no
        collective crossing the lane axis."""
        loop = self._loop
        (a, r, it, done, hw), steps = loop.lane_segment(
            (frames, r, it, done, hw), step=self._lane_step(env_frames),
            segment=self.segment,
            early_exit=loop.backend != "pallas-sharded")
        return a, env_frames, r, it, done, hw, steps[None]

    def _segment_entry(self, frames, env_frames, r, it, done, hw):
        self.stats["segment_traces"] += 1      # traced once per stream
        return self._segment_body(frames, env_frames, r, it, done, hw)

    def _segment_body(self, frames, env_frames, r, it, done, hw):
        if self.mesh is None:
            return self._local_segment(frames, env_frames, r, it, done,
                                       hw)
        from repro.sharding.specs import shard_map

        lane_spec = P(self.lane_axis)
        # composed mode: frames carry the spatial axes too; the segment
        # still runs per LANE shard (spatial shards of one lane group
        # share their trip counts through the collective reduce, so the
        # early exit stays SPMD-uniform within each exchange group)
        fr_spec = (self._fspec()
                   if self._loop.backend == "pallas-sharded"
                   else lane_spec)
        env_specs = tuple(fr_spec for _ in env_frames)
        fn = shard_map(
            self._local_segment, mesh=self.mesh,
            in_specs=(fr_spec, env_specs, lane_spec, lane_spec,
                      lane_spec, lane_spec),
            out_specs=(fr_spec, env_specs, lane_spec, lane_spec,
                       lane_spec, lane_spec, lane_spec))
        return fn(frames, env_frames, r, it, done, hw)

    def _refill_impl(self, frames, env_frames, r, it, done, hw, idx,
                     item):
        """Hand ONE finished lane's slot (dynamic index) to the next
        stream item and re-arm its carry — O(interior) writes, no pad,
        no re-framing, one compilation for every refill.  ``prep`` runs
        here, on the whole item (halo-aware by construction).  The
        health word re-arms to 0 with the rest of the carry: a slot's
        faults do not follow it onto the next occupant."""
        self.stats["refill_traces"] += 1       # traced once per stream
        loop = self._loop
        a0, envs = self._prep1(item)
        return self._slot_write(frames, env_frames, r, it, done, hw, idx,
                                a0, envs, loop._id, 0, 0)

    def _restore_impl(self, frames, env_frames, r, it, done, hw, idx,
                      item, a_mid, rv, iv, hv):
        """Re-seat a snapshotted in-flight occupant into a (possibly
        different) slot: the saved mid-flight LOGICAL interior ``a_mid``
        takes the place of a fresh item's prepped ``a0`` and the carry
        re-arms with the saved ``(reduce, iter, health)`` instead of the
        identity — the convergence loop continues from iteration ``iv``
        exactly as if the preemption never happened.  Ghost/boundary
        cells are re-derived by the same refill machinery a fresh item
        uses (they are a function of interior + boundary spec, and the
        next sweep re-asserts them before reading), which is what makes
        snapshots topology-free: this path repacks the interior onto
        whatever lane count / mesh the RESUMED engine runs.  ``prep``
        re-derives the env fields from the raw item (prep must be
        deterministic — the same property retries already rely on)."""
        _, envs = self._prep1(item)
        return self._slot_write(frames, env_frames, r, it, done, hw, idx,
                                a_mid, envs, rv, iv, hv)

    def _slot_write(self, frames, env_frames, r, it, done, hw, idx,
                    a0, envs, rv, iv, hv):
        from .frames import refill_slot_env, refill_slot_frame

        loop = self._loop
        if loop.backend == "pallas-sharded":
            return self._refill_sharded(frames, env_frames, r, it, done,
                                        hw, idx, a0, envs, rv, iv, hv)
        if loop.backend == "jnp":
            frames = jax.lax.dynamic_update_slice(
                frames, a0[None].astype(frames.dtype), (idx, 0, 0))
            env_frames = tuple(
                jax.lax.dynamic_update_slice(
                    ef, e[None].astype(ef.dtype), (idx,) + (0,) * e.ndim)
                for ef, e in zip(env_frames, envs))
        else:
            spec = self._lspec.frame
            frames = refill_slot_frame(frames, a0, idx, spec,
                                       loop.boundary)
            env_frames = tuple(
                refill_slot_env(ef, e, idx, spec, loop.boundary,
                                halo=self._eng._halo_env)
                for ef, e in zip(env_frames, envs))
        r = r.at[idx].set(jnp.asarray(rv, r.dtype))
        it = it.at[idx].set(jnp.asarray(iv, it.dtype))
        done = done.at[idx].set(False)
        hw = hw.at[idx].set(jnp.asarray(hv, hw.dtype))
        return frames, env_frames, r, it, done, hw

    def _refill_sharded(self, frames, env_frames, r, it, done, hw, idx,
                        a0, envs, rv, iv, hv):
        """Composed-mode slot hand-off: ``prep`` already ran on the
        WHOLE item (halo-aware); its (m, n) result splits at the
        shard_map boundary, each spatial shard scatters its LOCAL
        interior block into the owner lane shard's slot (owner-masked —
        every shard runs the same O(interior) program, only the owner's
        slot changes), and the ghost rings re-assert through the SAME
        O(k·n) edge-strip ppermute the loop body uses.  The carry
        re-arms with a masked select on the (local lanes,) vectors — no
        collective crosses the lane axis, one compilation per stream."""
        from repro.sharding.specs import local_slot, shard_map
        from .frames import (refill_slot_env_sharded,
                             refill_slot_frame_sharded)

        loop = self._loop
        fspec = self._fspec()
        lane_spec = P(self.lane_axis)
        spatial_spec = P(*self._spatial)
        local_L = self.lanes // self._nshards
        halo_env = self._eng._multistep

        def local_refill(frames, env_frames, r, it, done, hw, idx,
                         a_loc, env_loc, rv, iv, hv):
            owns, li = local_slot(idx, local_L, self.lane_axis)
            frames = refill_slot_frame_sharded(
                frames, a_loc, li, owns, self._lspec, loop.boundary)
            env_frames = tuple(
                refill_slot_env_sharded(ef, e, li, owns, self._lspec,
                                        loop.boundary, halo=halo_env)
                for ef, e in zip(env_frames, env_loc))
            upd = jnp.logical_and(owns,
                                  jnp.arange(r.shape[0]) == li)
            r = jnp.where(upd, jnp.asarray(rv, r.dtype), r)
            it = jnp.where(upd, jnp.asarray(iv, it.dtype), it)
            done = jnp.where(upd, jnp.zeros_like(done), done)
            hw = jnp.where(upd, jnp.asarray(hv, hw.dtype), hw)
            return frames, env_frames, r, it, done, hw

        env_specs = tuple(fspec for _ in env_frames)
        fn = shard_map(
            local_refill, mesh=self.mesh,
            in_specs=(fspec, env_specs, lane_spec, lane_spec, lane_spec,
                      lane_spec, P(), spatial_spec,
                      tuple(spatial_spec for _ in envs), P(), P(), P()),
            out_specs=(fspec, env_specs, lane_spec, lane_spec,
                       lane_spec, lane_spec))
        return fn(frames, env_frames, r, it, done, hw, idx, a0, envs,
                  jnp.asarray(rv), jnp.asarray(iv), jnp.asarray(hv))

    def _extract_impl(self, frames, idx):
        """Slice ONE lane's (m, n) domain out at a dynamic index — the
        only per-item device→host payload of the continuous path."""
        if self._loop.backend == "jnp":
            return jax.lax.dynamic_index_in_dim(frames, idx, axis=0,
                                                keepdims=False)
        if self._loop.backend == "pallas-sharded":
            from repro.sharding.specs import local_slot, shard_map

            spec = self._lspec.local
            p = spec.pad
            local_L = self.lanes // self._nshards

            def local_extract(fr, idx):
                _, li = local_slot(idx, local_L, self.lane_axis)
                return jax.lax.dynamic_slice(fr, (li, p, p),
                                             (1, spec.m, spec.n))

            fn = shard_map(local_extract, mesh=self.mesh,
                           in_specs=(self._fspec(), P()),
                           out_specs=P(self.lane_axis, *self._spatial))
            # every lane shard contributes ITS li-slot's stitched (m, n)
            # plane; the owner's plane is the result
            planes = fn(frames, idx)
            owner = idx // jnp.asarray(local_L, idx.dtype)
            return jax.lax.dynamic_index_in_dim(planes, owner, axis=0,
                                                keepdims=False)
        spec = self._lspec.frame
        p = spec.pad
        return jax.lax.dynamic_slice(
            frames, (idx, p, p), (1, spec.m, spec.n))[0]

    # -- chained dispatch: fused segment + ring refill + capture ---------
    def _unframe_all(self, frames):
        """Every lane's (m, n) domain as one (lanes, m, n) stack — the
        chained path's emission payload, captured INSIDE the fused entry
        (pre-refill, so it is value-identical to what the classic
        per-slot ``_extract_fn`` would have sliced)."""
        if self._loop.backend == "jnp":
            return frames
        from .frames import unframe_lanes
        return unframe_lanes(frames, self._lspec.frame)

    def _chain_refill(self, frames, env_frames, take, interiors,
                      env_sel):
        """Masked batch refill of every taken slot in ONE shot — the
        fused replacement for the host loop's per-finished-slot
        ``_refill_fn`` dispatches.  ``interiors``/``env_sel`` are the
        staging-ring gathers ((lanes, m, n) — junk rows where ``~take``
        are masked out by the select)."""
        loop = self._loop
        if loop.backend == "jnp":
            frames = jnp.where(take[:, None, None],
                               interiors.astype(frames.dtype), frames)
            env_frames = tuple(
                jnp.where(take.reshape((-1,) + (1,) * (ef.ndim - 1)),
                          e.astype(ef.dtype), ef)
                for ef, e in zip(env_frames, env_sel))
            return frames, env_frames
        from .frames import refill_lanes_env_masked, refill_lanes_masked
        spec = self._lspec.frame
        frames = refill_lanes_masked(frames, take, interiors, spec,
                                     loop.boundary)
        env_frames = tuple(
            refill_lanes_env_masked(ef, take, e, spec, loop.boundary,
                                    halo=self._eng._halo_env)
            for ef, e in zip(env_frames, env_sel))
        return frames, env_frames

    def _chain_entry(self, frames, env_frames, r, it, done, hw, ring,
                     ring_envs, rd, wr, live):
        """ONE donated jitted dispatch of the chained path: run a
        segment, CAPTURE the finished lanes' emission payloads (domains,
        reduce/iter/health — all pre-refill), then hand every finished
        live slot its next occupant straight from the staging ring via
        the device-side cursor ``rd`` — a masked batch refill, no host
        round trip, no per-slot dispatch.

        ``rd`` is device-resident (threaded call to call — only the
        device knows how many slots each segment finished); ``wr`` is
        the host's staged-count watermark, pushed as a fresh scalar per
        dispatch.  ``live`` masks quarantined slots out of the seating —
        it lags one in-flight dispatch behind the host's quarantine
        decisions (documented divergence from the classic loop: a
        just-quarantined slot may be seated once more before the mask
        catches up).  Seating follows lane order over the finished live
        slots — exactly the order the classic loop's ascending
        admit-per-slot produced, which is what keeps the two paths
        bit-identical on fault-free streams.  Returns the resumed carry
        plus ``(meta, r_pre, outs)`` for the host's ASYNC drain —
        ``meta`` is one packed int32 vector (fin | it | hw | take |
        steps), so the steady-state drain is a single small D2H."""
        self.stats["segment_traces"] += 1      # traced once per stream
        self.stats["chain_traces"] += 1
        loop = self._loop
        (frames, env_frames, r, it, done, hw,
         steps) = self._segment_body(frames, env_frames, r, it, done,
                                     hw)
        fin = jnp.logical_or(done, it >= loop.max_iters)
        outs = self._unframe_all(frames)
        r_pre, it_pre, hw_pre = r, it, hw
        elig = jnp.logical_and(fin, live)
        e32 = elig.astype(jnp.int32)
        rank = jnp.cumsum(e32) - e32
        take = jnp.logical_and(elig, rank < (wr - rd))
        K = self._ring_depth
        pos = jnp.where(take, (rd + rank) % K, 0)
        interiors = ring[pos]
        env_sel = tuple(re_[pos] for re_ in ring_envs)
        frames, env_frames = self._chain_refill(frames, env_frames,
                                                take, interiors,
                                                env_sel)
        r = jnp.where(take, jnp.asarray(loop._id, r.dtype), r)
        it = jnp.where(take, jnp.zeros_like(it), it)
        done = jnp.where(take, jnp.zeros_like(done), done)
        hw = jnp.where(take, jnp.zeros_like(hw), hw)
        rd = rd + jnp.sum(take.astype(jnp.int32))
        # ONE packed int32 metadata word per segment: the drain's whole
        # decision state (finished mask, pre-refill iters/health, seat
        # mask, per-shard step counts) crosses the device boundary as a
        # single small transfer — payloads (outs, r) stay device-side
        # until an emission actually needs them
        meta = jnp.concatenate([
            fin.astype(jnp.int32), it_pre.astype(jnp.int32),
            hw_pre.astype(jnp.int32), take.astype(jnp.int32),
            steps.astype(jnp.int32)])
        return (frames, env_frames, r, it, done, hw, ring, ring_envs,
                rd, meta, r_pre, outs)

    def _stage_impl(self, ring, ring_envs, pos, item):
        """Pre-stage one stream item's PREPPED interior/env into the
        ring at ``pos`` — the host's read stage running AHEAD of need
        (one compilation for every stage of the stream; the ring is
        donated, so the write is in place)."""
        self.stats["stage_traces"] += 1        # traced once per stream
        from .frames import stage_ring_write
        a0, envs = self._prep1(item)
        ring = stage_ring_write(ring, a0, pos)
        ring_envs = tuple(stage_ring_write(re_, e, pos)
                          for re_, e in zip(ring_envs, envs))
        return ring, ring_envs

    def _meta_read(self, *arrs):
        """THE single device→host transfer of one chained-segment drain:
        every metadata read of a drained segment funnels through here
        (the steady-state no-host-sync guard wraps it — one call per
        segment, issued only AFTER the next segment is in flight)."""
        return jax.device_get(arrs)

    def _check_item(self, item):
        """Guard EVERY leaf of a stream item — the main array AND any
        env leaves a tuple item carries — against mid-stream shape/dtype
        drift.  Without the env check a drifted env leaf sails into the
        jitted refill and dies as an opaque XLA shape error."""
        leaves = _item_leaves(item)
        if len(leaves) != len(self._item_avals):
            raise ValueError(
                f"stream item arity changed mid-stream: slots are bound "
                f"to {len(self._item_avals)} leaves (main + env), got "
                f"{len(leaves)} (build a fresh FarmEngine per item "
                "geometry)")
        for i, (leaf, aval) in enumerate(zip(leaves, self._item_avals)):
            if leaf.shape != aval.shape or leaf.dtype != aval.dtype:
                which = ("stream item" if i == 0
                         else f"env stream item {i - 1}")
                raise ValueError(
                    f"{which} shape changed mid-stream: slots are bound "
                    f"to {aval.shape}/{aval.dtype}, got "
                    f"{leaf.shape}/{leaf.dtype} (build a fresh "
                    "FarmEngine per item geometry)")
        if self.check_finite:
            for i, leaf in enumerate(leaves):
                if np.issubdtype(leaf.dtype, np.floating) and \
                        not np.isfinite(leaf).all():
                    which = ("stream item" if i == 0
                             else f"env stream item {i - 1}")
                    raise NonFiniteItemError(
                        f"{which} carries NaN/Inf input values — "
                        "rejected at the prep boundary (a non-finite "
                        "item poisons its lane and, on the sharded "
                        "deployments, leaks into neighbour shards "
                        "through the ghost exchange; pass "
                        "check_finite=False to admit it anyway under "
                        "sentinel quarantine)")

    def _bind_continuous(self):
        """Allocate the continuous carry around the bound slots: the jnp
        backend's resident (lanes, m, n) stack (the pallas backends reuse
        the lane frames ``_bind`` staged) plus the per-lane (r, it, done)
        vectors — all slots start retired (done, unoccupied)."""
        loop = self._loop
        if self.chained and loop.backend != "pallas-sharded" \
                and getattr(self, "_ring", None) is None:
            # the staging ring: K prepped (m, n) interiors (+ env
            # leaves) ahead of need, allocated once, donated in place
            # ever after.  Replicated under a lane mesh — every lane
            # shard gathers its own seats from the same ring.
            from .frames import alloc_stage_ring
            a_aval, env_avals = self._prep_avals
            K = self.stage_depth or max(2 * self.lanes, 2)
            self._ring_depth = K
            ring = alloc_stage_ring(K, a_aval.shape[1:], a_aval.dtype)
            rengs = tuple(alloc_stage_ring(K, e.shape[1:], e.dtype)
                          for e in env_avals)
            if self.mesh is None:
                self._ring = jnp.asarray(ring)
                self._ring_envs = tuple(jnp.asarray(x) for x in rengs)
            else:
                rep = NamedSharding(self.mesh, P())
                self._ring = jax.device_put(ring, rep)
                self._ring_envs = tuple(jax.device_put(x, rep)
                                        for x in rengs)
        if getattr(self, "_cont_carry", None) is not None:
            return          # slots + carry persist across streams: the
                            # end state (all lanes retired) is exactly a
                            # valid start state for the next stream
        a_aval, env_avals = self._prep_avals
        L = self.lanes
        if loop.backend == "jnp":
            frames = np.zeros(a_aval.shape, a_aval.dtype)
            envs = tuple(np.zeros(e.shape, e.dtype) for e in env_avals)
            if self.mesh is None:
                self._frames = jnp.asarray(frames)
                self._env_frames = tuple(jnp.asarray(e) for e in envs)
            else:
                lane_sh = NamedSharding(self.mesh, P(self.lane_axis))
                self._frames = jax.device_put(frames, lane_sh)
                self._env_frames = tuple(
                    jax.device_put(e, lane_sh) for e in envs)
        if loop.backend == "pallas-sharded":
            # the per-lane reduce dtype, evaluated abstractly through
            # the same shard_map the segments run in (the lane frames
            # _bind staged are already the continuous slots)
            from repro.sharding.specs import shard_map

            fspec = self._fspec()
            fn = shard_map(
                lambda fr, efs: self._eng.sweeps_lanes(
                    fr, efs, self._lspec)[1],
                mesh=self.mesh,
                in_specs=(fspec, tuple(fspec for _ in self._env_frames)),
                out_specs=P(self.lane_axis))
            r_aval = jax.eval_shape(fn, self._frames, self._env_frames)
        else:
            r_aval = jax.eval_shape(
                lambda fr, ef: self._lane_step(ef)(fr)[1],
                self._frames, self._env_frames)
        r0 = np.full((L,), loop._id, np.dtype(r_aval.dtype))
        it0 = np.zeros((L,), np.int32)
        d0 = np.ones((L,), bool)
        hw0 = np.zeros((L,), np.int32)
        if self.mesh is None:
            carry = tuple(jnp.asarray(x) for x in (r0, it0, d0, hw0))
        else:
            lane_sh = NamedSharding(self.mesh, P(self.lane_axis))
            carry = tuple(jax.device_put(x, lane_sh)
                          for x in (r0, it0, d0, hw0))
        self._cont_carry = carry

    # -- snapshot / restore (preemption recovery) ------------------------
    def snapshot(self) -> dict:
        """The in-flight continuous-stream state as ONE logical tree:
        every occupied slot's mid-flight interior (extracted UNSHARDED,
        whatever the deployment), its ``(reduce, iter, health)`` carry
        and raw item, the retry queue, and the source cursor
        (``next_index``).  Everything is topology-free — a snapshot
        taken at lanes=L over mesh=M restores onto any other lane
        count / mesh (:meth:`restore` repacks the interiors through the
        same refill machinery fresh items use).  Slot quarantine and
        bad-slot sets are deliberately NOT captured: they describe the
        old process's physical slots, not the stream.

        Only meaningful at a segment boundary — call it from an
        ``on_segment`` callback (or pass ``recovery=`` to
        :meth:`run_continuous`, which snapshots automatically)."""
        if self._rt_capture is None:
            raise ValueError(
                "snapshot() captures continuous-stream state; nothing "
                "has streamed yet — run run_continuous (pass recovery= "
                "to persist snapshots automatically)")
        return self._rt_capture()

    def restore(self, state: dict) -> "FarmEngine":
        """Stage a :meth:`snapshot` tree; the next :meth:`run_continuous`
        resumes from it: the source is fast-forwarded past the snapshot's
        cursor, in-flight occupants re-enter fresh slots mid-iteration,
        and pre-crash retries keep their attempt counts.  The engine's
        own geometry may differ from the snapshotting engine's (elastic
        resume); the ITEM geometry may not."""
        if self._mode == "round":
            raise ValueError("engine already streamed in round mode; "
                             "build a fresh FarmEngine to restore into")
        if not isinstance(state, dict) or state.get("kind") != "farm":
            raise ValueError("not a FarmEngine snapshot tree")
        if int(state.get("version", -1)) != 1:
            raise ValueError("unsupported FarmEngine snapshot version "
                             f"{state.get('version')!r}")
        self._resume_state = state
        return self

    def run_continuous(self, source, sink, *, recovery=None,
                       resume: bool = False,
                       on_segment: Optional[Callable] = None) -> int:
        """Drive a whole stream with continuous per-lane refill.

        ``sink`` receives one :class:`StreamResult` per stream item —
        EXACTLY once each, in COMPLETION order (``.index`` is the stream
        position).  Protocol: the farm advances in bounded segments; the
        moment a lane's convergence loop finishes, its (m, n) result is
        extracted, the next queued item takes over the slot in place,
        and the SAME carry resumes — the other lanes never notice.  One
        compilation serves every segment, refill and extraction.

        Failure semantics (DESIGN.md §Failure semantics): a lane the
        sentinel quarantined (poisoned / diverged) or that exhausted its
        iteration budget finishes with a non-ok ``status``.  With
        ``max_attempts > 1`` such an item re-enters a bounded retry
        queue and is re-admitted into a FRESH slot (a fault pinned to a
        slot must not follow the item); once its attempts are exhausted
        it is emitted with its final non-ok status and recorded on
        ``dead_letter``.  A slot that fails ``slot_patience``
        CONSECUTIVE occupants is itself quarantined — retired from the
        refill rotation (``stats["quarantined_slots"]``) — unless it is
        the last slot standing.  Items failing the admission-time
        finite check emit ``status="rejected"`` without touching a
        slot.  Sweeps burned on non-ok occupants are booked as
        ``stats["quarantined_lane_steps"]`` next to the barrier-waste
        metric.

        Preemption recovery (DESIGN.md §Recovery): with ``recovery=``
        (a :class:`repro.resilience.recovery.RecoveryConfig`) every
        emitted result is write-ahead journaled (fsync'd, CRC-framed)
        BEFORE it reaches the sink, and the whole in-flight state — see
        :meth:`snapshot` — is published atomically every
        ``snapshot_every`` segments.  ``resume=True`` restarts a killed
        run: the journal replays pre-crash results to the sink (each
        index suppressed from re-emission — exactly-once across
        restarts), the source is fast-forwarded past the snapshot
        cursor (it must re-yield the same items from position 0 —
        deterministic sources, the property retries already rely on),
        and occupants continue mid-iteration.  The resumed engine may
        run a DIFFERENT lane count or mesh (elastic resume).  RPO: at
        most ``snapshot_every`` segments of compute are redone; no
        emitted result is ever emitted twice.  ``on_segment`` is called
        with the cumulative segment count at every segment boundary —
        the seam ``FaultPlan.preempt_hook`` kills through, and where a
        caller may take its own :meth:`snapshot`.
        """
        import time as _time

        if self._mode == "round":
            raise ValueError("engine already streamed in round mode; "
                             "build a fresh FarmEngine for continuous")
        self._mode = "continuous"

        t_resume0 = _time.perf_counter()
        state = None
        if self._resume_state is not None:
            state, self._resume_state = self._resume_state, None
        elif recovery is not None and resume:
            from repro.resilience.recovery import load_snapshot
            state = load_snapshot(recovery.snap_dir)

        journal = None
        emitted_pre: set = set()
        n_out = 0

        def deliver(res, journal_rec=True):
            """WAL-ordered emission: journal (fsync'd) FIRST, then the
            sink.  A raising sink degrades the result to ``dead_letter``
            with its error attached instead of killing the stream and
            losing the in-flight slots' items — the journal already
            holds the payload, so a resumed run re-delivers it."""
            nonlocal n_out
            if journal is not None and journal_rec:
                journal.append({
                    "index": int(res.index), "status": res.status,
                    "attempts": int(res.attempts),
                    "iters": int(res.iters), "reduced": res.reduced,
                    "a": res.a, "error": res.error})
            try:
                sink(res)
            except Exception as e:
                self.stats["sink_errors"] += 1
                res = dataclasses.replace(
                    res,
                    status="failed" if res.status == "ok" else res.status,
                    error=f"sink raised: {type(e).__name__}: {e}")
            if res.status != "ok":
                self.dead_letter.append(res)
            n_out += 1

        if recovery is not None and resume:
            from repro.resilience.recovery import Journal
            for rec in Journal.replay(recovery.journal_path):
                ridx = int(rec["index"])
                if ridx in emitted_pre:
                    continue
                emitted_pre.add(ridx)
                deliver(StreamResult(
                    index=ridx, a=rec.get("a"),
                    reduced=rec.get("reduced"),
                    iters=np.int32(rec.get("iters") or 0),
                    status=rec.get("status", "ok"),
                    attempts=int(rec.get("attempts") or 1),
                    error=rec.get("error")), journal_rec=False)
                self.stats["replayed_items"] += 1
        if recovery is not None:
            from repro.resilience.recovery import Journal
            journal = Journal(recovery.journal_path,
                              fsync=recovery.fsync)

        if state is not None and state.get("complete"):
            # the preempted run had already drained its stream; the
            # journal replay above re-delivered every result (the
            # segment counter still restores — snapshot step numbering
            # stays monotonic if this engine runs again)
            self.stats["segments"] = int(state.get("segments", 0))
            if journal is not None:
                journal.close()
            self.stats["items"] += n_out
            self.stats["recovery_seconds"] += (
                _time.perf_counter() - t_resume0)
            return n_out

        stream = iter(source() if callable(source) else source)
        pending = None
        saved_occ = list(state.get("occupants") or ()) if state else []
        saved_retry = list(state.get("retry") or ()) if state else []
        if state is not None:
            # fast-forward the source cursor: positions below
            # next_index were pulled pre-crash — each is either in the
            # snapshot (in flight / queued) or in the journal (emitted)
            next_index = int(state["next_index"])
            stream = islice(stream, next_index, None)
            probe = None
            if saved_occ or saved_retry:
                probe = _as_item((saved_occ + saved_retry)[0]["item"])
            else:
                first = next(stream, None)
                if first is not None:
                    pending = probe = _as_item(first)
        else:
            next_index = 0
            probe = None
            first = next(stream, None)
            if first is not None:
                pending = probe = _as_item(first)
        if probe is None:      # nothing in flight AND stream drained
            if journal is not None:
                journal.close()
            self.stats["items"] += n_out
            return n_out
        if not self._bound:
            self._bind(probe)
        self._bind_continuous()
        loop = self._loop
        L, unroll = self.lanes, loop.unroll
        frames, env_frames = self._frames, self._env_frames
        r, itv, done, hw = self._cont_carry
        occupants: list = [None] * L      # slot -> in-flight entry
        slot_dead = [False] * L           # quarantined slots
        slot_fails = [0] * L              # consecutive non-ok finishes
        retry_q: list = []
        staged: deque = deque()           # entries resident in the
                                          # staging ring (chained mode),
                                          # ring-FIFO order
        pending_entries: deque = deque()  # entries pulled off the
                                          # stream but unstaged (repair
                                          # rewinds the ring through
                                          # here) — ahead of the cursor
        prev_it = np.zeros((L,), np.int64)

        if state is not None:
            # restored occupants re-enter through the retry-first
            # admission path, carrying their saved mid-flight state (a
            # resumed engine with FEWER lanes simply keeps the excess
            # queued); plain retries keep their attempt counts.  Slot
            # quarantine / bad-slot sets are physical facts about the
            # dead process's hardware and do not survive.
            self.stats["segments"] = int(state.get("segments", 0))
            for e in saved_occ:
                retry_q.append({
                    "index": int(e["index"]), "item": e["item"],
                    "attempts": int(e["attempts"]), "bad_slots": set(),
                    "carry": (e["a"], e["r"], int(e["it"]),
                              int(e["hw"]))})
            for e in saved_retry:
                retry_q.append({
                    "index": int(e["index"]), "item": e["item"],
                    "attempts": int(e["attempts"]), "bad_slots": set()})

        def pull_stream():
            """Next stream item as an in-flight entry (index assigned at
            pull time — the emission contract is exactly-once per
            index, whatever slots or retries it passes through)."""
            nonlocal pending, next_index
            if pending is not None:
                x, pending = pending, None
            else:
                x = next(stream, None)
                x = None if x is None else _as_item(x)
            if x is None:
                return None
            entry = {"index": next_index, "item": x, "attempts": 0,
                     "bad_slots": set()}
            next_index += 1
            return entry

        def next_entry(slot):
            """Retry entries first (fresh slots only), then the stream.
            A retry whose bad-slot set covers this slot re-enters it
            only as a last resort — stream drained AND no other live
            slot that could ever take it (the lanes=1 degenerate)."""
            for i, e in enumerate(retry_q):
                if slot not in e["bad_slots"]:
                    return retry_q.pop(i)
            if pending_entries:     # unstaged ring entries precede the
                return pending_entries.popleft()   # stream cursor
            e = pull_stream()
            if e is not None:
                return e
            others_live = any(
                occupants[s] is not None and not slot_dead[s]
                for s in range(L) if s != slot)
            if retry_q and not others_live:
                return retry_q.pop(0)
            return None

        def emit(entry, status, a=None, reduced=None, iters=0):
            deliver(StreamResult(index=entry["index"], a=a,
                                 reduced=reduced, iters=np.int32(iters),
                                 status=status,
                                 attempts=entry["attempts"]))

        def refill(slot, entry):
            nonlocal frames, env_frames, r, itv, done, hw
            carry = entry.pop("carry", None)
            if carry is None:
                entry["attempts"] += 1
                frames, env_frames, r, itv, done, hw = self._refill_fn(
                    frames, env_frames, r, itv, done, hw,
                    jnp.asarray(slot, jnp.int32),
                    jax.tree.map(jnp.asarray, entry["item"]))
                prev_it[slot] = 0
            else:
                # a snapshotted occupant continues its SAME occupation
                # (attempts unchanged) from its saved iteration
                a_mid, rs, its, hws = carry
                frames, env_frames, r, itv, done, hw = self._restore_fn(
                    frames, env_frames, r, itv, done, hw,
                    jnp.asarray(slot, jnp.int32),
                    jax.tree.map(jnp.asarray, entry["item"]),
                    jnp.asarray(a_mid), jnp.asarray(rs),
                    jnp.asarray(its, jnp.int32),
                    jnp.asarray(hws, jnp.int32))
                prev_it[slot] = int(its)
                self.stats["recovered_occupants"] += 1
            occupants[slot] = entry
            self.stats["h2d_bytes"] += _item_nbytes(entry["item"])
            self.stats["refills"] += 1

        def admit(slot):
            """Fill one free slot, skipping past items the admission
            guard rejects (they emit + dead-letter without consuming
            the slot; drift errors still raise) and items whose final
            result was journaled pre-crash (already re-delivered by the
            replay — recomputing them would break exactly-once)."""
            while True:
                entry = next_entry(slot)
                if entry is None:
                    return
                if entry["index"] in emitted_pre:
                    continue
                try:
                    self._check_item(entry["item"])
                except NonFiniteItemError:
                    self.stats["rejected"] += 1
                    emit(entry, "rejected")
                    continue
                refill(slot, entry)
                return

        def capture(complete=None):
            """Build the :meth:`snapshot` tree from the live run state.
            Interiors extract through the un-donated ``_extract_fn`` —
            the resident frames stay untouched."""
            r_cur = np.asarray(r)
            it_cur = np.asarray(itv).astype(np.int64)
            hw_cur = np.asarray(hw)
            occ = []
            for s in range(L):
                e = occupants[s]
                if e is None:
                    continue
                a_mid = np.asarray(self._extract_fn(
                    frames, jnp.asarray(s, jnp.int32)))
                occ.append({"index": int(e["index"]),
                            "attempts": int(e["attempts"]),
                            "item": e["item"], "a": a_mid,
                            "r": r_cur[s], "it": int(it_cur[s]),
                            "hw": int(hw_cur[s])})
            queued = list(retry_q) + list(staged) + list(pending_entries)
            if complete is None:
                complete = not occ and not queued
            return {"kind": "farm", "version": 1,
                    "segments": int(self.stats["segments"]),
                    "next_index": int(next_index), "n_out": int(n_out),
                    "occupants": occ,
                    # retries first, then ring-staged / unstaged entries
                    # in stream order — a staged-but-unseated item is
                    # queued work the resumed run must not lose
                    "retry": [{"index": int(e["index"]),
                               "attempts": int(e["attempts"]),
                               "item": e["item"]} for e in queued],
                    "complete": bool(complete)}

        self._rt_capture = capture

        def persist(complete=None):
            if recovery is None:
                return
            from repro.resilience.recovery import save_snapshot
            save_snapshot(recovery.snap_dir, self.stats["segments"],
                          capture(complete), keep=recovery.keep)
            self.stats["snapshots"] += 1

        ring = getattr(self, "_ring", None)
        ring_envs = getattr(self, "_ring_envs", ())

        def run_chained():
            """The chained dispatch pipeline: stage(t+1) ∥ run(t) ∥
            drain(t−1).  Every steady-state segment boundary is ONE
            donated ``_chain_fn`` dispatch — segment, emission capture
            and masked batch refill from the device staging ring fused
            into a single jitted call — and the host touches segment
            t's results only through a non-blocking metadata read issued
            AFTER segment t+1 is already in flight.  Retries drop to a
            synchronous repair phase (classic retry-first / bad-slot /
            quarantine admission, ring rewound through
            ``pending_entries``), then the chain resumes."""
            nonlocal frames, env_frames, r, itv, done, hw, prev_it
            nonlocal ring, ring_envs
            K = self._ring_depth
            local_L = L // self._nshards
            rd = jnp.asarray(0, jnp.int32)   # device-side read cursor
            wr_host = 0                      # staged-count watermark
            rd_host = 0                      # host mirror of rd (lags
                                             # by the in-flight takes)
            inflight: deque = deque()        # dispatched, undrained

            def stage_next():
                """Admission-checked staging of ONE entry into the ring
                (the chained twin of ``admit``): rejected items emit
                without touching the ring, journal-replayed indexes
                skip, everything else device_puts AHEAD of need."""
                nonlocal ring, ring_envs, wr_host
                while True:
                    if pending_entries:
                        entry = pending_entries.popleft()
                    else:
                        entry = pull_stream()
                    if entry is None:
                        return False
                    if entry["index"] in emitted_pre:
                        continue
                    try:
                        self._check_item(entry["item"])
                    except NonFiniteItemError:
                        self.stats["rejected"] += 1
                        emit(entry, "rejected")
                        continue
                    break
                # item leaves ride as numpy through the jit fast path —
                # no eager per-leaf device_put on the host's stage side
                ring, ring_envs = self._stage_fn(
                    ring, ring_envs, np.int32(wr_host % K),
                    entry["item"])
                staged.append(entry)
                wr_host += 1
                self.stats["h2d_bytes"] += _item_nbytes(entry["item"])
                return True

            def top_up():
                # rd_host is a conservative lower bound on the device
                # cursor, so staying < K deep can never overwrite a
                # ring position an in-flight chain might still read
                while wr_host - rd_host < K:
                    if not stage_next():
                        return

            def unstage_all():
                """Rewind the ring at a repair boundary: un-seated
                entries re-queue (stream order) ahead of the cursor,
                their device copies are abandoned, and the watermark
                drops back to the mirror cursor — safe because the
                pipeline is fully drained here."""
                nonlocal wr_host
                while staged:
                    pending_entries.appendleft(staged.pop())
                wr_host = rd_host

            # the live mask changes only on quarantine — cache its
            # device copy so the steady-state dispatch pays no per-call
            # host→device conversion (the per-dispatch `wr` watermark
            # rides as a numpy scalar through the jit fast path)
            live_cache = [None, None]          # (key, device array)

            def live_mask():
                key = tuple(slot_dead)
                if live_cache[0] != key:
                    live_cache[0] = key
                    live_cache[1] = jnp.asarray(
                        np.logical_not(slot_dead))
                return live_cache[1]

            def dispatch():
                nonlocal frames, env_frames, r, itv, done, hw
                nonlocal ring, ring_envs, rd
                (frames, env_frames, r, itv, done, hw, ring, ring_envs,
                 rd, meta, r_pre, outs) = self._chain_fn(
                     frames, env_frames, r, itv, done, hw, ring,
                     ring_envs, rd, np.int32(wr_host), live_mask())
                self.stats["segments"] += 1
                if on_segment is not None:
                    # the preemption seam, as in the classic loop:
                    # fires while the segment's results are still
                    # un-journaled (redone from the last snapshot,
                    # never re-emitted)
                    on_segment(self.stats["segments"])
                inflight.append((meta, r_pre, outs))

            def drain_one():
                """Consume the OLDEST in-flight segment: one async
                metadata read (``_meta_read`` — by now the next segment
                is dispatched, so the device never idles on this),
                then classic emission / retry / quarantine bookkeeping
                and the host-mirror replay of the device's ring seats
                (lane order over the finished live slots = the device's
                rank order)."""
                nonlocal prev_it, rd_host
                meta_d, r_d, outs_d = inflight.popleft()
                (meta_h,) = self._meta_read(meta_d)
                fin_h = meta_h[0:L] != 0
                it_h = meta_h[L:2 * L].astype(np.int64)
                hw_h = meta_h[2 * L:3 * L]
                took_h = meta_h[3 * L:4 * L] != 0
                steps_h = meta_h[4 * L:]
                for s in range(self._nshards):
                    sl = slice(s * local_L, (s + 1) * local_L)
                    total = int(steps_h[s]) * unroll * local_L
                    useful = int((it_h[sl] - prev_it[sl]).sum())
                    self.stats["lane_steps"] += total
                    self.stats["wasted_lane_steps"] += total - useful
                prev_it = np.where(took_h, 0, it_h)
                outs_h = r_h = None
                for slot in range(L):
                    entry = occupants[slot]
                    if entry is None or not fin_h[slot]:
                        continue
                    occupants[slot] = None
                    status = item_status(hw_h[slot], it_h[slot],
                                         loop.max_iters)
                    if status != "ok":
                        self.stats["quarantined_lane_steps"] += \
                            int(it_h[slot])
                        slot_fails[slot] += 1
                    else:
                        slot_fails[slot] = 0
                    if status != "ok" and \
                            entry["attempts"] < self.max_attempts:
                        entry["bad_slots"].add(slot)
                        retry_q.append(entry)
                        self.stats["retries"] += 1
                    else:
                        if outs_h is None:   # ONE payload pull per
                            outs_h, r_h = jax.device_get(  # drained seg
                                (outs_d, r_d))
                        out = outs_h[slot]
                        self.stats["d2h_bytes"] += (
                            out.nbytes + r_h[slot].nbytes + 4)
                        emit(entry, status, a=out, reduced=r_h[slot],
                             iters=it_h[slot])
                    if (not slot_dead[slot]
                            and slot_fails[slot] >= self.slot_patience
                            and L - sum(slot_dead) > 1):
                        # quarantine lags one in-flight dispatch: the
                        # chain already in flight may seat one more
                        # occupant here before the live mask catches up
                        slot_dead[slot] = True
                        self.stats["quarantined_slots"] += 1
                for slot in range(L):
                    if not took_h[slot]:
                        continue
                    assert staged, "device seated more than was staged"
                    entry = staged.popleft()
                    entry["attempts"] += 1
                    occupants[slot] = entry
                    self.stats["refills"] += 1
                    rd_host += 1

            while True:
                dispatched = False
                if retry_q:
                    # repair: drain the pipeline, rewind the ring, and
                    # run synchronously on classic admission until the
                    # retry queue is dry (quarantine-exact, retry-first,
                    # bad-slot-aware — the fault contracts unchanged)
                    while inflight:
                        drain_one()
                    unstage_all()
                    for slot in range(L):
                        if occupants[slot] is None \
                                and not slot_dead[slot]:
                            admit(slot)
                    if not any(o is not None for o in occupants):
                        break
                    dispatch()
                    dispatched = True
                    drain_one()
                else:
                    top_up()
                    work = (any(o is not None for o in occupants)
                            or bool(staged) or bool(pending_entries))
                    if not work and not inflight:
                        break
                    if work:
                        dispatch()
                        dispatched = True
                    # lag-1 drain: with a fresh dispatch in flight,
                    # consume only the PREVIOUS segment — the read
                    # overlaps the device's current segment.  With no
                    # dispatch left (tail), flush what remains.
                    if len(inflight) > (1 if dispatched else 0):
                        drain_one()
                if dispatched and recovery is not None and \
                        self.stats["segments"] % \
                        recovery.snapshot_every == 0:
                    # snapshot boundary: ONE explicit pipeline drain
                    # (instead of the classic loop's implicit blocking
                    # sync every segment), then capture a consistent
                    # boundary state
                    while inflight:
                        drain_one()
                    persist()

        try:
            local_L = L // self._nshards
            use_chain = (self.chained
                         and loop.backend != "pallas-sharded")
            # a FRESH chained stream seats its whole first cohort
            # through the staging ring: every slot starts retired, and
            # the first chain dispatch (a zero-step segment) batch-seats
            # from the ring — one fused call instead of L sequential
            # put + per-slot-refill dispatches.  Resumed runs keep the
            # classic admission: mid-flight occupants re-enter through
            # the carry-aware restore path the ring knows nothing about.
            chain_seed = use_chain and state is None and not resume
            if chain_seed:
                r = jnp.full_like(r, loop._id)
                itv = jnp.full_like(itv, loop.max_iters)
                done = jnp.ones_like(done)
                hw = jnp.zeros_like(hw)
            else:
                for slot in range(L):
                    admit(slot)
                    if occupants[slot] is None:  # stream already drained
                        break
            # retired slots may carry iteration counts from a previous
            # stream — baseline the useful-work deltas on the real carry
            prev_it = np.asarray(itv).astype(np.int64)
            persist(complete=False)   # RPO anchor: recoverable before
                                      # the first segment even starts
            if state is not None or resume:
                self.stats["recovery_seconds"] += (
                    _time.perf_counter() - t_resume0)

            if use_chain:
                # composed pallas-sharded farms stay on the classic
                # loop below: their fixed-step segments have no early
                # exit to chain past, and refill must live inside the
                # spatial shard_map
                run_chained()
            else:
                while any(o is not None for o in occupants):
                    (frames, env_frames, r, itv, done, hw,
                     steps) = self._segment_fn(frames, env_frames, r,
                                               itv, done, hw)
                    self.stats["segments"] += 1
                    if on_segment is not None:
                        # the preemption seam: fires BEFORE this
                        # segment's results are journaled — the
                        # harshest crash point (computed-but-
                        # unjournaled work is redone from the last
                        # snapshot, never re-emitted)
                        on_segment(self.stats["segments"])
                    done_h = np.asarray(done)
                    it_h = np.asarray(itv).astype(np.int64)
                    r_h = np.asarray(r)
                    hw_h = np.asarray(hw)
                    steps_h = np.asarray(steps).astype(np.int64)
                    # lane-step accounting: every body step advances
                    # (or idles) every lane of its shard by `unroll`
                    # sweeps
                    for s in range(self._nshards):
                        sl = slice(s * local_L, (s + 1) * local_L)
                        total = int(steps_h[s]) * unroll * local_L
                        useful = int((it_h[sl] - prev_it[sl]).sum())
                        self.stats["lane_steps"] += total
                        self.stats["wasted_lane_steps"] += \
                            total - useful
                    prev_it = it_h.copy()
                    finished = done_h | (it_h >= loop.max_iters)
                    for slot in range(L):
                        entry = occupants[slot]
                        if entry is None or not finished[slot]:
                            continue
                        occupants[slot] = None
                        status = item_status(hw_h[slot], it_h[slot],
                                             loop.max_iters)
                        if status != "ok":
                            # sweeps burned on a doomed occupant
                            self.stats["quarantined_lane_steps"] += \
                                int(it_h[slot])
                            slot_fails[slot] += 1
                        else:
                            slot_fails[slot] = 0
                        if status != "ok" and \
                                entry["attempts"] < self.max_attempts:
                            entry["bad_slots"].add(slot)
                            retry_q.append(entry)
                            self.stats["retries"] += 1
                        else:
                            out = np.asarray(self._extract_fn(
                                frames, jnp.asarray(slot, jnp.int32)))
                            self.stats["d2h_bytes"] += (
                                out.nbytes + r_h[slot].nbytes + 4)
                            emit(entry, status, a=out,
                                 reduced=r_h[slot], iters=it_h[slot])
                        if (not slot_dead[slot]
                                and slot_fails[slot] >=
                                self.slot_patience
                                and L - sum(slot_dead) > 1):
                            # the failures track the SLOT, not its
                            # items: retire it from the rotation
                            # (never the last slot standing)
                            slot_dead[slot] = True
                            self.stats["quarantined_slots"] += 1
                            continue
                        if not slot_dead[slot]:
                            admit(slot)
                    if recovery is not None and \
                            self.stats["segments"] % \
                            recovery.snapshot_every == 0:
                        persist()
            persist(complete=True)
        finally:
            # locals always name the LIVE buffers (the donated inputs
            # were consumed by the calls that produced these), so a
            # raising sink / shape check cannot strand the engine on
            # deleted device buffers
            self._frames, self._env_frames = frames, env_frames
            self._cont_carry = (r, itv, done, hw)
            if ring is not None:
                self._ring, self._ring_envs = ring, ring_envs
            if journal is not None:
                journal.close()
        self.stats["items"] += n_out
        return n_out

    # -- the stream protocol (read ∥ compute ∥ write) --------------------
    def run(self, source, sink, *, continuous: bool = False,
            recovery=None, resume: bool = False,
            on_segment: Optional[Callable] = None) -> int:
        """Drive a whole stream: ``source`` yields items (callable
        returning an iterator, or an iterable), ``sink`` consumes one
        :class:`~repro.core.pattern.LoopResult` per item, in order.

        Host-side double buffering: round i's dispatch is asynchronous,
        so the host drains round i-1 into the sink (and reads round
        i+1's items) while the device runs round i.

        With ``continuous=True`` the stream runs in continuous per-lane
        refill mode instead (see :meth:`run_continuous`): the sink
        receives :class:`StreamResult` objects in completion order and
        no lane ever idles behind a straggler in another slot.
        ``recovery`` / ``resume`` / ``on_segment`` pass through to the
        continuous path (round mode has no segment boundaries to
        snapshot at).
        """
        if continuous:
            return self.run_continuous(source, sink, recovery=recovery,
                                       resume=resume,
                                       on_segment=on_segment)
        if recovery is not None or resume or on_segment is not None:
            raise ValueError(
                "recovery/resume/on_segment need continuous=True "
                "(round mode has no segment boundaries to snapshot at)")
        it = iter(source() if callable(source) else source)
        n = 0
        inflight = None
        while True:
            batch = list(islice(it, self.lanes))
            nxt = self.round(_stack_items(batch), len(batch)) if batch \
                else None
            if inflight is not None:
                n += self._drain(inflight, sink)
            inflight = nxt
            if not batch:
                break
        if inflight is not None:
            n += self._drain(inflight, sink)
        return n

    def _drain(self, result, sink) -> int:
        from .pattern import LoopResult

        # ONE device→host pull per round (this is the point where the
        # host blocks on the in-flight round); per-item results are then
        # zero-copy numpy views, handed to the sink one at a time
        outs, red, iters, hw = jax.device_get(result)
        self.stats["d2h_bytes"] += outs.nbytes + red.nbytes + iters.nbytes
        for i in range(outs.shape[0]):
            sink(LoopResult(a=outs[i], reduced=red[i], iters=iters[i],
                            health=hw[i]))
        return outs.shape[0]
