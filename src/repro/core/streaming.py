"""Stream-parallel tier: pipe / farm / ofarm on the persistent engine.

The paper's two-tier model [1]: data-parallel patterns (stencil, reduce,
Loop-of-stencil-reduce) nest inside stream-parallel ones (pipe, farm).  The
experiments use exactly two compositions:

    pipe(read, sobel, write)                       (§4.2)
    pipe(read, detect, ofarm(restore), write)      (§4.3)

JAX realisation, two tiers of its own:

* the *generic* tier — :func:`pipe`, :func:`farm`, :func:`ofarm`,
  :func:`sharded_farm`, :class:`StreamRunner` — maps arbitrary workers
  over stream items (vmap / batch sharding / async double-buffered
  dispatch).  Kept for map-style stages (Sobel) and as the reference
  path; every batch re-enters the worker from the host.

* the *engine* tier — :class:`FarmEngine` — the FastFlow-style
  persistent-device deployment for farms whose worker is a
  Loop-of-stencil-reduce.  L lane *slots* hold persistent halo frames
  (:mod:`repro.core.frames`), the whole farm advances as ONE done-masked
  ``while_loop`` over the stacked (lanes, frame) carry
  (:meth:`repro.core.pattern.LoopOfStencilReduce.farm_run` semantics),
  and a finished round's slots are *refilled in place* with the next
  items' interiors — no re-pad, no re-allocation, no host round-trip of
  the frame; only new input and extracted output cross the host
  boundary, exactly the paper's device-buffer-persistence-across-stream-
  items design point.  Host-side double buffering (the read stage
  prepares round i+1 and the write stage drains round i-1 while the
  device runs round i) rides on JAX async dispatch.

``ofarm`` ordering comes for free everywhere: lanes are positional and
batched execution is deterministic.
"""
from __future__ import annotations

import dataclasses
from itertools import islice
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipe(*stages: Callable) -> Callable:
    """pipe(a, b, ...) — functional composition b∘a, per stream item."""
    def run(x):
        for s in stages:
            x = s(x)
        return x
    return run


def farm(worker: Callable, *, lanes_axis: int = 0) -> Callable:
    """1:1 mode, generic tier — apply ``worker`` to every item of a
    stacked stream batch via vmap.

    ``worker`` may itself be a Loop-of-stencil-reduce ``run``; done-
    masking makes the vmapped while_loop per-lane correct.  For a farm of
    loops on the persistent engine (one kernel launch per sweep for the
    whole farm, lane slots reusable across stream items) use
    :meth:`~repro.core.pattern.LoopOfStencilReduce.farm_run` /
    :class:`FarmEngine` instead.
    """
    return jax.vmap(worker, in_axes=lanes_axis, out_axes=lanes_axis)


def ofarm(worker: Callable, *, lanes_axis: int = 0) -> Callable:
    """Order-preserving farm.  vmap is deterministic + order-preserving, so
    this is ``farm`` with the paper's ordering contract made explicit."""
    return farm(worker, lanes_axis=lanes_axis)


def sharded_farm(worker: Callable, mesh: Mesh, axis: str = "data") -> Callable:
    """Generic-tier farm whose lanes are spread over a mesh axis.

    The jit wrapper is built ONCE here — constructing ``jax.jit(vw)``
    inside ``run`` would mint a fresh wrapper (and compilation cache) per
    call, retracing the worker on every batch (regression-tested by
    trace counting in tests/core/test_streaming.py).  Every batch still
    ``device_put``s its items and re-enters the worker from the host —
    :class:`FarmEngine` (with ``mesh=``) is the engine-tier replacement
    that keeps per-lane halo frames device-resident across batches.
    """
    jvw = jax.jit(jax.vmap(worker))
    sharding = NamedSharding(mesh, P(axis))

    def run(batch):
        batch = jax.device_put(batch, sharding)
        return jvw(batch)
    return run


@dataclasses.dataclass
class StreamRunner:
    """Host-side streaming driver: feeds batches of stream items through a
    (jitted) worker with double-buffered async dispatch.

    This is the runtime glue of the paper's streaming experiments: while the
    device processes batch i, the host 'read' stage prepares batch i+1 and
    the 'write' stage consumes batch i-1 (JAX async dispatch provides the
    overlap; ``block_until_ready`` only at the sink).

    Generic tier: the worker re-enters from the host per batch.  Farms of
    convergence loops should ride :class:`FarmEngine`, which shares this
    host protocol but keeps the loop state (the halo frames) on device
    between batches.
    """

    worker: Callable                  # jitted device stage
    source: Callable[[], Iterator]    # read stage: yields host items
    sink: Callable[[Any], None]       # write stage: consumes results
    batch: int = 1

    def run(self) -> int:
        it = self.source()
        n = 0
        inflight = None
        while True:
            chunk = []
            for _ in range(self.batch):
                try:
                    chunk.append(next(it))
                except StopIteration:
                    break
            if not chunk and inflight is None:
                break
            nxt = None
            if chunk:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *chunk) if len(chunk) > 1 \
                    else jax.tree.map(lambda x: jnp.asarray(x)[None], chunk[0])
                nxt = self.worker(stacked)   # async dispatch
            if inflight is not None:
                for item in self._unstack(inflight):
                    self.sink(item)
                    n += 1
            inflight = nxt
            if not chunk:
                break
        if inflight is not None:
            for item in self._unstack(inflight):
                self.sink(item)
                n += 1
        return n

    @staticmethod
    def _unstack(batched) -> Iterator:
        """Yield per-item views of a stacked result LAZILY — the sink runs
        on item i before item i+1 is sliced, so a sink that consumes (or
        discards) items incrementally never holds the whole batch of
        slices at once."""
        leaves = jax.tree.leaves(batched)
        if not leaves:
            return
        for i in range(leaves[0].shape[0]):
            yield jax.tree.map(lambda x: x[i], batched)


# ---------------------------------------------------------------------------
# FarmEngine — the lane-resident streaming engine (engine tier).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FarmEngine:
    """Lane-resident streaming farm: persistent-frame lane slots with
    device-side slot refill and host-side double buffering.

    ``loop`` is the per-item worker (a :class:`~repro.core.pattern.
    LoopOfStencilReduce`); ``lanes`` is the number of device-resident
    slots.  The stream advances in *rounds*: L items are staged into the
    slots (an O(interior) in-place refill — the frames were allocated
    once, at stream start), the whole farm runs as ONE done-masked
    while_loop to each lane's own trip count, and the (m, n) results are
    sliced out.  Between rounds nothing but new input and extracted
    output crosses the host boundary; the frames never do.

    ``prep`` optionally maps a raw stream item to ``(a0, env_tuple)`` on
    device (vmapped over lanes) — the farm's per-item read stage (e.g.
    the §4.3 detection pass feeding restoration).

    Deployments:

    * ``mesh=None`` — single device, lanes on the vmapped kernel grid.
    * ``mesh=`` with a single-device backend ("jnp"/"pallas"/
      "pallas-multistep") — lanes spread over ``mesh[lane_axis]`` via
      ``shard_map`` (the 1:1 mode across devices: each shard owns
      lanes/P slots and its own while trip count — no collectives cross
      the lane axis).
    * ``loop.backend == "pallas-sharded"`` — the two-tier composition:
      lanes over ``lane_axis`` × each lane's frame spatially decomposed
      over ``loop.partition``'s axes (all on the same ``mesh``), with the
      lane-batched ppermute ghost exchange inside the shared while body.
      ``prep`` is not supported here (it would run on spatially-local
      blocks).

    Use :meth:`run` for the full source→sink stream protocol, or
    :meth:`round` to push one stacked batch through the slots.
    """

    loop: Any                          # LoopOfStencilReduce worker
    lanes: int = 4
    prep: Optional[Callable] = None    # item -> (a0, env tuple), on device
    mesh: Optional[Mesh] = None
    lane_axis: str = "data"

    def __post_init__(self):
        loop = self.loop
        if loop.state_init is not None:
            raise ValueError("FarmEngine does not support the -s variant "
                             "(per-lane loop states are ambiguous)")
        if loop.mode != "taps" and loop.backend != "jnp":
            raise ValueError("FarmEngine needs mode='taps' on the pallas "
                             f"backends; got mode={loop.mode!r}")
        if self.mesh is not None:
            if self.lane_axis not in self.mesh.axis_names:
                raise ValueError(
                    f"lane_axis {self.lane_axis!r} not in mesh axes "
                    f"{self.mesh.axis_names}")
            if self.lanes % self.mesh.shape[self.lane_axis]:
                raise ValueError(
                    f"lanes={self.lanes} must divide evenly over mesh "
                    f"axis {self.lane_axis!r} "
                    f"(size {self.mesh.shape[self.lane_axis]})")
        if loop.backend == "pallas-sharded":
            if self.mesh is None:
                raise ValueError(
                    "backend='pallas-sharded' lanes need mesh= (carrying "
                    "the lane axis AND the partition's spatial axes)")
            part = loop.partition
            for name in part.axis_names:
                if name == self.lane_axis:
                    raise ValueError(
                        f"partition axis {name!r} collides with "
                        f"lane_axis; use distinct mesh axes for lanes "
                        "and the spatial decomposition")
                if name not in self.mesh.axis_names:
                    raise ValueError(
                        f"partition axis {name!r} missing from mesh "
                        f"axes {self.mesh.axis_names}")
            if self.prep is not None:
                raise ValueError(
                    "prep= is not supported with pallas-sharded lanes "
                    "(it would run on spatially-local blocks)")
        prep = self.prep or (lambda item: (item, ()))
        self._vprep = jax.vmap(prep)
        self._bound = False
        self._frames = None
        self._env_frames = ()
        # one jit wrapper for the stream's lifetime: every round hits the
        # same compilation (trace-count regression-tested); the slot
        # buffers are donated so the refill updates them in place
        self._round_fn = jax.jit(self._round_impl, donate_argnums=(0, 1))
        self.stats = {"items": 0, "rounds": 0, "h2d_bytes": 0,
                      "d2h_bytes": 0}

    # -- static geometry (first item binds the shapes) -------------------
    def _bind(self, item: np.ndarray):
        L = self.lanes
        item = np.asarray(item)
        items_aval = jax.ShapeDtypeStruct((L, *item.shape), item.dtype)
        a_aval, env_avals = jax.eval_shape(self._vprep, items_aval)
        if len(a_aval.shape) != 3:
            raise ValueError(
                f"stream items must be 2-D grids; prep produced "
                f"{a_aval.shape}")
        m, n = a_aval.shape[1:]
        self._loop = self.loop._resolve_unroll((m, n))
        loop = self._loop
        self._item_aval = items_aval
        self._nshards = (1 if self.mesh is None
                         else self.mesh.shape[self.lane_axis])

        if loop.backend == "jnp":
            self._eng, self._lspec = None, None
            self._frames = jnp.zeros((), a_aval.dtype)
            self._env_frames = ()
        elif loop.backend == "pallas-sharded":
            from .executor import ShardedStencilEngine, local_extents

            part = loop.partition
            for name, ax in zip(part.axis_names, part.array_axes):
                nsh = part.mesh.shape[name]
                if (m, n)[ax] % nsh:
                    raise ValueError(
                        f"array axis {ax} (size {(m, n)[ax]}) must "
                        f"divide evenly over mesh axis {name!r} "
                        f"(size {nsh})")
            lm, ln = local_extents(m, n, part)
            self._eng = ShardedStencilEngine(
                f=loop.f, part=part, k=loop.k, boundary=loop.boundary,
                combine=loop.combine, identity=loop.identity,
                delta=loop.delta, measure=loop.measure, block=loop.block,
                unroll=loop.unroll, interpret=loop.interpret)
            self._lspec = self._eng.lane_sspec(lm, ln)
            spatial = [None, None]
            for name, ax in zip(part.axis_names, part.array_axes):
                spatial[ax] = name
            self._spatial = tuple(spatial)
            fshape = self._lspec.local.shape
            gshape = (L,
                      fshape[0] * (part.mesh.shape[spatial[0]]
                                   if spatial[0] else 1),
                      fshape[1] * (part.mesh.shape[spatial[1]]
                                   if spatial[1] else 1))
            self._frames = jax.device_put(
                np.zeros(gshape, a_aval.dtype),
                NamedSharding(self.mesh, self._fspec()))
            self._env_frames = ()
        else:
            from .executor import StencilEngine
            from .frames import alloc_lane_env

            self._eng = StencilEngine(
                f=loop.f, k=loop.k, boundary=loop.boundary,
                combine=loop.combine, identity=loop.identity,
                delta=loop.delta, measure=loop.measure, block=loop.block,
                unroll=loop.unroll, backend=loop.backend,
                interpret=loop.interpret)
            self._lspec = self._eng.lane_spec(L // self._nshards, m, n)
            frames = np.zeros((L, *self._lspec.frame.shape), a_aval.dtype)
            envs = tuple(
                np.zeros((L,) + tuple(
                    alloc_lane_env(self._lspec, e.dtype,
                                   self._eng._halo_env).shape[1:]),
                    e.dtype)
                for e in env_avals)
            if self.mesh is None:
                self._frames = jnp.asarray(frames)
                self._env_frames = tuple(jnp.asarray(e) for e in envs)
            else:
                lane_sh = NamedSharding(self.mesh, P(self.lane_axis))
                self._frames = jax.device_put(frames, lane_sh)
                self._env_frames = tuple(
                    jax.device_put(e, lane_sh) for e in envs)
        self._bound = True

    def _fspec(self) -> P:
        """PartitionSpec of the lane-stacked frames/interiors (composed
        sharded mode: lanes × spatial)."""
        return P(self.lane_axis, *self._spatial)

    # -- one round: refill slots, run the farm, slice results ------------
    def _round_impl(self, frames, env_frames, items, active):
        a0s, envs = self._vprep(items)
        if self.mesh is None:
            return self._local_round(frames, env_frames, a0s, envs,
                                     active)
        from repro.sharding.specs import shard_map

        loop = self._loop
        if loop.backend == "pallas-sharded":
            data_spec = self._fspec()
        else:
            data_spec = P(self.lane_axis)
        fr_spec = P() if loop.backend == "jnp" else data_spec
        env_specs = tuple(data_spec for _ in env_frames)
        fn = shard_map(
            self._local_round, mesh=self.mesh,
            in_specs=(fr_spec, env_specs, data_spec,
                      tuple(data_spec for _ in envs), P(self.lane_axis)),
            out_specs=(fr_spec, env_specs, data_spec, P(self.lane_axis),
                       P(self.lane_axis)))
        return fn(frames, env_frames, a0s, envs, active)

    def _local_round(self, frames, env_frames, interiors, envs, active):
        """The device-side round (directly, or per-shard inside
        shard_map): in-place slot refill → ONE done-masked lane
        while_loop → O(interior) result slices.  Returns
        (frames', env_frames', outs, reduced, iters)."""
        loop = self._loop
        done0 = jnp.logical_not(active)
        if loop.backend == "jnp":
            res = loop.farm_run(interiors, env=envs, done0=done0)
            return frames, env_frames, res.a, res.reduced, res.iters
        eng, lspec = self._eng, self._lspec
        frames, env_frames = eng.refill_lanes(frames, env_frames,
                                              interiors, envs, lspec)
        res = loop._drive_lanes(
            frames,
            step=lambda fr: eng.sweeps_lanes(fr, env_frames, lspec),
            finalize=lambda fr: fr, done0=done0)
        outs = eng.unframe_lanes(res.a, lspec)
        return res.a, env_frames, outs, res.reduced, res.iters

    def round(self, items, count: Optional[int] = None):
        """Push one stacked (≤ lanes, ...) batch through the slots.

        Returns per-item ``(a, reduced, iters)`` stacks of length
        ``count`` (short batches are padded to the lane count on the
        host and masked out on device — the shapes, and therefore the
        compilation, never change).
        """
        items = np.asarray(items)
        count = items.shape[0] if count is None else count
        if count > self.lanes:
            raise ValueError(f"batch of {count} items exceeds "
                             f"lanes={self.lanes}")
        if not self._bound:
            self._bind(items[0])
        elif (items.shape[1:] != self._item_aval.shape[1:]
              or items.dtype != self._item_aval.dtype):
            raise ValueError(
                f"stream item shape changed mid-stream: slots are bound "
                f"to {self._item_aval.shape[1:]}/{self._item_aval.dtype},"
                f" got {items.shape[1:]}/{items.dtype} (build a fresh "
                "FarmEngine per item geometry)")
        # payload accounting, symmetric with _drain's d2h: the zero
        # lanes padding a ragged round are implementation overhead, not
        # per-item traffic
        self.stats["h2d_bytes"] += (items.nbytes // items.shape[0]) * count
        if items.shape[0] < self.lanes:
            pad = np.zeros((self.lanes - items.shape[0],
                            *items.shape[1:]), items.dtype)
            items = np.concatenate([items, pad], axis=0)
        if count == self.lanes:
            if getattr(self, "_active_full", None) is None:
                self._active_full = jnp.ones((self.lanes,), bool)
            active = self._active_full
        else:
            active = jnp.asarray(np.arange(self.lanes) < count)
        self.stats["rounds"] += 1
        self.stats["items"] += count
        self._frames, self._env_frames, outs, red, iters = self._round_fn(
            self._frames, self._env_frames, jnp.asarray(items), active)
        return outs[:count], red[:count], iters[:count]

    # -- the stream protocol (read ∥ compute ∥ write) --------------------
    def run(self, source, sink) -> int:
        """Drive a whole stream: ``source`` yields items (callable
        returning an iterator, or an iterable), ``sink`` consumes one
        :class:`~repro.core.pattern.LoopResult` per item, in order.

        Host-side double buffering: round i's dispatch is asynchronous,
        so the host drains round i-1 into the sink (and reads round
        i+1's items) while the device runs round i.
        """
        it = iter(source() if callable(source) else source)
        n = 0
        inflight = None
        while True:
            batch = list(islice(it, self.lanes))
            nxt = self.round(np.stack(batch), len(batch)) if batch \
                else None
            if inflight is not None:
                n += self._drain(inflight, sink)
            inflight = nxt
            if not batch:
                break
        if inflight is not None:
            n += self._drain(inflight, sink)
        return n

    def _drain(self, result, sink) -> int:
        from .pattern import LoopResult

        # ONE device→host pull per round (this is the point where the
        # host blocks on the in-flight round); per-item results are then
        # zero-copy numpy views, handed to the sink one at a time
        outs, red, iters = jax.device_get(result)
        self.stats["d2h_bytes"] += outs.nbytes + red.nbytes + iters.nbytes
        for i in range(outs.shape[0]):
            sink(LoopResult(a=outs[i], reduced=red[i], iters=iters[i]))
        return outs.shape[0]
