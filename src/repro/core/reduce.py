"""/(⊕) — parallel reduce, and the paper's two-phase device reduce.

The paper realises reduce as "a sequence of partial GPU-side reduces,
followed by a global host-side reduce" (§1) and fuses the first partial
reduce into the stencil kernel (§3.3, ``stencil<SUM_kernel,MF_kernel>``).
On TPU the same structure appears as: per-tile partials inside the Pallas
kernel (or per-shard partials inside shard_map), then a tiny final combine —
here :func:`tree_reduce` / :func:`two_phase_reduce` — that XLA keeps on
device (stronger than the paper's host-side final reduce).
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Callable

import jax
import jax.numpy as jnp

# Named monoids usable across the codebase (op, identity).
MONOIDS = {
    "sum": (operator.add, 0.0),
    "prod": (operator.mul, 1.0),
    "max": (jnp.maximum, -jnp.inf),
    "min": (jnp.minimum, jnp.inf),
    "any": (jnp.logical_or, False),
    "all": (jnp.logical_and, True),
}


def resolve_monoid(op, identity):
    """Accept either a named monoid ('sum') or an (op, identity) pair."""
    if isinstance(op, str):
        return MONOIDS[op]
    if identity is None:
        raise ValueError("identity required for custom combinator")
    return op, identity


def collective_combine(op: Callable, r: jnp.ndarray,
                       axis_names) -> jnp.ndarray:
    """Monoid-aware global combine of per-shard partials over mesh axes.

    The cross-device phase of the paper's two-phase reduce: every shard
    contributes its local fold and every shard receives the identical
    global value, so a convergence condition evaluated per-shard agrees
    everywhere (no host in the loop).  Named monoids map onto the native
    collective (``psum``/``pmax``/``pmin``); ``any``/``all`` go through a
    psum of indicator counts; other associative ops must be psum-compatible
    (i.e. ``op`` must *be* addition-like) — there is no generic
    all-reduce for arbitrary combinators on the mesh.
    """
    from jax import lax
    for name in axis_names:
        if op is jnp.maximum or op is jnp.minimum:
            # XLA's all-reduce max/min DROP NaN (unlike jnp.maximum),
            # which would silently un-poison a ⊥=NaN convergence measure
            # on exactly one deployment — re-propagate it explicitly so
            # every shard sees the same (possibly NaN) value.
            coll = lax.pmax(r, name) if op is jnp.maximum \
                else lax.pmin(r, name)
            if jnp.issubdtype(r.dtype, jnp.floating):
                nanq = lax.psum(jnp.isnan(r).astype(jnp.float32), name)
                coll = jnp.where(nanq > 0,
                                 jnp.asarray(jnp.nan, coll.dtype), coll)
            r = coll
        elif op in (jnp.logical_or, jnp.logical_and):
            rf = lax.psum(r.astype(jnp.float32), name)
            r = (rf > 0) if op is jnp.logical_or else (
                rf >= lax.psum(1.0, name))
        else:
            r = lax.psum(r, name)
    return r


# ---------------------------------------------------------------------------
# Convergence sentinels — the per-lane health word.
#
# The fused delta-reduce already computes one scalar per lane per sweep to
# drive the convergence condition; the sentinel reads THAT value (zero
# extra passes over the grid) and folds what it sees into a packed int32
# health word carried alongside (r, it, done):
#
#     bits 0..15   stall counter — consecutive sweeps whose reduce value
#                  failed to decrease (the divergence detector's memory)
#     bit  16      CONVERGED — the condition c fired for this lane
#     bit  17      POISONED — the reduce value went NaN/Inf
#     bit  18      DIVERGED — the stall counter hit the sentinel patience
#
# POISONED/DIVERGED quarantine the lane: the driver masks it done so it
# stops spinning (and, in the composed deployment, stops feeding the
# step-aligned ghost exchange with sweeps nobody needs).  A lane that
# hits max_iters with neither CONVERGED nor a fault bit reads as
# nonconverged — budget exhaustion needs no bit of its own.
# ---------------------------------------------------------------------------

HEALTH_STALL_MASK = (1 << 16) - 1
HEALTH_CONVERGED = 1 << 16
HEALTH_POISONED = 1 << 17
HEALTH_DIVERGED = 1 << 18

STATUS_OK = "ok"
STATUS_NONCONVERGED = "nonconverged"
STATUS_POISONED = "poisoned"


@dataclasses.dataclass(frozen=True)
class Sentinel:
    """Per-lane health policy riding the fused reduce.

    ``nan``       — poison a lane whose reduce value goes non-finite
                    (float reduce dtypes only; a bool/any-monoid reduce
                    has nothing to poison).
    ``patience``  — quarantine a lane whose reduce value has not
                    DECREASED for this many consecutive condition checks
                    (0 disables the divergence detector; leave it off
                    for oscillating but convergent measures).
    """
    nan: bool = True
    patience: int = 0


def health_update(hw, r_new, r_prev, live, converged, it, sentinel):
    """One sentinel step: fold this check's reduce value into the packed
    per-lane health words.  All inputs are (lanes,) vectors except
    ``sentinel`` (static) — jit/vmap/shard_map-safe, no collectives.

    Returns ``(hw', quarantine)`` where ``quarantine`` marks lanes the
    driver must mask done NOW (poisoned or diverged) — distinct from
    CONVERGED, which the driver's own done-mask already handles.
    """
    hw = jnp.asarray(hw, jnp.int32)
    stall = jnp.bitwise_and(hw, HEALTH_STALL_MASK)
    flags = hw - stall
    floatlike = jnp.issubdtype(jnp.asarray(r_new).dtype, jnp.floating)
    if sentinel is not None and sentinel.nan and floatlike:
        poison = jnp.logical_and(live, ~jnp.isfinite(r_new))
    else:
        poison = jnp.zeros(hw.shape, bool)
    if sentinel is not None and sentinel.patience > 0 and floatlike:
        # "non-decreasing" against the previous CHECK's value; the first
        # check compares against the identity element, which is not a
        # real iterate — let it pass
        stalled = jnp.logical_and(live,
                                  jnp.logical_and(it > 0, r_new >= r_prev))
        stall = jnp.where(live, jnp.where(stalled, stall + 1, 0), stall)
        diverged = stall >= sentinel.patience
    else:
        diverged = jnp.zeros(hw.shape, bool)
    flags = jnp.where(jnp.logical_and(live, converged),
                      jnp.bitwise_or(flags, HEALTH_CONVERGED), flags)
    flags = jnp.where(poison, jnp.bitwise_or(flags, HEALTH_POISONED),
                      flags)
    flags = jnp.where(diverged, jnp.bitwise_or(flags, HEALTH_DIVERGED),
                      flags)
    quarantine = jnp.logical_and(live, jnp.logical_or(poison, diverged))
    return jnp.bitwise_or(flags, stall), quarantine


def health_status(hw) -> str:
    """Host-side status taxonomy of one packed health word.  Poison wins
    over everything (a NaN result is never 'ok' however the condition
    read it); a clean CONVERGED bit is the only path to 'ok'."""
    hw = int(hw)
    if hw & HEALTH_POISONED:
        return STATUS_POISONED
    if hw & HEALTH_DIVERGED:
        return STATUS_NONCONVERGED
    if hw & HEALTH_CONVERGED:
        return STATUS_OK
    return STATUS_NONCONVERGED


def tree_reduce(op: Callable, a: jnp.ndarray, identity) -> jnp.ndarray:
    """Balanced-tree fold of the associative ⊕ over all items of ``a``.

    Log-depth pairwise combine; identical result structure to the paper's
    reduction tree and to :func:`repro.core.semantics.reduce_all`, but built
    from O(log n) vectorised ops so XLA lowers it efficiently.
    """
    flat = a.reshape(-1)
    n = flat.shape[0]
    size = 1 if n == 0 else 1 << (n - 1).bit_length()
    if size != n:
        flat = jnp.concatenate(
            [flat, jnp.full((size - n,), identity, dtype=flat.dtype)])
    while flat.shape[0] > 1:
        flat = op(flat[0::2], flat[1::2])
    return flat[0]


def two_phase_reduce(op: Callable, a: jnp.ndarray, identity,
                     tile: int = 4096) -> jnp.ndarray:
    """Paper's two-phase reduce: tile partials then final combine.

    Phase 1 mirrors the device-side partial reduce (each tile folds
    locally); phase 2 is the small final reduce.  Extensionally equal to
    :func:`tree_reduce` for associative+commutative ⊕.
    """
    flat = a.reshape(-1)
    n = flat.shape[0]
    ntiles = max(1, -(-n // tile))
    size = ntiles * tile
    if size != n:
        flat = jnp.concatenate(
            [flat, jnp.full((size - n,), identity, dtype=flat.dtype)])
    partials = flat.reshape(ntiles, tile)
    # phase 1: per-tile fold (vectorised across tiles)
    while partials.shape[1] > 1:
        half = partials.shape[1] // 2
        partials = op(partials[:, :half], partials[:, half:])
    # phase 2: final combine of the ntiles partials
    return tree_reduce(op, partials[:, 0], identity)
