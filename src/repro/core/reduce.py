"""/(⊕) — parallel reduce, and the paper's two-phase device reduce.

The paper realises reduce as "a sequence of partial GPU-side reduces,
followed by a global host-side reduce" (§1) and fuses the first partial
reduce into the stencil kernel (§3.3, ``stencil<SUM_kernel,MF_kernel>``).
On TPU the same structure appears as: per-tile partials inside the Pallas
kernel (or per-shard partials inside shard_map), then a tiny final combine —
here :func:`tree_reduce` / :func:`two_phase_reduce` — that XLA keeps on
device (stronger than the paper's host-side final reduce).
"""
from __future__ import annotations

import operator
from typing import Callable

import jax
import jax.numpy as jnp

# Named monoids usable across the codebase (op, identity).
MONOIDS = {
    "sum": (operator.add, 0.0),
    "prod": (operator.mul, 1.0),
    "max": (jnp.maximum, -jnp.inf),
    "min": (jnp.minimum, jnp.inf),
    "any": (jnp.logical_or, False),
    "all": (jnp.logical_and, True),
}


def resolve_monoid(op, identity):
    """Accept either a named monoid ('sum') or an (op, identity) pair."""
    if isinstance(op, str):
        return MONOIDS[op]
    if identity is None:
        raise ValueError("identity required for custom combinator")
    return op, identity


def collective_combine(op: Callable, r: jnp.ndarray,
                       axis_names) -> jnp.ndarray:
    """Monoid-aware global combine of per-shard partials over mesh axes.

    The cross-device phase of the paper's two-phase reduce: every shard
    contributes its local fold and every shard receives the identical
    global value, so a convergence condition evaluated per-shard agrees
    everywhere (no host in the loop).  Named monoids map onto the native
    collective (``psum``/``pmax``/``pmin``); ``any``/``all`` go through a
    psum of indicator counts; other associative ops must be psum-compatible
    (i.e. ``op`` must *be* addition-like) — there is no generic
    all-reduce for arbitrary combinators on the mesh.
    """
    from jax import lax
    for name in axis_names:
        if op is jnp.maximum or op is jnp.minimum:
            # XLA's all-reduce max/min DROP NaN (unlike jnp.maximum),
            # which would silently un-poison a ⊥=NaN convergence measure
            # on exactly one deployment — re-propagate it explicitly so
            # every shard sees the same (possibly NaN) value.
            coll = lax.pmax(r, name) if op is jnp.maximum \
                else lax.pmin(r, name)
            if jnp.issubdtype(r.dtype, jnp.floating):
                nanq = lax.psum(jnp.isnan(r).astype(jnp.float32), name)
                coll = jnp.where(nanq > 0,
                                 jnp.asarray(jnp.nan, coll.dtype), coll)
            r = coll
        elif op in (jnp.logical_or, jnp.logical_and):
            rf = lax.psum(r.astype(jnp.float32), name)
            r = (rf > 0) if op is jnp.logical_or else (
                rf >= lax.psum(1.0, name))
        else:
            r = lax.psum(r, name)
    return r


def tree_reduce(op: Callable, a: jnp.ndarray, identity) -> jnp.ndarray:
    """Balanced-tree fold of the associative ⊕ over all items of ``a``.

    Log-depth pairwise combine; identical result structure to the paper's
    reduction tree and to :func:`repro.core.semantics.reduce_all`, but built
    from O(log n) vectorised ops so XLA lowers it efficiently.
    """
    flat = a.reshape(-1)
    n = flat.shape[0]
    size = 1 if n == 0 else 1 << (n - 1).bit_length()
    if size != n:
        flat = jnp.concatenate(
            [flat, jnp.full((size - n,), identity, dtype=flat.dtype)])
    while flat.shape[0] > 1:
        flat = op(flat[0::2], flat[1::2])
    return flat[0]


def two_phase_reduce(op: Callable, a: jnp.ndarray, identity,
                     tile: int = 4096) -> jnp.ndarray:
    """Paper's two-phase reduce: tile partials then final combine.

    Phase 1 mirrors the device-side partial reduce (each tile folds
    locally); phase 2 is the small final reduce.  Extensionally equal to
    :func:`tree_reduce` for associative+commutative ⊕.
    """
    flat = a.reshape(-1)
    n = flat.shape[0]
    ntiles = max(1, -(-n // tile))
    size = ntiles * tile
    if size != n:
        flat = jnp.concatenate(
            [flat, jnp.full((size - n,), identity, dtype=flat.dtype)])
    partials = flat.reshape(ntiles, tile)
    # phase 1: per-tile fold (vectorised across tiles)
    while partials.shape[1] > 1:
        half = partials.shape[1] // 2
        partials = op(partials[:, :half], partials[:, half:])
    # phase 2: final combine of the ntiles partials
    return tree_reduce(op, partials[:, 0], identity)
