"""The Loop-of-stencil-reduce pattern — production implementation.

Pattern semantics (paper §3.1, all variants, composable):

    repeat
        a = stencil(σ_k, f) : a          # -i: f also sees absolute indexes
        [d = α(δ) : ⟨a_new, a_old⟩]      # -d: measure the change
        [s = update(s, ...)]             # -s: global loop state
    until c(/⊕ : a_or_d [, s])

The whole loop lowers into a single ``jax.lax.while_loop`` — the TPU
realisation of the paper's *device memory persistence*: the grid never
leaves HBM, buffers are swapped by XLA, and (beyond the paper) even the
convergence reduce + condition stay on device.

The ``backend`` axis picks the loop-body realisation (see
:mod:`repro.core.executor`): ``"jnp"`` applies the stencil through the
shift algebra (pad per application); ``"pallas"`` iterates the fused
Pallas kernel on a *persistent halo frame* — padding and block round-up
happen once before the loop, the frame is the while-carry, and only the
O(m+n) ghost ring is re-asserted per sweep; ``"pallas-multistep"``
additionally fuses ``unroll`` sweeps per HBM round-trip (temporal
blocking).  Read-only per-cell fields (the paper's ``env``) enter through
``run(..., env=(...))`` and are staged once alongside the frame.

Loop bodies are *done-masked* so the pattern is ``vmap``-safe: under
``farm`` (streaming 1:1 mode) each stream item runs to its own trip count
while vmap executes until all are done.  :meth:`LoopOfStencilReduce.
farm_run` makes that mode first-class — ONE while_loop over a stacked
(lanes, frame) carry with per-lane done masks — and
:class:`repro.core.streaming.FarmEngine` streams through it with lane
slots that persist (and are refilled in place) across stream items.

``step`` mode generalises the stencil to an arbitrary pytree transformer —
the k=0 map-reduce case the paper notes is subsumed — which is how the
trainer (:mod:`repro.train.trainer`) and the decode engine
(:mod:`repro.serve.engine`) instantiate the pattern.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .executor import BACKENDS
from .reduce import (HEALTH_STALL_MASK, health_update, resolve_monoid,
                     tree_reduce)
from .semantics import Boundary
from .stencil import stencil_taps, stencil_windows, stencil_indexed


def segmented_while(body, carry, *, finished, segment, early_exit=True):
    """Bounded early-exit slice of a done-masked lane loop.

    The continuous-refill primitive shared by the farm tier
    (:meth:`LoopOfStencilReduce.lane_segment`) and the serve tier
    (:class:`repro.serve.engine.ContinuousEngine`): run ``body`` (carry →
    carry) until

    * any lane **newly** satisfies ``finished(carry)`` (a (lanes,) bool —
      the dispatcher must be told so it can refill that lane's slot), or
    * no unfinished lane remains (nothing left to advance), or
    * ``segment`` body steps have elapsed (the bounded-latency knob: the
      dispatcher regains control at least this often even when nothing
      converges, e.g. to admit work that arrived after the segment was
      dispatched).

    Lanes already finished at entry do NOT trigger the early exit — only
    a 0→1 transition of the finished mask does, so a segment entered with
    retired lanes (queue drained) keeps advancing the live ones.
    Returns ``(carry', steps)``; the carry shapes round-trip unchanged,
    so ONE compilation serves every segment.

    ``early_exit=False`` runs EXACTLY ``segment`` done-masked body steps
    instead (a ``fori_loop`` — no data-dependent trip count).  This is
    the uniform-schedule variant for deployments whose body carries
    collectives that must stay step-aligned across independently paced
    shard groups: the composed lanes × spatial farm exchanges ghost
    strips by ppermute inside the body, so a data-dependent early exit
    on one lane shard would desynchronise the other shards' exchange
    rendezvous (the convergence masks still freeze each lane at its own
    trip count — only the *schedule* is fixed).
    """
    if not early_exit:
        carry = jax.lax.fori_loop(0, segment, lambda _, c: body(c), carry)
        return carry, jnp.asarray(segment, jnp.int32)
    fin0 = finished(carry)

    def seg_body(c):
        inner, steps = c
        return body(inner), steps + 1

    def seg_cond(c):
        inner, steps = c
        fin = finished(inner)
        newly = jnp.any(jnp.logical_and(fin, jnp.logical_not(fin0)))
        return jnp.logical_and(
            jnp.any(jnp.logical_not(fin)),
            jnp.logical_and(steps < segment, jnp.logical_not(newly)))

    carry, steps = jax.lax.while_loop(
        seg_cond, seg_body, (carry, jnp.asarray(0, jnp.int32)))
    return carry, steps


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LoopResult:
    """Final state of a Loop-of-stencil-reduce run (a pytree: farm/vmap-able)."""
    a: Any                 # the converged array (or pytree in step mode)
    reduced: jnp.ndarray   # last /⊕ value (what the condition saw)
    iters: jnp.ndarray     # number of stencil iterations executed
    state: Any = None      # final loop state (-s variant), None otherwise
    health: Any = None     # packed per-lane health word(s) — decode with
                           # repro.core.reduce.health_status


@dataclasses.dataclass
class LoopOfStencilReduce:
    """Loop-of-stencil-reduce(k, f, ⊕, c, a) with -i / -d / -s variants.

    Parameters
    ----------
    f:        elemental function.  Signature depends on ``mode``:
                taps    — f(get) -> array              (fast shift algebra)
                windows — f(w) -> array                (materialised σ_k)
                indexed — f(w, idx) -> array           (-i variant, σ̄_k)
                step    — f(a) -> a                    (generalised map step)
    k:        stencil radius (halo depth).  Ignored in step mode.
    combine:  ⊕ — a monoid name ('sum','max','min','any','all','prod') or a
              binary associative callable (then ``identity`` is required).
    cond:     c — termination condition.  c(reduced) or c(reduced, state)
              when ``state_init`` is given.  Loop stops when it returns True
              (paper's repeat/until: the body always runs at least once).
    delta:    δ — optional; switches on the -d variant: the reduce runs over
              ``delta(a_new, a_old)`` instead of ``a_new``.
    measure:  optional map from the post-step value to the array the reduce
              folds (needed in step mode when ``a`` is a pytree).
    state_init / state_update: the -s variant.  ``state_update(s, reduced_
              input_array, it)`` runs after the stencil, before the reduce
              feeds the condition.
    boundary: ⊥ model at the domain edge (zero/nan/reflect/wrap).
    max_iters: hard iteration cap (safety net; the paper's runtime has the
              same guard in the iteration-condition plumbing).
    unroll:   check the condition every ``unroll`` stencil applications
              (beyond-paper optimisation: amortises the reduce+condition;
              may overshoot convergence by < unroll iterations).  Under
              ``backend="pallas-multistep"`` this is also the temporal-
              blocking depth T (sweeps fused per HBM round-trip).
              ``unroll="auto"`` picks T from the cost heuristic
              (:func:`repro.core.executor.auto_unroll`: mesh-aware
              k·T < min(local m, n) ceiling + redundant-compute limit) at
              ``run`` time, once the grid shape is known; an explicit
              infeasible T raises with the feasible ceiling spelled out.
    backend:  loop-body realisation — "jnp" (shift algebra), "pallas"
              (fused kernel on a persistent halo frame),
              "pallas-multistep" (temporal blocking), or "pallas-sharded"
              (the 1:n deployment: the whole loop inside ``shard_map``,
              per-shard frames, ppermute ghost exchange, collective
              reduce; requires ``partition``).  Pallas backends require
              ``mode="taps"`` and a 2-D array.
    partition: a :class:`repro.sharding.specs.GridPartition` describing
              the mesh decomposition — required by (and only meaningful
              for) ``backend="pallas-sharded"``.
    block:    Pallas tile shape (clipped to the rounded domain).
    interpret: force Pallas interpret mode (None = auto: interpret
              everywhere but TPU).
    sentinel: a :class:`repro.core.reduce.Sentinel` health policy, or
              None (the default — only the CONVERGED bit is tracked).
              The sentinel reads the SAME fused reduce value the
              condition sees (zero extra passes): a lane whose reduce
              goes NaN/Inf (``nan=True``) or fails to decrease for
              ``patience`` consecutive checks is QUARANTINED — masked
              done immediately so it stops spinning (and, in the
              composed deployment, stops feeding the step-aligned ghost
              exchange).  Decode the per-lane outcome from
              ``LoopResult.health`` with :func:`repro.core.reduce.
              health_status`.
    fault_hook: deterministic fault-injection seam (lane paths only):
              ``hook(r, it) -> r`` intercepts the (lanes,) reduce vector
              after each check — see :mod:`repro.resilience.faults`.
              Production deployments leave it None.
    """

    f: Callable
    k: int = 1
    combine: Any = "sum"
    identity: Any = None
    cond: Callable = None
    mode: str = "taps"
    delta: Optional[Callable] = None
    measure: Optional[Callable] = None
    state_init: Optional[Callable] = None
    state_update: Optional[Callable] = None
    boundary: Boundary | str = Boundary.ZERO
    max_iters: int = 10_000
    unroll: int = 1
    backend: str = "jnp"
    partition: Optional[Any] = None
    block: tuple = (256, 256)
    interpret: Optional[bool] = None
    sentinel: Optional[Any] = None
    fault_hook: Optional[Callable] = None

    def __post_init__(self):
        self._op, self._id = resolve_monoid(self.combine, self.identity)
        self.boundary = Boundary(self.boundary)
        if self.cond is None:
            raise ValueError("a termination condition c is required")
        if self.mode not in ("taps", "windows", "indexed", "step"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; choose from {BACKENDS}")
        if self.backend == "pallas-sharded" and self.partition is None:
            raise ValueError(
                "backend='pallas-sharded' needs a partition= "
                "(repro.sharding.specs.GridPartition)")
        if self.unroll != "auto" and (not isinstance(self.unroll, int)
                                      or self.unroll < 1):
            raise ValueError(
                f"unroll must be a positive int or 'auto'; "
                f"got {self.unroll!r}")
        if self.sentinel is not None and not (
                0 <= self.sentinel.patience <= HEALTH_STALL_MASK):
            raise ValueError(
                f"sentinel patience {self.sentinel.patience} outside "
                f"[0, {HEALTH_STALL_MASK}] (the health word's stall "
                "counter width)")

    # -- single stencil application ------------------------------------
    def _apply(self, a, env=()):
        f = self.f if not env else (lambda *args: self.f(*args, *env))
        if self.mode == "taps":
            return stencil_taps(f, a, self.k, self.boundary)
        if self.mode == "windows":
            return stencil_windows(f, a, self.k, self.boundary)
        if self.mode == "indexed":
            return stencil_indexed(f, a, self.k, self.boundary)
        return f(a)  # step mode

    def _measure(self, a_new, a_old):
        if self.delta is not None:
            m = self.delta(a_new, a_old)
        elif self.measure is not None:
            m = self.measure(a_new)
        else:
            m = a_new
        if not isinstance(m, jnp.ndarray) and not hasattr(m, "reshape"):
            raise TypeError(
                "reduce input must be an array; supply `measure` for pytrees")
        return m

    def _reduce(self, m):
        return tree_reduce(self._op, m, self._id)

    def _cond_value(self, r, s):
        c = self.cond(r, s) if self.state_init is not None else self.cond(r)
        return jnp.asarray(c, dtype=bool).reshape(())

    # -- the loop --------------------------------------------------------
    def run(self, a0, state0=None, *, env=()) -> LoopResult:
        """Execute the pattern on ``a0`` (device-resident end to end).

        ``env`` holds read-only per-cell fields passed to ``f`` after its
        positional arguments (the paper Fig. 2 ``env`` schema).  On the
        Pallas backends they are staged into device frames once, before
        the loop.
        """
        if self.state_init is not None and state0 is None:
            state0 = self.state_init()
        resolved = self._resolve_unroll(getattr(a0, "shape", None))
        if resolved is not self:
            return resolved.run(a0, state0, env=env)
        if self.backend != "jnp":
            if self.mode != "taps" or getattr(a0, "ndim", None) != 2:
                raise ValueError(
                    "pallas backends require mode='taps' and a 2-D array; "
                    f"got mode={self.mode!r}, "
                    f"ndim={getattr(a0, 'ndim', None)}")
            if self.backend == "pallas-sharded":
                return self._run_sharded(a0, state0, env)
            return self._run_persistent(a0, state0, env)

        def one_iter(a):
            """unroll× stencil applications + the fused measure/reduce of
            the final one (against the second-to-last iterate)."""
            a_prev = a
            for _ in range(self.unroll):
                a_prev, a = a, self._apply(a, env)
            return a, self._reduce(self._measure(a, a_prev))

        return self._drive(a0, state0, step=one_iter,
                           state_view=lambda a: a,
                           finalize=lambda a: a)

    # -- unroll resolution (the T auto-tuner seam) -----------------------
    def _resolve_unroll(self, shape,
                        segment=None) -> "LoopOfStencilReduce":
        """Resolve ``unroll="auto"`` against the grid shape (and mesh for
        the sharded backend), and fail loudly on an infeasible explicit T.
        Returns ``self`` when nothing changes, else a resolved copy.
        ``segment`` (continuous farms: body steps per dispatch) folds the
        per-dispatch cost into the tuning — see
        :func:`~repro.core.executor.auto_unroll`."""
        from .executor import auto_unroll, check_unroll_feasible

        if shape is None or len(shape) < 2:
            if self.unroll == "auto":
                return dataclasses.replace(self, unroll=1)
            return self
        m, n = shape[-2], shape[-1]
        part = (self.partition if self.backend == "pallas-sharded"
                else None)
        if self.unroll == "auto":
            deep = self.backend in ("pallas-multistep", "pallas-sharded")
            T = auto_unroll(m, n, k=self.k, block=self.block,
                            part=part, segment=segment) if deep else 1
            return dataclasses.replace(self, unroll=T)
        if self.backend in ("pallas", "pallas-multistep",
                            "pallas-sharded"):
            sweeps = (self.unroll
                      if self.backend != "pallas" else 1)
            check_unroll_feasible(m, n, max(sweeps, 1), k=self.k,
                                  part=part)
        return self

    # -- the persistent-halo loop (pallas backends) ----------------------
    def _run_persistent(self, a0, state0, env) -> LoopResult:
        """Zero-copy realisation: the halo frame is the while-carry.

        Padding/round-up happens once in ``prepare``; the loop body is
        kernel sweeps + O(m+n) ghost refresh — no ``jnp.pad`` or full-grid
        slice per iteration.  The domain is sliced back exactly once after
        convergence.  (The -s variant's ``state_update`` still sees the
        (m, n) view each check, which costs a slice — avoid combining a
        per-iteration state with the persistent backends on hot paths.)
        """
        from .executor import StencilEngine

        eng = StencilEngine(
            f=self.f, k=self.k, boundary=self.boundary,
            combine=self.combine, identity=self.identity, delta=self.delta,
            measure=self.measure, block=self.block, unroll=self.unroll,
            backend=self.backend, interpret=self.interpret)
        frame0, env_frames, spec = eng.prepare(a0, env)
        return self._drive(frame0, state0,
                           step=lambda fr: eng.sweeps(fr, env_frames, spec),
                           state_view=lambda fr: eng.unframe(fr, spec),
                           finalize=lambda fr: eng.unframe(fr, spec))

    # -- the sharded persistent loop (1:n deployment) --------------------
    def _run_sharded(self, a0, state0, env) -> LoopResult:
        """The whole repeat/until runs INSIDE ``shard_map``: each shard's
        while-carry is its local halo frame, the per-check ghost refresh
        is a ppermute of edge strips, and the fused reduce composes with
        the monoid collective so every shard evaluates the identical
        condition — one SPMD program, no host (and no full-block copy)
        in the loop.
        """
        from repro.sharding.specs import shard_map
        from .executor import ShardedStencilEngine

        if self.state_init is not None or state0 is not None:
            raise ValueError(
                "the -s variant is not supported on backend="
                "'pallas-sharded' (per-shard state views are ambiguous)")
        part = self.partition
        for name, ax in zip(part.axis_names, part.array_axes):
            nsh = part.mesh.shape[name]
            if a0.shape[ax] % nsh:
                raise ValueError(
                    f"array axis {ax} (size {a0.shape[ax]}) must divide "
                    f"evenly over mesh axis {name!r} (size {nsh})")
        eng = ShardedStencilEngine(
            f=self.f, part=part, k=self.k, boundary=self.boundary,
            combine=self.combine, identity=self.identity, delta=self.delta,
            measure=self.measure, block=self.block, unroll=self.unroll,
            interpret=self.interpret)

        def local_run(block, *env_local):
            frame0, env_frames, sspec = eng.prepare(block, env_local)
            res = self._drive(
                frame0, None,
                step=lambda fr: eng.sweeps(fr, env_frames, sspec),
                state_view=lambda fr: eng.unframe(fr, sspec),
                finalize=lambda fr: eng.unframe(fr, sspec))
            return res.a, res.reduced, res.iters, res.health

        from jax.sharding import PartitionSpec as P
        pspec = part.pspec
        # reduced/iters/health are shard-invariant (the collective
        # combine hands every shard the identical reduce value, so the
        # sentinel folds identically everywhere)
        fn = shard_map(local_run, mesh=part.mesh,
                       in_specs=(pspec,) * (1 + len(env)),
                       out_specs=(pspec, P(), P(), P()))
        a, r, it, hw = fn(a0, *env)
        return LoopResult(a=a, reduced=r, iters=it, state=None, health=hw)

    # -- the lane-stacked loop (1:1 streaming farm) ----------------------
    def farm_run(self, a0, *, env=(), done0=None) -> LoopResult:
        """Run a FARM of convergence loops as ONE done-masked while_loop
        over a stacked (lanes, ...) carry — the paper's 1:1 streaming
        mode on the persistent engine.

        ``a0`` carries a leading lane axis ((lanes, m, n) on the array
        backends; any pytree of lane-stacked leaves in step mode), and so
        does every ``env`` field (stream items bring their own env).  On
        the Pallas backends the lane frames are built once and every
        sweep is ONE vmapped kernel launch; each lane runs to its own
        trip count (``done0`` pre-masks lanes — the streaming engine uses
        it for ragged final rounds).  Results match ``vmap(self.run)``
        lane for lane; ordering is positional (ofarm's contract).

        The sharded 1:n×1:1 composition (lanes spread over a mesh axis)
        lives in :class:`repro.core.streaming.FarmEngine`, which also
        adds the cross-item slot reuse.
        """
        if self.state_init is not None:
            raise ValueError(
                "the -s variant is not supported on farm_run "
                "(per-lane states do not compose with a shared loop "
                "state)")
        if self.backend == "pallas-sharded":
            raise ValueError(
                "backend='pallas-sharded' lanes are driven by "
                "repro.core.streaming.FarmEngine (they need a mesh "
                "carrying both the lane and the spatial axes)")
        resolved = self._resolve_unroll(
            getattr(a0, "shape", None) and a0.shape[1:])
        if resolved is not self:
            return resolved.farm_run(a0, env=env, done0=done0)

        if self.backend != "jnp":
            if self.mode != "taps" or getattr(a0, "ndim", None) != 3:
                raise ValueError(
                    "pallas farm_run requires mode='taps' and a "
                    "(lanes, m, n) stack; got mode="
                    f"{self.mode!r}, ndim={getattr(a0, 'ndim', None)}")
            from .executor import StencilEngine

            eng = StencilEngine(
                f=self.f, k=self.k, boundary=self.boundary,
                combine=self.combine, identity=self.identity,
                delta=self.delta, measure=self.measure, block=self.block,
                unroll=self.unroll, backend=self.backend,
                interpret=self.interpret)
            frames, env_frames, lspec = eng.prepare_lanes(a0, env)
            return self._drive_lanes(
                frames,
                step=lambda fr: eng.sweeps_lanes(fr, env_frames, lspec),
                finalize=lambda fr: eng.unframe_lanes(fr, lspec),
                done0=done0)

        return self._drive_lanes(a0, step=self._lane_step_jnp(env),
                                 finalize=lambda a: a, done0=done0)

    def _lane_step_jnp(self, env):
        """Vmapped ``unroll``-deep step over a lane-stacked carry on the
        jnp backend (``env`` fields lane-stacked alongside) — the step
        both :meth:`farm_run` and the continuous streaming engine drive."""
        def one(a1, *e):
            a_prev = a1
            for _ in range(self.unroll):
                a_prev, a1 = a1, self._apply(a1, e)
            return a1, self._reduce(self._measure(a1, a_prev))
        return lambda a: jax.vmap(one)(a, *env)

    def _lane_body(self, step, lanes: int):
        """The shared done-masked lane body: one ``step`` over the stacked
        carry with per-lane freeze.  ``carry = (a, r, it, done, hw)``; a
        lane whose flag (or iteration cap) has fired keeps its slice
        frozen while the others run on.  ``hw`` is the packed per-lane
        health word the sentinel maintains on the reduce value the
        condition already computes — a POISONED or DIVERGED lane is
        masked done on the spot (quarantined) instead of spinning to the
        iteration cap or feeding further exchanges."""

        def lane_where(live, old, new):
            return jax.tree.map(
                lambda o, n: jnp.where(
                    live.reshape((lanes,) + (1,) * (o.ndim - 1)), n, o),
                old, new)

        def body(carry):
            a, r, it, done, hw = carry
            live = jnp.logical_and(~done, it < self.max_iters)
            a_new, r_new = step(a)
            if self.fault_hook is not None:
                r_new = self.fault_hook(r_new, it)
            done_new = jax.vmap(self._cond_value, in_axes=(0, None))(
                r_new, None)
            hw_new, quar = health_update(hw, r_new, r, live, done_new,
                                         it, self.sentinel)
            retire = jnp.logical_or(done_new, quar)
            return (lane_where(live, a, a_new),
                    jnp.where(live, r_new, r),
                    jnp.where(live, it + self.unroll, it),
                    jnp.where(live, jnp.logical_or(done, retire), done),
                    jnp.where(live, hw_new, hw))

        return body

    def _lane_finished(self, carry):
        """Per-lane 'this lane needs the dispatcher' mask: condition fired
        OR iteration cap hit (a capped lane will never fire its flag, so
        the continuous dispatcher must retire it like a converged one).
        Quarantined lanes arrive here already done-masked."""
        it, done = carry[2], carry[3]
        return jnp.logical_or(done, it >= self.max_iters)

    def _drive_lanes(self, a0, *, step, finalize, done0=None,
                     cond_fold=None) -> LoopResult:
        """Lane-stacked repeat/until: ``step(carry) -> (carry', r)`` with
        ``r`` of shape (lanes,); each lane owns a done flag and an
        iteration counter, and a lane whose flag (or iteration cap) has
        fired keeps its carry frozen while the others run on — the
        while_loop exits when no live lane remains.  Semantically
        identical to ``vmap``-ing :meth:`_drive` lane by lane, but shaped
        so a streaming executor can hold the stacked carry across items.

        ``cond_fold`` optionally folds the scalar any-live predicate
        across shard groups (inside ``shard_map``): the composed farm
        passes a lane-axis ``pmax`` so every shard runs the SAME trip
        count — its body carries spatial ppermutes whose rendezvous must
        stay step-aligned mesh-wide (done-masking keeps per-lane results
        unchanged; the extra sweeps are the barrier's waste).
        """
        r_aval = jax.eval_shape(lambda a: step(a)[1], a0)
        lanes = r_aval.shape[0]
        r0 = jnp.full((lanes,), self._id, dtype=r_aval.dtype)
        it0 = jnp.zeros((lanes,), jnp.int32)
        d0 = (jnp.zeros((lanes,), bool) if done0 is None
              else jnp.asarray(done0, bool).reshape((lanes,)))
        hw0 = jnp.zeros((lanes,), jnp.int32)
        body = self._lane_body(step, lanes)

        def cond_fun(carry):
            it, done = carry[2], carry[3]
            live = jnp.any(jnp.logical_and(~done, it < self.max_iters))
            return live if cond_fold is None else cond_fold(live)

        a, r, it, _, hw = jax.lax.while_loop(cond_fun, body,
                                             (a0, r0, it0, d0, hw0))
        return LoopResult(a=finalize(a), reduced=r, iters=it, state=None,
                          health=hw)

    def lane_segment(self, carry, *, step, segment: int,
                     early_exit: bool = True):
        """One bounded slice of the lane loop — the continuous-refill tier.

        Runs the same done-masked body as :meth:`_drive_lanes` but hands
        control back to the dispatcher as soon as any lane *newly*
        finishes (condition fired or iteration cap hit), after at most
        ``segment`` body steps, or immediately when no live lane remains.
        ``carry = (a, r, it, done, hw)`` round-trips unchanged in shape, so a
        streaming executor resumes the SAME carry after refilling only
        the finished lanes' slots in place — one compilation serves every
        segment of the stream.  Returns ``(carry', steps)`` with
        ``steps`` the number of body steps executed (each ``unroll``
        sweeps deep).  ``early_exit=False`` runs exactly ``segment``
        done-masked steps (see :func:`segmented_while` — the
        uniform-schedule variant for collective-carrying bodies).
        """
        lanes = carry[3].shape[0]
        return segmented_while(
            self._lane_body(step, lanes), carry,
            finished=self._lane_finished, segment=segment,
            early_exit=early_exit)

    # -- shared while_loop scaffold (all backends) -----------------------
    def _drive(self, a0, state0, *, step, state_view, finalize
               ) -> LoopResult:
        """The repeat/until driver: ``step(a) -> (a_new, reduced)`` does
        ``unroll`` stencil applications in whatever representation the
        backend carries (plain array or halo frame); ``state_view`` maps
        that representation to what -s state updates see; ``finalize``
        maps the converged carry to the result array.  Done-masking keeps
        every backend vmap/farm safe."""

        def body(carry):
            a, r, it, s, done, hw = carry
            a_new, r_new = step(a)
            it_new = it + self.unroll
            s_new = (self.state_update(s, state_view(a_new), it_new)
                     if self.state_update is not None else s)
            done_new = self._cond_value(r_new, s_new)
            hw_new, quar = health_update(hw, r_new, r, ~done, done_new,
                                         it, self.sentinel)
            # done-masking => vmap/farm safe
            keep = lambda old, new: jax.tree.map(
                lambda o, n: jnp.where(done, o, n), old, new)
            return (keep(a, a_new), jnp.where(done, r, r_new),
                    jnp.where(done, it, it_new), keep(s, s_new),
                    jnp.logical_or(done,
                                   jnp.logical_or(done_new, quar)),
                    jnp.where(done, hw, hw_new))

        def cond_fun(carry):
            _, _, it, _, done, _ = carry
            return jnp.logical_and(~done, it < self.max_iters)

        # identity element typed like the actual reduce output so the
        # while_loop carry is type-stable (e.g. bool for the 'any' monoid)
        r_shape = jax.eval_shape(lambda a: step(a)[1], a0)
        r0 = jnp.asarray(self._id, dtype=r_shape.dtype)
        carry0 = (a0, r0, jnp.asarray(0, jnp.int32), state0,
                  jnp.asarray(False), jnp.asarray(0, jnp.int32))
        a, r, it, s, _, hw = jax.lax.while_loop(cond_fun, body, carry0)
        return LoopResult(a=finalize(a), reduced=r, iters=it, state=s,
                          health=hw)

    # convenience: a jitted runner
    def jit_run(self, donate: bool = True):
        return jax.jit(self.run, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Functional front-ends (match the paper's procedure signatures).
# ---------------------------------------------------------------------------

def loop_of_stencil_reduce(k, f, combine, c, a, *, identity=None,
                           boundary="zero", max_iters=10_000, mode="taps",
                           unroll=1, backend="jnp", env=()) -> LoopResult:
    """LOOP-OF-STENCIL-REDUCE(k, f, ⊕, c, a) — base variant."""
    return LoopOfStencilReduce(
        f=f, k=k, combine=combine, identity=identity, cond=c, mode=mode,
        boundary=boundary, max_iters=max_iters, unroll=unroll,
        backend=backend).run(a, env=env)


def loop_of_stencil_reduce_d(k, f, delta, combine, c, a, *, identity=None,
                             boundary="zero", max_iters=10_000,
                             mode="taps", unroll=1, backend="jnp",
                             env=()) -> LoopResult:
    """-D variant: convergence measured on δ between successive iterates."""
    return LoopOfStencilReduce(
        f=f, k=k, combine=combine, identity=identity, cond=c, delta=delta,
        mode=mode, boundary=boundary, max_iters=max_iters,
        unroll=unroll, backend=backend).run(a, env=env)


def loop_of_stencil_reduce_s(k, f, combine, c, a, *, init, update,
                             identity=None, boundary="zero",
                             max_iters=10_000, mode="taps",
                             unroll=1, backend="jnp", env=()) -> LoopResult:
    """-S variant: a global state participates in the condition."""
    return LoopOfStencilReduce(
        f=f, k=k, combine=combine, identity=identity, cond=c,
        state_init=init, state_update=update, mode=mode, boundary=boundary,
        max_iters=max_iters, unroll=unroll, backend=backend).run(a, env=env)
