"""Production stencil application: the α(f)∘σ_k of the paper, compiled to shifts.

Two execution strategies, both extensionally equal to
:func:`repro.core.semantics.stencil` (property-tested):

* :func:`stencil_windows` — materialise the window tensor (general; memory
  cost ×(2k+1)^n).  Used for elemental functions that need the whole window
  (e.g. the adaptive median filter's sort).
* :func:`stencil_taps` — the elemental function receives a *tap accessor*
  ``get(*offsets)`` returning the array shifted by the given offsets.  XLA
  fuses the shifts; nothing is materialised.  This is the fast path used by
  Jacobi, Sobel, Game-of-Life and by the sequence-stencil layers of the LM
  stack, and it is the semantics the Pallas kernels implement in VMEM.

Both paths share the boundary model (⊥ realisation) of the semantics module.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax.numpy as jnp

from .semantics import Boundary, neighborhoods


class TapAccessor:
    """Shifted-array accessor handed to tap-style elemental functions.

    ``get(d1, ..., dn)`` returns the array whose item at position i is
    ``a'[i + (d1..dn)]`` — i.e. the neighbour at relative offset d, with ⊥
    filled according to the boundary model.  Offsets must lie in [-k, k].
    """

    def __init__(self, a: jnp.ndarray, k: int, boundary: Boundary,
                 axes: Sequence[int] | None = None):
        self._k = k
        self._axes = tuple(axes) if axes is not None else tuple(range(a.ndim))
        self._p = Boundary(boundary).pad(a, k, axes=self._axes)
        self._shape = a.shape

    def __call__(self, *offsets: int) -> jnp.ndarray:
        if len(offsets) != len(self._axes):
            raise ValueError(
                f"expected {len(self._axes)} offsets, got {len(offsets)}")
        if any(abs(o) > self._k for o in offsets):
            raise ValueError(f"offset out of stencil radius k={self._k}")
        idx = [slice(None)] * self._p.ndim
        for ax, off in zip(self._axes, offsets):
            start = self._k + off
            idx[ax] = slice(start, start + self._shape[ax])
        return self._p[tuple(idx)]

    @property
    def center(self) -> jnp.ndarray:
        return self(*([0] * len(self._axes)))


def stencil_taps(f: Callable[[TapAccessor], jnp.ndarray], a: jnp.ndarray,
                 k: int, boundary: Boundary | str = Boundary.ZERO,
                 axes: Sequence[int] | None = None) -> jnp.ndarray:
    """Apply a tap-style elemental function.  ``f(get) -> new array``."""
    return f(TapAccessor(a, k, Boundary(boundary), axes))


def stencil_windows(f: Callable[[jnp.ndarray], jnp.ndarray], a: jnp.ndarray,
                    k: int, boundary: Boundary | str = Boundary.ZERO
                    ) -> jnp.ndarray:
    """Apply a window-style elemental function (materialised σ_k)."""
    return f(neighborhoods(a, k, Boundary(boundary)))


def stencil_indexed(f: Callable, a: jnp.ndarray, k: int,
                    boundary: Boundary | str = Boundary.ZERO) -> jnp.ndarray:
    """-i variant: f receives (windows, absolute-index tensor) — σ̄_k."""
    from .semantics import indexed_neighborhoods
    w, idx = indexed_neighborhoods(a, k, Boundary(boundary))
    return f(w, idx)


def conv_taps(weights: jnp.ndarray,
              boundary: Boundary | str = Boundary.ZERO) -> Callable:
    """Build a tap-style linear-stencil elemental function from a weight
    window of shape (2k+1,)*n — the convolution special case."""
    win = weights.shape[0]
    k = (win - 1) // 2
    n = weights.ndim

    def f(get: TapAccessor):
        import itertools
        acc = None
        for offs in itertools.product(range(win), repeat=n):
            wv = weights[offs]
            term = get(*[o - k for o in offs]) * wv
            acc = term if acc is None else acc + term
        return acc

    f.k = k  # type: ignore[attr-defined]
    return f
