"""Jaxpr introspection of the pattern's loop body — test/bench tooling.

The zero-copy and communication-avoiding claims are *structural*: no
``pad``/array-sized ``concatenate``/full-block ``dynamic_slice`` inside
the ``while_loop`` body, and ppermute rounds per body that amortise over
``unroll`` fused sweeps.  This module is the single place that knows how
to dig those bodies out of a traced jaxpr (shared by
``tests/core/test_sharded.py``, ``tests/core/test_executor.py``-style
inspections, and ``benchmarks/bench_sharded.py``).
"""
from __future__ import annotations

import numpy as np

import jax


def subjaxprs(eq):
    """Nested sub-jaxprs of an equation (Jaxpr or ClosedJaxpr params)."""
    for v in eq.params.values():
        if hasattr(v, "eqns"):
            yield v
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            yield v.jaxpr


def flatten_eqns(jx, out):
    """All eqns of ``jx`` including nested sub-jaxprs (pjit/scan/...),
    but NOT Pallas kernel bodies — those are VMEM-tile-internal, not
    HBM/ICI staging passes."""
    for eq in jx.eqns:
        out.append(eq)
        if eq.primitive.name == "pallas_call":
            continue
        for sub in subjaxprs(eq):
            flatten_eqns(sub, out)
    return out


def while_body_eqns(fn, *args):
    """Equations inside the while_loop bodies of fn's jaxpr, flattened
    through nested sub-jaxprs."""
    bodies = []

    def walk(jx):
        for eq in jx.eqns:
            if eq.primitive.name == "while":
                bodies.append(eq.params["body_jaxpr"].jaxpr)
                continue
            for sub in subjaxprs(eq):
                walk(sub)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    assert bodies, "no while_loop in jaxpr"
    eqns = []
    for body in bodies:
        flatten_eqns(body, eqns)
    return eqns


def count_primitive(eqns, name: str) -> int:
    return sum(e.primitive.name == name for e in eqns)


def max_outsize(eq) -> int:
    """Largest output array size of one equation (1 for scalars)."""
    return max(int(np.prod(v.aval.shape)) if v.aval.shape else 1
               for v in eq.outvars)
