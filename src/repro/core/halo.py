"""Multi-device 1:n deployment: domain decomposition + halo exchange.

The paper's 1:n mode splits one input across n GPUs ("evenly for 1D array
and by rows for 2D matrix") and keeps the k-deep borders aligned after every
iteration with host-mediated copies — "since no device-to-device copy
mechanism is available (as of OpenCL 2.0)".

On TPU the halo swap is a *nearest-neighbour collective-permute over the ICI
torus* — a true D2D copy, so this port is strictly cheaper than the paper's
mechanism.  The convergence reduce becomes a ``psum`` over the grid axes, so
every shard computes the same condition value and the ``while_loop`` runs
*inside* ``shard_map``: one XLA program per device, no host in the loop.

Supports 1-D (by rows) and 2-D (rows × cols) decompositions; corner halos
propagate through the standard two-pass trick (exchange axis 0 first, then
exchange the already-extended axis 1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .pattern import LoopOfStencilReduce, LoopResult
from .reduce import resolve_monoid, tree_reduce
from .semantics import Boundary
from .stencil import TapAccessor


def _edge(x, axis, lo, hi):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(lo, hi)
    return x[tuple(idx)]


def _pad_axes(a: jnp.ndarray, k: int, axes: Sequence[int],
              boundary: Boundary) -> jnp.ndarray:
    """Local ⊥-padding of selected axes (non-decomposed stencil axes)."""
    if not axes:
        return a
    pw = [(k, k) if ax in axes else (0, 0) for ax in range(a.ndim)]
    if boundary is Boundary.ZERO:
        return jnp.pad(a, pw, constant_values=0)
    if boundary is Boundary.NAN:
        return jnp.pad(a, pw, constant_values=jnp.nan)
    if boundary is Boundary.REFLECT:
        return jnp.pad(a, pw, mode="reflect")
    if boundary is Boundary.WRAP:
        return jnp.pad(a, pw, mode="wrap")
    raise ValueError(boundary)


def exchange_halo(x: jnp.ndarray, k: int, axis: int, axis_name: str,
                  boundary: Boundary | str = Boundary.ZERO) -> jnp.ndarray:
    """Extend the local block with k-deep halos from mesh neighbours.

    Returns the block grown by 2k along ``axis``.  Edge shards fill the
    missing side according to the boundary model: ZERO/NaN constants,
    REFLECT mirrors locally, WRAP wraps around the mesh ring.
    """
    boundary = Boundary(boundary)
    n = lax.psum(1, axis_name)          # static mesh-axis size
    me = lax.axis_index(axis_name)

    fwd = [(i, i + 1) for i in range(n - 1)]    # data flowing "down" (+1)
    bwd = [(i + 1, i) for i in range(n - 1)]    # data flowing "up"   (-1)
    if boundary is Boundary.WRAP:
        fwd.append((n - 1, 0))
        bwd.append((0, n - 1))

    # my bottom k rows -> next shard's top halo; my top k -> prev's bottom.
    from_prev = lax.ppermute(_edge(x, axis, x.shape[axis] - k, x.shape[axis]),
                             axis_name, fwd)
    from_next = lax.ppermute(_edge(x, axis, 0, k), axis_name, bwd)

    if boundary in (Boundary.ZERO, Boundary.WRAP):
        pass  # ppermute zero-fills non-receivers; WRAP perms are complete
    elif boundary is Boundary.NAN:
        nanv = jnp.full_like(from_prev, jnp.nan)
        from_prev = jnp.where((me == 0), nanv, from_prev)
        from_next = jnp.where((me == n - 1), jnp.full_like(from_next, jnp.nan),
                              from_next)
    elif boundary is Boundary.REFLECT:
        # mirror of the local first/last k rows (excluding the edge row),
        # matching jnp.pad(mode="reflect")
        top = jnp.flip(_edge(x, axis, 1, k + 1), axis=axis)
        bot = jnp.flip(_edge(x, axis, x.shape[axis] - k - 1,
                             x.shape[axis] - 1), axis=axis)
        from_prev = jnp.where((me == 0), top, from_prev)
        from_next = jnp.where((me == n - 1), bot, from_next)
    return jnp.concatenate([from_prev, x, from_next], axis=axis)


def _apply_prepadded(f_taps: Callable, ext: jnp.ndarray, k: int,
                     axes: Sequence[int], out_shape) -> jnp.ndarray:
    """Run a tap-style elemental function on an already-halo-extended block."""
    acc = TapAccessor.__new__(TapAccessor)
    acc._k = k
    acc._axes = tuple(axes)
    acc._p = ext
    acc._shape = out_shape
    return f_taps(acc)


@dataclasses.dataclass
class GridPartition:
    """How the global array maps onto the device mesh (1:n deployment)."""
    mesh: Mesh
    axis_names: Sequence[str]        # mesh axes carrying the decomposition
    array_axes: Sequence[int]        # which array axes they split ("by rows")

    @property
    def pspec(self) -> P:
        spec = [None] * (max(self.array_axes) + 1)
        for name, ax in zip(self.axis_names, self.array_axes):
            spec[ax] = name
        return P(*spec)


def distributed_loop_of_stencil_reduce(
        f_taps: Callable, combine, cond: Callable, a: jnp.ndarray, *,
        k: int, part: GridPartition, identity=None,
        boundary: Boundary | str = Boundary.ZERO, max_iters: int = 10_000,
        delta: Optional[Callable] = None, unroll: int = 1,
        stencil_axes: Sequence[int] | None = None) -> LoopResult:
    """The pattern's 1:n mode: while_loop inside shard_map with halo swaps.

    Every iteration: (1) halo exchange along every decomposed axis
    (ppermute), (2) local ⊥-padding of the non-decomposed stencil axes,
    (3) local stencil on the extended block, (4) psum'd global reduce
    feeding the shared termination condition.
    """
    op, ident = resolve_monoid(combine, identity)
    boundary = Boundary(boundary)
    names = tuple(part.axis_names)
    axes = tuple(part.array_axes)
    st_axes = (tuple(stencil_axes) if stencil_axes is not None
               else tuple(range(a.ndim)))
    local_axes = tuple(ax for ax in st_axes if ax not in axes)

    def local_step(block):
        ext = block
        for name, ax in zip(names, axes):
            ext = exchange_halo(ext, k, ax, name, boundary)
        ext = _pad_axes(ext, k, local_axes, boundary)
        return _apply_prepadded(f_taps, ext, k, st_axes, block.shape)

    def sharded_run(block):
        def body(carry):
            blk, r, it, done = carry
            prev = blk
            new = blk
            for _ in range(unroll):
                prev, new = new, local_step(new)
            m = delta(new, prev) if delta is not None else new
            r_loc = tree_reduce(op, m, ident)
            r_new = r_loc
            for name in names:
                # monoid-aware global combine
                if op is jnp.maximum:
                    r_new = lax.pmax(r_new, name)
                elif op is jnp.minimum:
                    r_new = lax.pmin(r_new, name)
                elif op in (jnp.logical_or, jnp.logical_and):
                    rf = lax.psum(r_new.astype(jnp.float32), name)
                    r_new = (rf > 0) if op is jnp.logical_or else (
                        rf >= lax.psum(1.0, name))
                else:
                    r_new = lax.psum(r_new, name)
            it_new = it + unroll
            done_new = jnp.asarray(cond(r_new), bool).reshape(())
            blk = jnp.where(done, blk, new)
            return (blk, jnp.where(done, r, r_new),
                    jnp.where(done, it, it_new),
                    jnp.logical_or(done, done_new))

        def cond_fun(carry):
            _, _, it, done = carry
            return jnp.logical_and(~done, it < max_iters)

        r0 = jnp.asarray(ident, dtype=jax.eval_shape(
            lambda b: tree_reduce(op, delta(b, b) if delta else b, ident),
            block).dtype)
        out = lax.while_loop(cond_fun, body,
                             (block, r0, jnp.asarray(0, jnp.int32),
                              jnp.asarray(False)))
        blk, r, it, _ = out
        return blk, r, it

    pspec = part.pspec
    fn = jax.shard_map(sharded_run, mesh=part.mesh, in_specs=(pspec,),
                       out_specs=(pspec, P(), P()), check_vma=False)
    blk, r, it = fn(a)
    return LoopResult(a=blk, reduced=r, iters=it, state=None)
