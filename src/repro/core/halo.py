"""Multi-device 1:n deployment: domain decomposition + halo exchange.

The paper's 1:n mode splits one input across n GPUs ("evenly for 1D array
and by rows for 2D matrix") and keeps the k-deep borders aligned after every
iteration with host-mediated copies — "since no device-to-device copy
mechanism is available (as of OpenCL 2.0)".

On TPU the halo swap is a *nearest-neighbour collective-permute over the ICI
torus* — a true D2D copy, so this port is strictly cheaper than the paper's
mechanism.  The convergence reduce becomes a monoid collective
(:func:`repro.core.reduce.collective_combine`) over the grid axes, so every
shard computes the same condition value and the ``while_loop`` runs *inside*
``shard_map``: one XLA program per device, no host in the loop.

Two loop-body realisations, both driven by the shared repeat/until scaffold
:meth:`repro.core.pattern.LoopOfStencilReduce._drive`:

``backend="jnp"``
    the reference path: per-iteration ``exchange_halo`` grows the local
    block by 2k (ppermute + concatenate), ⊥-pads the non-decomposed
    stencil axes, and applies the tap-style f.  General (any ndim, any
    ``stencil_axes``) but stages a fresh extended block every sweep.

``backend="pallas-sharded"``
    the persistent path (:class:`repro.core.executor.
    ShardedStencilEngine`): each shard's while-carry is its halo frame,
    the exchange writes O(k·n) edge strips straight into the neighbour's
    ghost ring — no concatenate, no pad, no full-block copy in the loop
    body — and ``unroll=T`` exchanges a k·T-deep halo once per T fused
    sweeps (communication-avoiding).  2-D ``taps`` arrays only.

Supports 1-D (by rows) and 2-D (rows × cols) decompositions; corner halos
propagate through the standard two-pass trick (exchange axis 0 first, then
exchange the already-extended axis 1).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import GridPartition, shard_map
from .pattern import LoopOfStencilReduce, LoopResult
from .reduce import collective_combine, resolve_monoid, tree_reduce
from .semantics import Boundary
from .stencil import TapAccessor


def _edge(x, axis, lo, hi):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(lo, hi)
    return x[tuple(idx)]


def exchange_halo(x: jnp.ndarray, k: int, axis: int, axis_name: str,
                  boundary: Boundary | str = Boundary.ZERO) -> jnp.ndarray:
    """Extend the local block with k-deep halos from mesh neighbours.

    Returns the block grown by 2k along ``axis``.  Edge shards fill the
    missing side according to the boundary model: ZERO/NaN constants,
    REFLECT mirrors locally, WRAP wraps around the mesh ring.
    """
    boundary = Boundary(boundary)
    n = lax.psum(1, axis_name)          # static mesh-axis size
    me = lax.axis_index(axis_name)

    fwd = [(i, i + 1) for i in range(n - 1)]    # data flowing "down" (+1)
    bwd = [(i + 1, i) for i in range(n - 1)]    # data flowing "up"   (-1)
    if boundary is Boundary.WRAP:
        fwd.append((n - 1, 0))
        bwd.append((0, n - 1))

    # my bottom k rows -> next shard's top halo; my top k -> prev's bottom.
    from_prev = lax.ppermute(_edge(x, axis, x.shape[axis] - k, x.shape[axis]),
                             axis_name, fwd)
    from_next = lax.ppermute(_edge(x, axis, 0, k), axis_name, bwd)

    if boundary in (Boundary.ZERO, Boundary.WRAP):
        pass  # ppermute zero-fills non-receivers; WRAP perms are complete
    elif boundary is Boundary.NAN:
        nanv = jnp.full_like(from_prev, jnp.nan)
        from_prev = jnp.where((me == 0), nanv, from_prev)
        from_next = jnp.where((me == n - 1), jnp.full_like(from_next, jnp.nan),
                              from_next)
    elif boundary is Boundary.REFLECT:
        # mirror of the local first/last k rows (excluding the edge row),
        # matching jnp.pad(mode="reflect")
        top = jnp.flip(_edge(x, axis, 1, k + 1), axis=axis)
        bot = jnp.flip(_edge(x, axis, x.shape[axis] - k - 1,
                             x.shape[axis] - 1), axis=axis)
        from_prev = jnp.where((me == 0), top, from_prev)
        from_next = jnp.where((me == n - 1), bot, from_next)
    return jnp.concatenate([from_prev, x, from_next], axis=axis)


def _apply_prepadded(f_taps: Callable, ext: jnp.ndarray, k: int,
                     axes: Sequence[int], out_shape) -> jnp.ndarray:
    """Run a tap-style elemental function on an already-halo-extended block."""
    acc = TapAccessor.__new__(TapAccessor)
    acc._k = k
    acc._axes = tuple(axes)
    acc._p = ext
    acc._shape = out_shape
    return f_taps(acc)


def distributed_loop_of_stencil_reduce(
        f_taps: Callable, combine, cond: Callable, a: jnp.ndarray, *,
        k: int, part: GridPartition, identity=None,
        boundary: Boundary | str = Boundary.ZERO, max_iters: int = 10_000,
        delta: Optional[Callable] = None, unroll: int = 1,
        stencil_axes: Sequence[int] | None = None, env=(),
        backend: str = "jnp", block: tuple = (256, 256),
        interpret: Optional[bool] = None) -> LoopResult:
    """The pattern's 1:n mode: while_loop inside shard_map with halo swaps.

    ``backend="jnp"`` re-aligns borders per sweep by growing the block
    (general path); ``backend="pallas-sharded"`` iterates the persistent
    per-shard frames with strip-wise ppermute refresh and, with
    ``unroll=T``, one deep exchange per T fused sweeps.  Both share the
    pattern's repeat/until driver and monoid collectives.
    """
    if backend not in ("jnp", "pallas-sharded"):
        raise ValueError(
            f"unknown distributed backend {backend!r}; "
            "choose 'jnp' or 'pallas-sharded'")
    boundary = Boundary(boundary)
    pat = LoopOfStencilReduce(
        f=f_taps, k=k, combine=combine, identity=identity, cond=cond,
        delta=delta, boundary=boundary, max_iters=max_iters, unroll=unroll,
        backend=backend,
        partition=part if backend == "pallas-sharded" else None,
        block=block, interpret=interpret)
    if backend == "pallas-sharded":
        return pat.run(a, env=env)

    op, ident = resolve_monoid(combine, identity)
    names = tuple(part.axis_names)
    axes = tuple(part.array_axes)
    st_axes = (tuple(stencil_axes) if stencil_axes is not None
               else tuple(range(a.ndim)))
    local_axes = tuple(ax for ax in st_axes if ax not in axes)

    def local_step(block_arr, env_local):
        ext = block_arr
        for name, ax in zip(names, axes):
            ext = exchange_halo(ext, k, ax, name, boundary)
        ext = boundary.pad(ext, k, axes=local_axes)
        return _apply_prepadded(
            lambda g: f_taps(g, *env_local), ext, k, st_axes,
            block_arr.shape)

    def sharded_run(block_arr, *env_local):
        def step(blk):
            prev, new = blk, blk
            for _ in range(unroll):
                prev, new = new, local_step(new, env_local)
            r_loc = tree_reduce(op, pat._measure(new, prev), ident)
            return new, collective_combine(op, r_loc, names)

        res = pat._drive(block_arr, None, step=step,
                         state_view=lambda b: b, finalize=lambda b: b)
        return res.a, res.reduced, res.iters

    pspec = part.pspec
    fn = shard_map(sharded_run, mesh=part.mesh,
                   in_specs=(pspec,) * (1 + len(env)),
                   out_specs=(pspec, P(), P()))
    blk, r, it = fn(a, *env)
    return LoopResult(a=blk, reduced=r, iters=it, state=None)
