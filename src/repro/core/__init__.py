"""repro.core — the Loop-of-stencil-reduce pattern (paper's contribution).

Public API:
    semantics   — executable formal semantics (test oracle)
    stencil     — production stencil application (taps / windows / indexed)
    reduce      — /(⊕) tree reduce + two-phase reduce
    pattern     — LoopOfStencilReduce + -i/-d/-s variants (lax.while_loop)
    halo        — multi-device 1:n mode (shard_map + ppermute halo swap)
    streaming   — pipe / farm / ofarm stream tier + the lane-resident
                  FarmEngine (persistent-frame farms, device-side slot
                  refill, host-side double buffering)
"""
from .semantics import Boundary
from .stencil import TapAccessor, stencil_taps, stencil_windows, conv_taps
from .reduce import (tree_reduce, two_phase_reduce, collective_combine,
                     MONOIDS, Sentinel, health_status)
from .pattern import (LoopOfStencilReduce, LoopResult, loop_of_stencil_reduce,
                      loop_of_stencil_reduce_d, loop_of_stencil_reduce_s)
from .halo import (GridPartition, exchange_halo,
                   distributed_loop_of_stencil_reduce)
from .streaming import (pipe, farm, ofarm, sharded_farm, StreamRunner,
                        FarmEngine, StreamResult, NonFiniteItemError,
                        item_status)

__all__ = [
    "Boundary", "TapAccessor", "stencil_taps", "stencil_windows",
    "conv_taps", "tree_reduce", "two_phase_reduce", "collective_combine",
    "MONOIDS", "LoopOfStencilReduce", "LoopResult",
    "loop_of_stencil_reduce", "loop_of_stencil_reduce_d",
    "loop_of_stencil_reduce_s", "GridPartition", "exchange_halo",
    "distributed_loop_of_stencil_reduce", "pipe", "farm", "ofarm",
    "sharded_farm", "StreamRunner", "FarmEngine", "StreamResult",
    "Sentinel", "health_status", "NonFiniteItemError", "item_status",
]
