"""Executable formal semantics of the Loop-of-stencil-reduce pattern (paper §3.1).

This module is a *direct transcription* of the paper's definitions and serves as
the oracle for property tests.  It favours clarity over speed; the production
implementations live in :mod:`repro.core.stencil` / :mod:`repro.core.pattern`
and are tested for extensional equality against these functions.

Notation (paper §3.1):
    α(f) : a        apply-to-all            -> :func:`apply_to_all`
    /(⊕) : a        reduce                  -> :func:`reduce_all`
    σ_k^n : a       stencil operator        -> :func:`neighborhoods`
    σ̄_k^n : a       indexed stencil         -> :func:`indexed_neighborhoods`
    stencil(σ_k,f)  = α(f) ∘ σ_k            -> :func:`stencil`

The paper models out-of-range accesses with a bottom element ⊥; we realise ⊥
as a *boundary model* (see :class:`Boundary`): the fill value the padded array
carries outside the domain.  ``f`` and ``⊕`` must be total on that value, as
the paper requires ("both f and ⊕ should take into account the possibility
that some of the input arguments are ⊥").
"""
from __future__ import annotations

import enum
import itertools
from typing import Callable

import jax.numpy as jnp


class Boundary(str, enum.Enum):
    """How σ_k realises the paper's ⊥ outside the array domain."""

    ZERO = "zero"        # ⊥ := 0                (paper's Game-of-Life example)
    NAN = "nan"          # ⊥ := NaN              (caller's f/⊕ must absorb it)
    REFLECT = "reflect"  # ⊥ := mirrored value   (PDE Neumann-style boundary)
    WRAP = "wrap"        # ⊥ := periodic value   (torus domains)

    def pad(self, a: jnp.ndarray, k: int, axes=None) -> jnp.ndarray:
        """Extend ``a`` by ``k`` ⊥-cells per side along ``axes`` (default:
        every axis).  The single realisation of the four ⊥ models shared by
        the semantics oracle, :class:`repro.core.stencil.TapAccessor`, and
        the distributed halo path (local, non-decomposed axes)."""
        if axes is None:
            pw = k
        else:
            axes = set(axes)
            pw = [(k, k) if ax in axes else (0, 0) for ax in range(a.ndim)]
        if self is Boundary.ZERO:
            return jnp.pad(a, pw, mode="constant", constant_values=0)
        if self is Boundary.NAN:
            return jnp.pad(a, pw, mode="constant", constant_values=jnp.nan)
        if self is Boundary.REFLECT:
            return jnp.pad(a, pw, mode="reflect")
        if self is Boundary.WRAP:
            return jnp.pad(a, pw, mode="wrap")
        raise ValueError(self)


def apply_to_all(f: Callable, a: jnp.ndarray) -> jnp.ndarray:
    """α(f) : a  —  (α(f):a)_{i1..in} = f(a_{i1..in}).

    ``f`` must be an elementwise jnp-traceable function; we apply it to the
    whole array at once (the parallel interpretation the paper intends).
    """
    return f(a)


def reduce_all(op: Callable, a: jnp.ndarray, identity) -> jnp.ndarray:
    """/(⊕) : a  —  fold the binary associative ⊕ over *all* items.

    Implemented as the paper describes a parallel reduce: a balanced reduction
    tree, combining pairs level by level ("applications of the combinator to
    different pairs in the reduction tree ... done independently").
    """
    flat = a.reshape(-1)
    n = flat.shape[0]
    # pad to a power of two with the identity so the tree is balanced
    size = 1 if n == 0 else 1 << (n - 1).bit_length()
    flat = jnp.concatenate(
        [flat, jnp.full((size - n,), identity, dtype=flat.dtype)])
    while flat.shape[0] > 1:
        flat = op(flat[0::2], flat[1::2])
    return flat[0]


def neighborhoods(a: jnp.ndarray, k: int,
                  boundary: Boundary | str = Boundary.ZERO) -> jnp.ndarray:
    """σ_k^n : a  —  per-item neighbourhood tensor.

    Returns ``w`` with shape ``a.shape + (2k+1,)*n`` where
    ``w[i1..in, j1..jn] = a'[i1-k+j1, ..., in-k+jn]`` and a' extends a with
    the boundary model (⊥).
    """
    boundary = Boundary(boundary)
    n = a.ndim
    padded = boundary.pad(a, k)
    win = 2 * k + 1
    tiles = []
    for offsets in itertools.product(range(win), repeat=n):
        sl = tuple(slice(o, o + d) for o, d in zip(offsets, a.shape))
        tiles.append(padded[sl])
    w = jnp.stack(tiles, axis=-1)
    return w.reshape(a.shape + (win,) * n)


def indexed_neighborhoods(a: jnp.ndarray, k: int,
                          boundary: Boundary | str = Boundary.ZERO):
    """σ̄_k^n : a  —  neighbourhoods of ⟨value, index⟩ pairs (the -i variant).

    Returns ``(w, idx)`` where ``w`` is :func:`neighborhoods` and ``idx`` has
    shape ``a.shape + (2k+1,)*n + (n,)`` holding the *absolute* coordinates
    ``⟨i1-k+j1, ..., in-k+jn⟩`` of every window element (out-of-range indexes
    are delivered as-is, mirroring the paper's definition; f decides).
    """
    n = a.ndim
    w = neighborhoods(a, k, boundary)
    win = 2 * k + 1
    centres = jnp.meshgrid(*[jnp.arange(d) for d in a.shape], indexing="ij")
    centres = jnp.stack(centres, axis=-1)            # a.shape + (n,)
    offs = jnp.meshgrid(*[jnp.arange(-k, k + 1)] * n, indexing="ij")
    offs = jnp.stack(offs, axis=-1)                  # (win,)*n + (n,)
    idx = (centres.reshape(a.shape + (1,) * n + (n,))
           + offs.reshape((1,) * n + (win,) * n + (n,)))
    return w, idx


def stencil(f: Callable, a: jnp.ndarray, k: int,
            boundary: Boundary | str = Boundary.ZERO) -> jnp.ndarray:
    """stencil(σ_k, f) : a = α(f) ∘ σ_k : a.

    ``f`` consumes a window tensor of shape ``a.shape + (2k+1,)*n`` and must
    reduce the trailing ``n`` window axes (vectorised over the leading item
    axes) — the data-oriented elemental function of the paper.
    """
    return apply_to_all(f, neighborhoods(a, k, boundary))


# ---------------------------------------------------------------------------
# Reference (non-jit, python-loop) pattern interpreters — paper's pseudocode.
# ---------------------------------------------------------------------------

def loop_of_stencil_reduce_ref(k, f, op, c, a, *, identity,
                               boundary=Boundary.ZERO, max_iters=1000):
    """LOOP-OF-STENCIL-REDUCE(k, f, ⊕, c, a) — paper §3.1, base variant.

    repeat a = stencil(σ_k, f): a  until c(/⊕ : a)      (do-while semantics)
    """
    for it in range(1, max_iters + 1):
        a = stencil(f, a, k, boundary)
        r = reduce_all(op, a, identity)
        if bool(c(r)):
            break
    return a, r, it


def loop_of_stencil_reduce_d_ref(k, f, delta, op, c, a, *, identity,
                                 boundary=Boundary.ZERO, max_iters=1000):
    """LOOP-OF-STENCIL-REDUCE-D — reduce over δ(new, old) (paper variant 2)."""
    for it in range(1, max_iters + 1):
        b = stencil(f, a, k, boundary)
        d = delta(b, a)                      # α(δ) over ⟨f:a, a⟩ pairs
        a = b                                # α(fst)
        r = reduce_all(op, d, identity)
        if bool(c(r)):
            break
    return a, r, it


def loop_of_stencil_reduce_s_ref(k, f, op, c, a, *, identity, init, update,
                                 boundary=Boundary.ZERO, max_iters=1000):
    """LOOP-OF-STENCIL-REDUCE-S — global loop state in the condition."""
    s = init()
    for it in range(1, max_iters + 1):
        a = stencil(f, a, k, boundary)
        s = update(s)
        r = reduce_all(op, a, identity)
        if bool(c(r, s)):
            break
    return a, r, it, s
