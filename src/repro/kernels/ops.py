"""Public jit'd wrappers around the execution engine (paper §4 apps).

Every app here instantiates the Loop-of-stencil-reduce through the
persistent-halo engine's **backend axis** (:mod:`repro.core.executor`):

* ``backend="jnp"``       — the shift-algebra reference path;
* ``backend="pallas"``    — the fused single-step kernel iterated on a
  persistent halo frame (pad/round-up hoisted out of the loop);
* ``backend="pallas-multistep"`` — temporal blocking, ``unroll`` sweeps
  fused per HBM round-trip.

``use_pallas`` is kept as a boolean shorthand (False → "jnp",
True → "pallas"); an explicit ``backend=`` wins.  All paths implement the
same Loop-of-stencil-reduce contract, so the whole framework runs
end-to-end on any of them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.executor import sweep_once
from repro.core.pattern import LoopOfStencilReduce

from . import ref as R


def _resolve_backend(use_pallas: bool, backend: Optional[str]) -> str:
    return backend if backend else ("pallas" if use_pallas else "jnp")


def _resolve_sharded_backend(use_pallas: bool, backend: Optional[str],
                             part) -> str:
    """Backend resolution for the iterative apps: a mesh partition means
    the 1:n deployment — refuse a conflicting single-device backend
    rather than silently ignoring ``part``."""
    if part is None:
        return _resolve_backend(use_pallas, backend)
    if backend not in (None, "pallas-sharded"):
        raise ValueError(
            f"part= selects the sharded 1:n deployment; backend="
            f"{backend!r} conflicts (pass backend='pallas-sharded' or "
            "drop it)")
    return "pallas-sharded"


def fused_sweep(a, f, *, env=(), k=1, combine="sum", identity=None,
                measure=None, boundary="zero", block=(256, 256),
                use_pallas=True, backend=None, unroll=1, interpret=None,
                double_buffer=True):
    """One fused stencil+reduce sweep: returns (new, reduced)."""
    return sweep_once(
        a, f, env=env, k=k, combine=combine, identity=identity,
        measure=measure, boundary=boundary, block=block,
        backend=_resolve_backend(use_pallas, backend), unroll=unroll,
        interpret=interpret, double_buffer=double_buffer)


@functools.partial(jax.jit, static_argnames=("alpha", "dx", "max_iters",
                                             "use_pallas", "backend",
                                             "unroll", "part"))
def jacobi_solve(u0, fxy, *, alpha=0.5, dx=1.0 / 512, tol=1e-4,
                 max_iters=1000, use_pallas=False, backend=None, unroll=1,
                 part=None):
    """Full Helmholtz Jacobi solve as ONE on-device while_loop (persistent
    device memory, fused sweep+delta-reduce — the paper's optimised path).

    On the Pallas backends the grid is carried as a persistent halo frame:
    no per-iteration pad/slice; ``unroll`` with "pallas-multistep" fuses
    that many sweeps per HBM round-trip (convergence checked every
    ``unroll`` iterations, as the pattern's unroll semantics).  Passing a
    ``part`` (:class:`repro.sharding.specs.GridPartition`, hashable →
    jit-static) selects the 1:n deployment: per-shard frames inside
    shard_map, ppermute ghost exchange, unroll=T deep halos
    (``backend`` then defaults to "pallas-sharded").
    """
    be = _resolve_sharded_backend(use_pallas, backend, part)
    loop = LoopOfStencilReduce(
        f=R.helmholtz_jacobi_taps(alpha, dx), k=1, combine="max",
        cond=lambda r: r < tol, delta=R.abs_delta, boundary="zero",
        max_iters=max_iters, unroll=unroll, backend=be, partition=part)
    res = loop.run(u0, env=(fxy,))
    return res.a, res.reduced, res.iters


@functools.partial(jax.jit, static_argnames=("use_pallas", "backend"))
def sobel(img, *, use_pallas=False, backend=None):
    """Single-iteration stencil (the paper's worst case for accelerators):
    Sobel magnitude + fused max-response reduce (stream statistics)."""
    new, r = sweep_once(img, R.sobel_taps(), k=1, combine="max",
                        identity=-jnp.inf, boundary="reflect",
                        backend=_resolve_backend(use_pallas, backend))
    return new, r


@functools.partial(jax.jit, static_argnames=("max_iters", "use_pallas",
                                             "backend", "unroll", "part"))
def restore(frame, noisy_mask, *, beta=2.0, tol=1e-3, max_iters=64,
            use_pallas=False, backend=None, unroll=1, part=None):
    """Restoration phase (§4.3): iterate the regularisation sweep until the
    mean absolute update over noisy pixels converges.  ``part`` selects
    the sharded 1:n deployment, as in :func:`jacobi_solve`."""
    be = _resolve_sharded_backend(use_pallas, backend, part)
    npx = jnp.maximum(noisy_mask.sum(), 1.0)
    loop = LoopOfStencilReduce(
        f=R.restore_taps(beta), k=1, combine="sum",
        cond=lambda r: r / npx < tol, delta=R.abs_delta,
        boundary="reflect", max_iters=max_iters, unroll=unroll,
        backend=be, partition=part)
    res = loop.run(frame, env=(frame, noisy_mask))
    return res.a, res.reduced / npx, res.iters


@functools.partial(jax.jit, static_argnames=("use_pallas", "kmax",
                                             "backend"))
def adaptive_median_detect(frame, *, kmax=3, use_pallas=False, backend=None):
    """Detection phase (§4.3): classic adaptive median filter with window
    escalation 3×3→5×5→7×7.  Returns (noise_mask, repaired_frame) where the
    repaired frame replaces flagged pixels by the AMF median — the
    restoration phase's initial guess."""
    be = _resolve_backend(use_pallas, backend)
    f_mask, f_repl = R.amf_detect_taps(kmax)
    mask, frac = sweep_once(frame, f_mask, k=kmax, combine="sum",
                            identity=0.0, boundary="reflect", backend=be)
    repl, _ = sweep_once(frame, f_repl, k=kmax, combine="sum",
                         identity=0.0, boundary="reflect", backend=be)
    repaired = jnp.where(mask > 0, repl, frame)
    return mask, repaired
