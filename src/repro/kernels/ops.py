"""Public jit'd wrappers around the Pallas kernels.

``use_pallas`` toggles between the Pallas kernel (interpret=True on CPU,
compiled on TPU) and the pure-jnp reference path — both implement the same
Loop-of-stencil-reduce contract, so the whole framework runs end-to-end on
either backend.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import ref as R
from .stencil2d import stencil2d_fused

_ON_TPU = jax.default_backend() == "tpu"


def fused_sweep(a, f, *, env=(), k=1, combine="sum", identity=None,
                measure=None, boundary="zero", block=(256, 256),
                use_pallas=True, interpret=None, double_buffer=True):
    """One fused stencil+reduce sweep: returns (new, reduced)."""
    if use_pallas:
        interp = (not _ON_TPU) if interpret is None else interpret
        return stencil2d_fused(
            a, f, env=env, k=k, combine=combine, identity=identity,
            measure=measure, boundary=boundary, block=block,
            double_buffer=double_buffer, interpret=interp)
    return R.stencil2d_fused_ref(a, f, env=env, k=k, combine=combine,
                                 identity=identity, measure=measure,
                                 boundary=boundary)


@functools.partial(jax.jit, static_argnames=("alpha", "dx", "max_iters",
                                              "use_pallas"))
def jacobi_solve(u0, fxy, *, alpha=0.5, dx=1.0 / 512, tol=1e-4,
                 max_iters=1000, use_pallas=False):
    """Full Helmholtz Jacobi solve as ONE on-device while_loop (persistent
    device memory, fused sweep+delta-reduce — the paper's optimised path)."""
    f = R.helmholtz_jacobi_taps(alpha, dx)

    def body(carry):
        u, delta, it = carry
        new, d = fused_sweep(u, f, env=(fxy,), k=1, combine="max",
                             identity=-jnp.inf, measure=R.abs_delta,
                             boundary="zero", use_pallas=use_pallas)
        return new, d, it + 1

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta >= tol, it < max_iters)

    u, delta, iters = jax.lax.while_loop(
        cond, body, (u0, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    return u, delta, iters


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def sobel(img, *, use_pallas=False):
    """Single-iteration stencil (the paper's worst case for accelerators):
    Sobel magnitude + fused max-response reduce (stream statistics)."""
    new, r = fused_sweep(img, R.sobel_taps(), k=1, combine="max",
                         identity=-jnp.inf, boundary="reflect",
                         use_pallas=use_pallas)
    return new, r


@functools.partial(jax.jit, static_argnames=("max_iters", "use_pallas"))
def restore(frame, noisy_mask, *, beta=2.0, tol=1e-3, max_iters=64,
            use_pallas=False):
    """Restoration phase (§4.3): iterate the regularisation sweep until the
    mean absolute update over noisy pixels converges."""
    f = R.restore_taps(beta)
    npx = jnp.maximum(noisy_mask.sum(), 1.0)

    def body(carry):
        u, delta, it = carry
        new, s = fused_sweep(u, f, env=(frame, noisy_mask), k=1,
                             combine="sum", identity=0.0,
                             measure=R.abs_delta, boundary="reflect",
                             use_pallas=use_pallas)
        return new, s / npx, it + 1

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta >= tol, it < max_iters)

    u, delta, iters = jax.lax.while_loop(
        cond, body, (frame, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    return u, delta, iters


@functools.partial(jax.jit, static_argnames=("use_pallas", "kmax"))
def adaptive_median_detect(frame, *, kmax=3, use_pallas=False):
    """Detection phase (§4.3): classic adaptive median filter with window
    escalation 3×3→5×5→7×7.  Returns (noise_mask, repaired_frame) where the
    repaired frame replaces flagged pixels by the AMF median — the
    restoration phase's initial guess."""
    f_mask, f_repl = R.amf_detect_taps(kmax)
    mask, frac = fused_sweep(frame, f_mask, k=kmax, combine="sum",
                             identity=0.0, boundary="reflect",
                             use_pallas=use_pallas)
    repl, _ = fused_sweep(frame, f_repl, k=kmax, combine="sum",
                          identity=0.0, boundary="reflect",
                          use_pallas=use_pallas)
    repaired = jnp.where(mask > 0, repl, frame)
    return mask, repaired
