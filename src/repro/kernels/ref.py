"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are built on :mod:`repro.core.stencil` — which is itself
property-tested against the executable formal semantics — so the kernel
tests close the loop: Pallas kernel ≡ core stencil ≡ paper semantics.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.reduce import resolve_monoid, tree_reduce
from repro.core.stencil import TapAccessor, stencil_taps
from repro.core.semantics import Boundary


def stencil2d_fused_ref(a, f, *, env=(), k=1, combine="sum", identity=None,
                        measure: Optional[Callable] = None,
                        boundary="zero", acc_dtype=jnp.float32):
    """Oracle for :func:`repro.kernels.stencil2d.stencil2d_fused`."""
    op, ident = resolve_monoid(combine, identity)
    new = stencil_taps(lambda get: f(get, *env), a, k, boundary)
    meas = measure(new, a) if measure is not None else new
    red = tree_reduce(op, meas.astype(acc_dtype), ident)
    return new, red


# ---------------------------------------------------------------------------
# Application elemental functions (shared by kernels, refs, and the apps).
# Taps-style (paper's data-oriented elemental-function protocol).
# ---------------------------------------------------------------------------

def jacobi_taps(rhs_scale: float = 0.25):
    """Jacobi sweep for the Helmholtz/Laplace problem: 4-point average."""
    def f(get):
        return rhs_scale * (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1))
    return f


def helmholtz_jacobi_taps(alpha: float, dx: float):
    """Jacobi iteration for (∇² - α)u = -f on a uniform grid.

    u' = (dx²·f + Σ_4-neighbours u) / (4 + α·dx²)
    The forcing field enters through the kernel's ``env`` — the paper's
    read-only input matrix combined with the partial-solution's 3×3
    neighbourhood (§4.1, and Fig. 2's ``(input, env)`` schema).
    """
    denom = 4.0 + alpha * dx * dx

    def f(get, fxy):
        s = get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
        return (dx * dx * fxy + s) / denom
    return f


def sobel_taps():
    """Sobel edge detector: gradient magnitude of the 3×3 neighbourhood."""
    def f(get, *_):
        gx = (get(-1, 1) + 2 * get(0, 1) + get(1, 1)
              - get(-1, -1) - 2 * get(0, -1) - get(1, -1))
        gy = (get(1, -1) + 2 * get(1, 0) + get(1, 1)
              - get(-1, -1) - 2 * get(-1, 0) - get(-1, 1))
        return jnp.sqrt(gx * gx + gy * gy)
    return f


def gol_taps():
    """Conway's Game of Life (the paper's running example, Fig. 1)."""
    def f(get, *_):
        n = sum(get(di, dj) for di in (-1, 0, 1) for dj in (-1, 0, 1)
                if (di, dj) != (0, 0))
        return jnp.where((n == 3) | ((get(0, 0) > 0) & (n == 2)), 1.0, 0.0)
    return f


def median3_taps():
    """3×3 median (detection phase of the video-restoration app, §4.3)."""
    def f(get, *_):
        w = jnp.stack([get(di, dj) for di in (-1, 0, 1) for dj in (-1, 0, 1)])
        return jnp.sort(w, axis=0)[4]
    return f


def amf_detect_taps(kmax: int = 3):
    """Adaptive median filter detection (§4.3 phase 1, after [5]).

    The classic AMF escalates the window 3×3 → 5×5 → 7×7 ("dynamic stencil
    with reasonable static bounds", paper §3.2): at each level, if the
    window median is strictly between the window min/max the decision is
    made there — the pixel is noise iff it equals a window extreme;
    otherwise the window grows.  Pixels undecided at kmax are flagged.

    Returns a taps function emitting ``select`` of the decision:
    ``what='mask'`` → 1.0 where noise, ``what='repl'`` → median replacement.
    (Two planes, two sweeps; the detection runs once per frame.)
    """
    def core(get):
        x = get(0, 0)
        decided = jnp.zeros_like(x, dtype=bool)
        noise = jnp.zeros_like(x, dtype=bool)
        repl = x
        for k in range(1, kmax + 1):
            w = jnp.stack([get(di, dj)
                           for di in range(-k, k + 1)
                           for dj in range(-k, k + 1)])
            srt = jnp.sort(w, axis=0)
            mn, med, mx = srt[0], srt[w.shape[0] // 2], srt[-1]
            level_a = (med > mn) & (med < mx)
            is_noise_here = ~((x > mn) & (x < mx))
            newly = level_a & ~decided
            noise = jnp.where(newly, is_noise_here, noise)
            repl = jnp.where(newly & is_noise_here, med, repl)
            decided = decided | level_a
        noise = jnp.where(decided, noise, True)
        repl = jnp.where(~decided, med, repl)  # last-level median fallback
        return noise.astype(x.dtype), repl

    def f_mask(get, *_):
        return core(get)[0]

    def f_repl(get, *_):
        return core(get)[1]
    return f_mask, f_repl


def restore_taps(beta: float = 2.0):
    """Regularisation sweep of the two-phase restoration (§4.3).

    Pixels flagged noisy (mask=1) move toward a weighted combination of the
    4-neighbourhood median and mean (edge-preserving smoothing functional
    minimisation, as in [5]); clean pixels are pinned to the observation.
    ``env = (noisy_observation, noise_mask)``.
    """
    def f(get, noisy, mask):
        nb = jnp.stack([get(-1, 0), get(1, 0), get(0, -1), get(0, 1)])
        med = jnp.sort(nb, axis=0)
        med4 = 0.5 * (med[1] + med[2])
        mean4 = jnp.mean(nb, axis=0)
        prop = (beta * med4 + mean4) / (beta + 1.0)
        return jnp.where(mask > 0, prop, noisy)
    return f


def heat_taps(nu: float = 0.1):
    """Explicit heat equation step (generic iterative stencil for tests)."""
    def f(get, *_):
        lap = (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
               - 4.0 * get(0, 0))
        return get(0, 0) + nu * lap
    return f


def abs_delta(new, old):
    """The -d variant's δ for convergence-on-change monitoring."""
    return jnp.abs(new - old)
