"""Temporal-blocking stencil kernel: T iterations per VMEM residency.

Beyond-paper kernel optimisation for the memory-bound iterative stencil:
the single-step kernel moves the whole grid HBM↔VMEM once per iteration
(arithmetic intensity of a 5-point f32 Jacobi ≈ 4 FLOPs / 8 bytes → far
below the v5e ridge point of ~240 FLOPs/byte).  Temporal blocking loads
a (bm + 2kT, bn + 2kT) halo window once and applies T sweeps in VMEM,
shrinking the valid region by k per side per sweep:

    HBM traffic/iter ≈ ((bm+2kT)(bn+2kT)/T + bm·bn/T) · bytes   (≈ ÷T)
    redundant compute ≈ ((bm+2kT)(bn+2kT)/(bm·bn) − 1)          (~13%
    at bm=bn=256, k=1, T=8)

Boundary (⊥) correctness: at global edges the ghost values must match the
boundary model of the *current* internal iterate after EVERY sweep (a
pre-padded initial window alone would let ghost values evolve freely).
Per model:

* ``zero`` / ``nan`` — re-assert the constant on out-of-domain cells
  (cheap ``where`` over the shrinking window);
* ``reflect`` — mirror the just-computed interior back onto the ghost
  cells.  The mirror source always lies inside the current window (depth-d
  ghost mirrors depth-d interior), realised as flip+roll with a
  program-id-dependent shift — no gather needed;
* ``wrap`` — nothing per-sweep: a wrapped ghost ring is a patch of the
  torus, so ghost cells evolve *exactly* like their pre-images and the
  shrinking-window containment argument applies unchanged.  (Requires the
  frame's ghost ring and the env frames to be wrap-filled, which
  :func:`repro.core.frames.refresh_frame` / ``frame_env`` provide.)

``env`` tiles (the paper Fig. 2 read-only fields) are DMA'd as halo
windows alongside the state — intermediate sweeps evaluate f on a region
wider than the output tile, so env must cover the shrinking window at
every step.  Input DMA is double-buffered (revolving windows) like the
single-step kernel; the convergence reduce is fused and evaluated on the
final sweep only — semantically the pattern's ``unroll`` option (checks
every T iterations).

Validated against T× :func:`repro.core.stencil.stencil_taps` in
tests/kernels/test_multistep.py.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.frames import frame_spec, make_frame, frame_env, unframe
from repro.core.reduce import resolve_monoid
from .stencil2d import decode_acc, reduce_epilogue, revolving_fetch


def _fix_boundary(cur, row_base, col_base, *, bounds, boundary):
    """Re-assert ⊥ on out-of-domain cells of an internal sweep output.

    ``cur`` holds the sweep output whose [0, 0] cell sits at frame
    coordinates (row_base, col_base) — traced, tile-dependent.  The
    GLOBAL domain occupies frame rows [row_lo, row_hi) × cols
    [col_lo, col_hi), given by ``bounds`` — static ints on the
    single-device path, traced scalars (read from SMEM) on the sharded
    path, where interior shards carry ±2^30 sentinels so no cell is ever
    "outside" (their ghost cells are real neighbour cells and must evolve
    freely).
    """
    if boundary == "wrap":
        return cur                      # torus continuation is exact
    row_lo, row_hi, col_lo, col_hi = bounds
    L, W = cur.shape
    rows = row_base + jax.lax.broadcasted_iota(jnp.int32, (L, W), 0)
    cols = col_base + jax.lax.broadcasted_iota(jnp.int32, (L, W), 1)
    if boundary in ("zero", "nan"):
        inside = ((rows >= row_lo) & (rows < row_hi)
                  & (cols >= col_lo) & (cols < col_hi))
        fill = jnp.asarray(0.0 if boundary == "zero" else jnp.nan, cur.dtype)
        return jnp.where(inside, cur, fill)
    if boundary != "reflect":
        raise ValueError(boundary)
    # reflect: ghost row g < row_lo mirrors row 2·row_lo - g; g >= row_hi
    # mirrors 2(row_hi-1) - g (jnp.pad 'reflect', no edge repeat).
    # flip+roll turns the traced mirror map into a cyclic shift:
    # flip(cur)[l'] = cur[L-1-l'], so roll(flip(cur), s)[l] = cur[L-1+s-l]
    # — choosing s makes L-1+s-l the mirror image of row_base+l.
    # Out-of-range (or sentinel-bound) rolls only land on rows the masks
    # below never select.
    fr = jnp.flip(cur, axis=0)
    top = jnp.roll(fr, 2 * (row_lo - row_base) - L + 1, axis=0)
    bot = jnp.roll(fr, 2 * (row_hi - 1 - row_base) - L + 1, axis=0)
    cur = jnp.where(rows < row_lo, top,
                    jnp.where(rows >= row_hi, bot, cur))
    fc = jnp.flip(cur, axis=1)
    left = jnp.roll(fc, 2 * (col_lo - col_base) - W + 1, axis=1)
    right = jnp.roll(fc, 2 * (col_hi - 1 - col_base) - W + 1, axis=1)
    return jnp.where(cols < col_lo, left,
                     jnp.where(cols >= col_hi, right, cur))


def _ms_kernel(x_hbm, *rest, f, measure, op, identity, k, T, bm, bn,
               gm, gn, m, n, acc_dtype, boundary, n_env, double_buffer,
               has_bounds):
    env_hbm = rest[:n_env]
    pos = n_env
    if has_bounds:
        bounds_ref = rest[pos]
        pos += 1
    o_hbm, acc_ref, win, wsem = rest[pos:pos + 4]
    tail = rest[pos + 4:]
    ewins = tail[:n_env]
    esem = tail[n_env] if n_env else None
    ostage, osem = tail[-2:]
    pad_static = k * T
    if has_bounds:
        bounds = (bounds_ref[0, 0], bounds_ref[0, 1],
                  bounds_ref[0, 2], bounds_ref[0, 3])
    else:
        bounds = (pad_static, pad_static + m, pad_static, pad_static + n)

    i, j = pl.program_id(0), pl.program_id(1)
    t = i * gn + j
    pad = k * T
    wm, wn = bm + 2 * pad, bn + 2 * pad

    def window_copies(ti, tj, slot):
        cps = [pltpu.make_async_copy(
            x_hbm.at[pl.ds(ti * bm, wm), pl.ds(tj * bn, wn)],
            win.at[slot], wsem.at[slot])]
        for e in range(n_env):
            cps.append(pltpu.make_async_copy(
                env_hbm[e].at[pl.ds(ti * bm, wm), pl.ds(tj * bn, wn)],
                ewins[e].at[slot], esem.at[slot, e]))
        return cps

    slot = revolving_fetch(t, i, j, gm, gn, window_copies, double_buffer)
    cur = win[slot]
    prev_center = None
    for step in range(T):
        size_m = wm - 2 * k * (step + 1)
        size_n = wn - 2 * k * (step + 1)
        if step == T - 1:
            prev_center = cur[k:k + size_m, k:k + size_n]
        taps = _ShrinkTaps(cur, k, size_m, size_n)
        off = k * (step + 1)            # window-local origin of this sweep
        envs = [ewins[e][slot][off:off + size_m, off:off + size_n]
                for e in range(n_env)]
        new = f(taps, *envs)
        cur = _fix_boundary(
            new, i * bm + off, j * bn + off, bounds=bounds,
            boundary=boundary).astype(cur.dtype)

    ostage[...] = cur.astype(ostage.dtype)    # (bm, bn) after T shrinks
    wr = pltpu.make_async_copy(
        ostage, o_hbm.at[pl.ds(pad + i * bm, bm), pl.ds(pad + j * bn, bn)],
        osem)
    wr.start()
    wr.wait()

    reduce_epilogue(acc_ref, t, cur, prev_center, measure=measure, op=op,
                    identity=identity, i=i, j=j, bm=bm, bn=bn, m=m, n=n,
                    acc_dtype=acc_dtype)


class _ShrinkTaps:
    """Taps over the current (size+2k) window, producing (size) output."""

    def __init__(self, arr, k, size_m, size_n):
        self._a, self._k, self._m, self._n = arr, k, size_m, size_n

    def __call__(self, di, dj):
        k = self._k
        return self._a[k + di:k + di + self._m, k + dj:k + dj + self._n]

    @property
    def center(self):
        return self(0, 0)


def stencil2d_multistep_framed(frame: jnp.ndarray, f: Callable, spec, *,
                               T: int, env_framed=(), combine="sum",
                               identity=None,
                               measure: Optional[Callable] = None,
                               boundary: str = "zero",
                               domain_bounds=None,
                               acc_dtype=jnp.float32,
                               double_buffer: bool = True,
                               interpret: bool = False):
    """T fused sweeps on a persistent halo frame — frame in, frame out.

    ``spec`` must have ``pad == k*T``; ``env_framed`` are full-frame fields
    (``frame_env(..., halo=True)``).  Returns ``(new_frame, reduced)``
    with the reduce taken over ``measure(last, second-last)`` on the final
    sweep only.  Like the single-step framed kernel, the output ghost ring
    is left for the caller's ``refresh_frame``.

    ``domain_bounds`` (optional, (1, 4) int32, possibly traced) overrides
    where the per-sweep ⊥ re-assertion sees the GLOBAL domain edge in
    frame coordinates — the sharded deployment passes per-shard bounds
    (sentinels on interior sides) through SMEM; None keeps the
    single-device static bounds.
    """
    op, ident = resolve_monoid(combine, identity)
    k, bm, bn, gm, gn = spec.k, spec.bm, spec.bn, spec.gm, spec.gn
    assert spec.pad == k * T, (spec.pad, k, T)
    nbuf = 2 if double_buffer else 1
    wm, wn = bm + 2 * spec.pad, bn + 2 * spec.pad
    n_env = len(env_framed)
    has_bounds = domain_bounds is not None

    kernel = functools.partial(
        _ms_kernel, f=f, measure=measure, op=op, identity=ident, k=k,
        T=T, bm=bm, bn=bn, gm=gm, gn=gn, m=spec.m, n=spec.n,
        acc_dtype=acc_dtype, boundary=boundary, n_env=n_env,
        double_buffer=double_buffer, has_bounds=has_bounds)

    scratch = [pltpu.VMEM((nbuf, wm, wn), frame.dtype),
               pltpu.SemaphoreType.DMA((nbuf,))]
    scratch += [pltpu.VMEM((nbuf, wm, wn), e.dtype) for e in env_framed]
    if n_env:
        scratch.append(pltpu.SemaphoreType.DMA((nbuf, n_env)))
    scratch += [pltpu.VMEM((bm, bn), frame.dtype), pltpu.SemaphoreType.DMA]

    in_specs = ([pl.BlockSpec(memory_space=pl.ANY)]
                + [pl.BlockSpec(memory_space=pl.ANY) for _ in env_framed])
    operands = [frame, *env_framed]
    if has_bounds:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(jnp.asarray(domain_bounds, jnp.int32))

    out, acc = pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec(memory_space=pl.ANY),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(frame.shape, frame.dtype),
                   jax.ShapeDtypeStruct((1, 1), acc_dtype)],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    return out, decode_acc(op, acc[0, 0])


def stencil2d_multistep(a, f, *, env=(), k: int = 1, T: int = 4,
                        combine="sum", identity=None, measure=None,
                        boundary: str = "zero", block=(256, 256),
                        acc_dtype=jnp.float32, double_buffer: bool = True,
                        interpret: bool = False):
    """T fused sweeps per VMEM residency, all four ⊥ models, env tiles.

    Returns (array after T sweeps, /(⊕) of measure(last, second-last)).
    One-shot convenience around :func:`stencil2d_multistep_framed`;
    iterative callers should hold the frame across kernel calls instead —
    see :mod:`repro.core.executor`.
    """
    m, n = a.shape
    spec = frame_spec(m, n, k=k, block=block, sweeps=T)
    frame = make_frame(a, spec, boundary)
    env_framed = tuple(frame_env(e, spec, boundary, halo=True) for e in env)
    out, red = stencil2d_multistep_framed(
        frame, f, spec, T=T, env_framed=env_framed, combine=combine,
        identity=identity, measure=measure, boundary=boundary,
        acc_dtype=acc_dtype, double_buffer=double_buffer,
        interpret=interpret)
    return unframe(out, spec), red
