"""Temporal-blocking stencil kernel: T iterations per VMEM residency.

Beyond-paper kernel optimisation for the memory-bound iterative stencil:
the single-step kernel moves the whole grid HBM↔VMEM once per iteration
(arithmetic intensity of a 5-point f32 Jacobi ≈ 4 FLOPs / 8 bytes → far
below the v5e ridge point of ~240 FLOPs/byte).  Temporal blocking loads
a (bm + 2kT, bn + 2kT) halo window once and applies T sweeps in VMEM,
shrinking the valid region by k per side per sweep:

    HBM traffic/iter ≈ ((bm+2kT)(bn+2kT)/T + bm·bn/T) · bytes   (≈ ÷T)
    redundant compute ≈ ((bm+2kT)(bn+2kT)/(bm·bn) − 1)          (~13%
    at bm=bn=256, k=1, T=8)

Boundary (⊥) correctness: at global edges the ghost ring must be reset
to the boundary value after EVERY internal sweep (zero boundary
supported; a pre-padded initial window alone would let ghost values
evolve).  The convergence reduce is evaluated on the final sweep only —
semantically the pattern's ``unroll`` option (checks every T iterations).

Validated against T× :func:`repro.core.stencil.stencil_taps` in
tests/kernels/test_multistep.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.reduce import resolve_monoid
from .stencil2d import KernelTaps, _tile_fold


def _ms_kernel(x_hbm, o_ref, acc_ref, win, sem, *, f, measure, op,
               identity, k, T, bm, bn, gm, gn, m, n, acc_dtype):
    i, j = pl.program_id(0), pl.program_id(1)
    t = i * gn + j
    pad = k * T
    wm, wn = bm + 2 * pad, bn + 2 * pad

    cp = pltpu.make_async_copy(
        x_hbm.at[pl.ds(i * bm, wm), pl.ds(j * bn, wn)], win, sem)
    cp.start()
    cp.wait()

    # absolute coordinates of the window's top-left cell in the padded
    # frame; domain cells live at [pad, pad+m) × [pad, pad+n) there
    row0 = i * bm
    col0 = j * bn

    cur = win[...]
    prev_center = None
    for step in range(T):
        size_m = wm - 2 * k * (step + 1)
        size_n = wn - 2 * k * (step + 1)
        if step == T - 1:
            prev_center = cur[k:k + size_m, k:k + size_n]
        taps = _ShrinkTaps(cur, k, size_m, size_n)
        new = f(taps)
        # re-assert the ⊥=0 boundary on ghost cells outside the domain
        roff = row0 + k * (step + 1)
        coff = col0 + k * (step + 1)
        rows = roff + jax.lax.broadcasted_iota(jnp.int32,
                                               (size_m, size_n), 0)
        cols = coff + jax.lax.broadcasted_iota(jnp.int32,
                                               (size_m, size_n), 1)
        inside = ((rows >= pad) & (rows < pad + m)
                  & (cols >= pad) & (cols < pad + n))
        cur = jnp.where(inside, new, 0.0).astype(cur.dtype)

    out = cur                                       # (bm, bn)
    o_ref[...] = out.astype(o_ref.dtype)

    meas = (measure(out, prev_center) if measure is not None else out)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    valid = (rows < m) & (cols < n)
    meas = jnp.where(valid, meas.astype(acc_dtype),
                     jnp.asarray(identity, acc_dtype))
    part = _tile_fold(op, meas, identity, acc_dtype)

    @pl.when(t == 0)
    def _():
        acc_ref[0, 0] = jnp.asarray(identity, acc_dtype)
    acc_ref[0, 0] = op(acc_ref[0, 0], part)


class _ShrinkTaps:
    """Taps over the current (size+2k) window, producing (size) output."""

    def __init__(self, arr, k, size_m, size_n):
        self._a, self._k, self._m, self._n = arr, k, size_m, size_n

    def __call__(self, di, dj):
        k = self._k
        return self._a[k + di:k + di + self._m, k + dj:k + dj + self._n]

    @property
    def center(self):
        return self(0, 0)


def stencil2d_multistep(a, f, *, k: int = 1, T: int = 4, combine="sum",
                        identity=None, measure=None,
                        block=(256, 256), acc_dtype=jnp.float32,
                        interpret: bool = False):
    """T fused sweeps per VMEM residency (zero boundary).

    Returns (array after T sweeps, /(⊕) of measure(last, second-last)).
    """
    op, ident = resolve_monoid(combine, identity)
    m, n = a.shape
    bm, bn = block
    bm, bn = min(bm, _ceil_mul(m, 8)), min(bn, _ceil_mul(n, 128))
    gm, gn = -(-m // bm), -(-n // bn)
    pad = k * T
    xp = jnp.pad(a, ((pad, pad + gm * bm - m), (pad, pad + gn * bn - n)))

    kernel = functools.partial(
        _ms_kernel, f=f, measure=measure, op=op, identity=ident, k=k,
        T=T, bm=bm, bn=bn, gm=gm, gn=gn, m=m, n=n, acc_dtype=acc_dtype)
    out, acc = pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((gm * bm, gn * bn), a.dtype),
                   jax.ShapeDtypeStruct((1, 1), acc_dtype)],
        scratch_shapes=[pltpu.VMEM((bm + 2 * pad, bn + 2 * pad), a.dtype),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(xp)
    return out[:m, :n], acc[0, 0]


def _ceil_mul(x: int, q: int) -> int:
    return -(-x // q) * q
