"""Fused stencil + partial-reduce Pallas TPU kernel (the paper's §3.3 core).

The paper fuses the stencil elemental function with the first (device-side)
phase of the reduce into one kernel — ``stencil<SUM_kernel, MF_kernel>`` —
so the convergence measure costs no extra memory pass.  TPU-native
re-thinking of that design:

* the global grid lives in HBM as a *persistent halo frame*
  (:mod:`repro.core.frames`): a (gm·bm + 2k, gn·bn + 2k) array whose ghost
  ring realises ⊥.  Each grid step DMAs its halo-extended (bm+2k, bn+2k)
  window into VMEM with an explicit async copy (``pltpu.make_async_copy``)
  — the HBM→VMEM tier replaces the paper's global→local OpenCL memory
  staging, and the halo comes from the frame rather than inter-work-group
  synchronisation;
* the elemental function runs on the VPU/MXU over the whole VMEM tile
  (data-oriented, vectorised — not thread-oriented as in OpenCL);
* the output tile is staged in VMEM and DMA'd back **into the same frame
  layout**, so the frame is a fixed-point type: iterating the kernel needs
  no per-iteration ``jnp.pad``/slice (two full-grid HBM passes saved on an
  already memory-bound kernel) — only the O(m+n) ghost refresh between
  sweeps (:func:`repro.core.frames.refresh_frame`);
* the per-tile partial reduce accumulates in a VMEM scratch carried across
  the **sequential TPU grid** (acc BlockSpec pinned to (0,0)) — phase one of
  the paper's two-phase reduce.  The tiny final combine happens in the jnp
  wrapper and stays on device;
* optional **double-buffered DMA** (revolving windows) overlaps the next
  tile's copy with the current tile's compute — the TPU analogue of the
  paper's asynchronous H2D/D2H overlap via OpenCL events.

:func:`stencil2d_fused` keeps the one-shot (m, n) → (m, n) contract by
framing/unframing around one sweep; :func:`stencil2d_fused_framed` is the
zero-copy entry point the persistent engine (:mod:`repro.core.executor`)
iterates inside ``lax.while_loop``.

Validated in interpret mode against :mod:`repro.kernels.ref` (which is built
on :mod:`repro.core.stencil`, itself property-tested against the formal
semantics).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.frames import frame_spec, make_frame, frame_env, unframe
from repro.core.reduce import resolve_monoid


class KernelTaps:
    """Tap accessor over the halo-extended VMEM window (kernel-side twin of
    :class:`repro.core.stencil.TapAccessor`)."""

    def __init__(self, win, k: int, bm: int, bn: int):
        self._w, self._k, self._bm, self._bn = win, k, bm, bn

    def __call__(self, di: int, dj: int):
        k, bm, bn = self._k, self._bm, self._bn
        return self._w[k + di:k + di + bm, k + dj:k + dj + bn]

    @property
    def center(self):
        return self(0, 0)


def revolving_fetch(t, i, j, gm, gn, make_copies, double_buffer):
    """Bring tile (i, j)'s windows into VMEM; return the slot they landed
    in.  ``make_copies(ti, tj, slot)`` builds the async-copy list for one
    tile.  With double buffering the next tile's copies are kicked off
    into the other slot before waiting on the current one (revolving
    windows over the sequential TPU grid).  Shared by the single-step and
    temporal-blocking kernels."""
    if double_buffer:
        # first tile of the whole grid: kick off slot 0
        @pl.when(t == 0)
        def _():
            for cp in make_copies(i, j, 0):
                cp.start()
        # prefetch the next tile into the other slot
        nt = t + 1
        ni, nj = nt // gn, nt % gn

        @pl.when(nt < gm * gn)
        def _():
            for cp in make_copies(ni, nj, nt % 2):
                cp.start()
        for cp in make_copies(i, j, t % 2):
            cp.wait()
        return t % 2
    cps = make_copies(i, j, 0)
    for cp in cps:
        cp.start()
    for cp in cps:
        cp.wait()
    return 0


def reduce_epilogue(acc_ref, t, new, prev_center, *, measure, op, identity,
                    i, j, bm, bn, m, n, acc_dtype, do_reduce=True):
    """Fused per-tile partial reduce (phase 1 of the paper's two-phase
    reduce), accumulated across the sequential grid into ``acc_ref``.
    Cells beyond the (m, n) domain (block round-up) fold as ⊕'s identity.
    ``do_reduce=False`` only initialises the accumulator — used on
    intermediate unrolled sweeps, where the condition is not checked."""
    @pl.when(t == 0)
    def _():
        acc_ref[0, 0] = jnp.asarray(identity, acc_dtype)
    if not do_reduce:
        return
    meas = measure(new, prev_center) if measure is not None else new
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    valid = (rows < m) & (cols < n)
    meas = jnp.where(valid, meas.astype(acc_dtype),
                     jnp.asarray(identity, acc_dtype))
    part = _tile_fold(op, meas, identity, acc_dtype)
    if op in (jnp.logical_or, jnp.logical_and):
        # bool monoids accumulate as {0,1} indicators in the acc_dtype
        # scratch (or ≡ max, and ≡ min on {0,1}); decode_acc in the jnp
        # wrapper turns the scalar back into a bool.
        acc_op = jnp.maximum if op is jnp.logical_or else jnp.minimum
        acc_ref[0, 0] = acc_op(acc_ref[0, 0], part.astype(acc_dtype))
    else:
        acc_ref[0, 0] = op(acc_ref[0, 0], part)


def decode_acc(op, red):
    """Map the kernel's scalar accumulator back to the monoid's carrier
    (bool monoids ride through VMEM as {0,1} indicators)."""
    if op in (jnp.logical_or, jnp.logical_and):
        return red >= 0.5
    return red


def _stencil_kernel(x_hbm, *rest, f, measure, op,
                    identity, k, bm, bn, gm, gn, m, n, acc_dtype,
                    double_buffer, n_env, do_reduce):
    env = rest[:n_env]            # per-cell read-only fields (paper's `env`)
    o_hbm, acc_ref, win, wsem, ostage, osem = rest[n_env:]
    i, j = pl.program_id(0), pl.program_id(1)
    t = i * gn + j

    def window_copies(ti, tj, slot):
        return [pltpu.make_async_copy(
            x_hbm.at[pl.ds(ti * bm, bm + 2 * k), pl.ds(tj * bn, bn + 2 * k)],
            win.at[slot], wsem.at[slot])]

    slot = revolving_fetch(t, i, j, gm, gn, window_copies, double_buffer)
    taps = KernelTaps(win[slot], k, bm, bn)
    new = f(taps, *[e[...] for e in env])

    # write the tile back into the frame layout (ghost ring untouched —
    # the engine's O(m+n) refresh re-asserts it between sweeps)
    ostage[...] = new.astype(ostage.dtype)
    wr = pltpu.make_async_copy(
        ostage, o_hbm.at[pl.ds(k + i * bm, bm), pl.ds(k + j * bn, bn)], osem)
    wr.start()
    wr.wait()

    reduce_epilogue(acc_ref, t, new, taps.center, measure=measure, op=op,
                    identity=identity, i=i, j=j, bm=bm, bn=bn, m=m, n=n,
                    acc_dtype=acc_dtype, do_reduce=do_reduce)


def _tile_fold(op, x2d, identity, acc_dtype):
    """Fold a 2-D VMEM tile down to a scalar (VPU-friendly fast paths)."""
    if op is jnp.maximum:
        return jnp.max(x2d)
    if op is jnp.minimum:
        return jnp.min(x2d)
    if op is jnp.logical_or:
        return jnp.any(x2d)
    if op is jnp.logical_and:
        return jnp.all(x2d)
    import operator
    if op is operator.add:
        return jnp.sum(x2d)
    if op is operator.mul:
        return jnp.prod(x2d)
    # generic associative combinator: balanced tree over the flat tile
    flat = x2d.reshape(-1)
    n = flat.shape[0]
    size = 1 << (n - 1).bit_length()
    if size != n:
        flat = jnp.concatenate(
            [flat, jnp.full((size - n,), identity, acc_dtype)])
    while flat.shape[0] > 1:
        flat = op(flat[0::2], flat[1::2])
    return flat[0]


def stencil2d_fused_framed(frame: jnp.ndarray, f: Callable, spec, *,
                           env_framed=(), combine="sum", identity=None,
                           measure: Optional[Callable] = None,
                           acc_dtype=jnp.float32, double_buffer: bool = True,
                           do_reduce: bool = True, interpret: bool = False):
    """One fused sweep on a persistent halo frame — frame in, frame out.

    ``frame`` has the layout of ``spec`` (:func:`repro.core.frames.
    frame_spec` with ``sweeps=1``); ``env_framed`` are block-rounded
    interior-only fields (:func:`repro.core.frames.frame_env`).  Returns
    ``(new_frame, reduced_scalar)``; the new frame's ghost ring is
    *unrefreshed* — callers re-assert it with ``refresh_frame`` before the
    next sweep.  No full-grid pad or slice happens here: this is the
    zero-copy loop body.

    ``do_reduce=False`` skips the fused measure+fold (the scalar returned
    is just ⊕'s identity) — used by the engine on intermediate unrolled
    sweeps, where the condition is not checked and the reduce would be
    wasted work.
    """
    op, ident = resolve_monoid(combine, identity)
    k, bm, bn, gm, gn = spec.k, spec.bm, spec.bn, spec.gm, spec.gn
    nbuf = 2 if double_buffer else 1

    kernel = functools.partial(
        _stencil_kernel, f=f, measure=measure, op=op, identity=ident,
        k=k, bm=bm, bn=bn, gm=gm, gn=gn, m=spec.m, n=spec.n,
        acc_dtype=acc_dtype, double_buffer=double_buffer,
        n_env=len(env_framed), do_reduce=do_reduce)

    out, acc = pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]
        + [pl.BlockSpec((bm, bn), lambda i, j: (i, j)) for _ in env_framed],
        out_specs=[pl.BlockSpec(memory_space=pl.ANY),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(frame.shape, frame.dtype),
                   jax.ShapeDtypeStruct((1, 1), acc_dtype)],
        scratch_shapes=[pltpu.VMEM((nbuf, bm + 2 * k, bn + 2 * k),
                                   frame.dtype),
                        pltpu.SemaphoreType.DMA((nbuf,)),
                        pltpu.VMEM((bm, bn), frame.dtype),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(frame, *env_framed)
    return out, decode_acc(op, acc[0, 0])


def stencil2d_fused(a: jnp.ndarray, f: Callable, *, env=(), k: int = 1,
                    combine="sum", identity=None,
                    measure: Optional[Callable] = None,
                    boundary: str = "zero",
                    block: tuple[int, int] = (256, 256),
                    acc_dtype=jnp.float32, double_buffer: bool = True,
                    interpret: bool = False):
    """One fused stencil+partial-reduce sweep over a 2-D array.

    Returns ``(new_array, reduced_scalar)`` where the scalar is
    ``/(⊕) : measure(new, old_center)`` (or of ``new`` when measure is None).

    ``f`` is a taps-style elemental function ``f(get, *env_tiles)`` (same
    protocol as :func:`repro.core.stencil.stencil_taps`, offsets within ±k).
    ``env`` holds per-cell read-only fields (the paper Fig. 2 ``env``
    argument — e.g. the Helmholtz forcing matrix, the restoration
    observation+mask); they are tiled like the output, without halo.

    One-shot convenience: frames the input (⊥ padding + block round-up),
    runs :func:`stencil2d_fused_framed` once, and slices the domain back.
    Iterative callers should hold the frame across sweeps instead — see
    :mod:`repro.core.executor`.
    """
    m, n = a.shape
    spec = frame_spec(m, n, k=k, block=block)
    frame = make_frame(a, spec, boundary)
    env_framed = tuple(frame_env(e, spec, boundary) for e in env)
    out, red = stencil2d_fused_framed(
        frame, f, spec, env_framed=env_framed, combine=combine,
        identity=identity, measure=measure, acc_dtype=acc_dtype,
        double_buffer=double_buffer, interpret=interpret)
    return unframe(out, spec), red
