"""Fused stencil + partial-reduce Pallas TPU kernel (the paper's §3.3 core).

The paper fuses the stencil elemental function with the first (device-side)
phase of the reduce into one kernel — ``stencil<SUM_kernel, MF_kernel>`` —
so the convergence measure costs no extra memory pass.  TPU-native
re-thinking of that design:

* the global grid lives in HBM; each grid step DMAs its *halo-extended*
  (bm+2k, bn+2k) window into VMEM with an explicit async copy
  (``pltpu.make_async_copy``) — the HBM→VMEM tier replaces the paper's
  global→local OpenCL memory staging, and the halo comes from the window
  overlap rather than inter-work-group synchronisation;
* the elemental function runs on the VPU/MXU over the whole VMEM tile
  (data-oriented, vectorised — not thread-oriented as in OpenCL);
* the per-tile partial reduce accumulates in a VMEM scratch carried across
  the **sequential TPU grid** (out BlockSpec pinned to (0,0)) — phase one of
  the paper's two-phase reduce.  The tiny final combine happens in the jnp
  wrapper (:mod:`repro.kernels.ops`) and stays on device;
* optional **double-buffered DMA** (revolving windows) overlaps the next
  tile's copy with the current tile's compute — the TPU analogue of the
  paper's asynchronous H2D/D2H overlap via OpenCL events.

Validated in interpret mode against :mod:`repro.kernels.ref` (which is built
on :mod:`repro.core.stencil`, itself property-tested against the formal
semantics).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.reduce import resolve_monoid


class KernelTaps:
    """Tap accessor over the halo-extended VMEM window (kernel-side twin of
    :class:`repro.core.stencil.TapAccessor`)."""

    def __init__(self, win, k: int, bm: int, bn: int):
        self._w, self._k, self._bm, self._bn = win, k, bm, bn

    def __call__(self, di: int, dj: int):
        k, bm, bn = self._k, self._bm, self._bn
        return self._w[k + di:k + di + bm, k + dj:k + dj + bn]

    @property
    def center(self):
        return self(0, 0)


def _stencil_kernel(x_hbm, *rest, f, measure, op,
                    identity, k, bm, bn, gm, gn, m, n, acc_dtype,
                    double_buffer, n_env):
    env = rest[:n_env]            # per-cell read-only fields (paper's `env`)
    o_ref, acc_ref, win, sem = rest[n_env:]
    i, j = pl.program_id(0), pl.program_id(1)
    t = i * gn + j
    nbuf = 2 if double_buffer else 1

    def window_copy(ti, tj, slot):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(ti * bm, bm + 2 * k), pl.ds(tj * bn, bn + 2 * k)],
            win.at[slot], sem.at[slot])

    if double_buffer:
        # first tile of the whole grid: kick off slot 0
        @pl.when(t == 0)
        def _():
            window_copy(i, j, 0).start()
        # prefetch the next tile into the other slot
        nt = t + 1
        ni, nj = nt // gn, nt % gn

        @pl.when(nt < gm * gn)
        def _():
            window_copy(ni, nj, (t + 1) % 2).start()
        window_copy(i, j, t % 2).wait()
        w = win[t % 2]
    else:
        cp = window_copy(i, j, 0)
        cp.start()
        cp.wait()
        w = win[0]

    taps = KernelTaps(w, k, bm, bn)
    new = f(taps, *[e[...] for e in env])
    o_ref[...] = new.astype(o_ref.dtype)

    # fused partial reduce (phase 1 of the paper's two-phase reduce)
    meas = measure(new, taps.center) if measure is not None else new
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    valid = (rows < m) & (cols < n)
    meas = jnp.where(valid, meas.astype(acc_dtype),
                     jnp.asarray(identity, acc_dtype))
    part = _tile_fold(op, meas, identity, acc_dtype)

    @pl.when(t == 0)
    def _():
        acc_ref[0, 0] = jnp.asarray(identity, acc_dtype)
    acc_ref[0, 0] = op(acc_ref[0, 0], part)


def _tile_fold(op, x2d, identity, acc_dtype):
    """Fold a 2-D VMEM tile down to a scalar (VPU-friendly fast paths)."""
    if op is jnp.maximum:
        return jnp.max(x2d)
    if op is jnp.minimum:
        return jnp.min(x2d)
    if op is jnp.logical_or:
        return jnp.any(x2d)
    if op is jnp.logical_and:
        return jnp.all(x2d)
    import operator
    if op is operator.add:
        return jnp.sum(x2d)
    if op is operator.mul:
        return jnp.prod(x2d)
    # generic associative combinator: balanced tree over the flat tile
    flat = x2d.reshape(-1)
    n = flat.shape[0]
    size = 1 << (n - 1).bit_length()
    if size != n:
        flat = jnp.concatenate(
            [flat, jnp.full((size - n,), identity, acc_dtype)])
    while flat.shape[0] > 1:
        flat = op(flat[0::2], flat[1::2])
    return flat[0]


def stencil2d_fused(a: jnp.ndarray, f: Callable, *, env=(), k: int = 1,
                    combine="sum", identity=None,
                    measure: Optional[Callable] = None,
                    boundary: str = "zero",
                    block: tuple[int, int] = (256, 256),
                    acc_dtype=jnp.float32, double_buffer: bool = True,
                    interpret: bool = False):
    """One fused stencil+partial-reduce sweep over a 2-D array.

    Returns ``(new_array, reduced_scalar)`` where the scalar is
    ``/(⊕) : measure(new, old_center)`` (or of ``new`` when measure is None).

    ``f`` is a taps-style elemental function ``f(get, *env_tiles)`` (same
    protocol as :func:`repro.core.stencil.stencil_taps`, offsets within ±k).
    ``env`` holds per-cell read-only fields (the paper Fig. 2 ``env``
    argument — e.g. the Helmholtz forcing matrix, the restoration
    observation+mask); they are tiled like the output, without halo.
    """
    op, ident = resolve_monoid(combine, identity)
    m, n = a.shape
    bm, bn = block
    bm, bn = min(bm, _ceil_mul(m, 8)), min(bn, _ceil_mul(n, 128))
    gm, gn = -(-m // bm), -(-n // bn)

    # ⊥ padding: k halo + round-up to the block grid (edge fill w/ boundary)
    pad_m, pad_n = gm * bm - m, gn * bn - n
    mode = {"zero": ("constant", 0), "nan": ("constant", jnp.nan),
            "reflect": ("reflect", None), "wrap": ("wrap", None)}[boundary]
    if mode[0] == "constant":
        xp = jnp.pad(a, ((k, k + pad_m), (k, k + pad_n)),
                     constant_values=mode[1])
    else:
        xp = jnp.pad(a, ((k, k), (k, k)), mode=mode[0])
        xp = jnp.pad(xp, ((0, pad_m), (0, pad_n)))  # grid round-up: inert
    envp = tuple(jnp.pad(e, ((0, pad_m), (0, pad_n))) for e in env)
    nbuf = 2 if double_buffer else 1

    kernel = functools.partial(
        _stencil_kernel, f=f, measure=measure, op=op, identity=ident,
        k=k, bm=bm, bn=bn, gm=gm, gn=gn, m=m, n=n, acc_dtype=acc_dtype,
        double_buffer=double_buffer, n_env=len(env))

    out, acc = pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]
        + [pl.BlockSpec((bm, bn), lambda i, j: (i, j)) for _ in env],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((gm * bm, gn * bn), a.dtype),
                   jax.ShapeDtypeStruct((1, 1), acc_dtype)],
        scratch_shapes=[pltpu.VMEM((nbuf, bm + 2 * k, bn + 2 * k), a.dtype),
                        pltpu.SemaphoreType.DMA((nbuf,))],
        interpret=interpret,
    )(xp, *envp)
    return out[:m, :n], acc[0, 0]


def _ceil_mul(x: int, q: int) -> int:
    return -(-x // q) * q
