"""Sliding-window flash attention Pallas kernel (sequence-stencil).

The LM-side hot-spot where the paper's stencil insight applies 1-D: a
local attention layer is a one-sided causal stencil of radius ``window``
along the sequence.  Flash-style online softmax over kv blocks:

* grid (B·H, S/bq, S/bk) — the kv axis is the innermost (sequential on
  TPU) dimension; running (m, l, acc) live in VMEM scratch and reset at
  the first kv block of every q row;
* blocks outside the stencil (kv ahead of q, or behind the window) are
  masked at element level and their DMAs skipped at block level via the
  index map (the block never moves when fully out of range — the tile is
  re-read but ignored, keeping the spec static);
* bq = bk = 128 (MXU-aligned), accumulation fp32.

Oracle: :func:`repro.kernels.ref_swa.swa_attention_ref`; tests sweep
shapes/windows/causal in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                bq, bk, nk, window, causal, scale, softcap):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window

    # block-level early out: fully-masked kv blocks skip all compute
    @pl.when(jnp.any(ok))
    def _():
        q = q_ref[0].astype(jnp.float32) * scale         # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        s = q @ k.T                                      # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha \
            + p @ v_ref[0].astype(jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def swa_attention(q, k, v, *, window: int = 0, causal: bool = True,
                  block_q: int = 128, block_k: int = 128,
                  softcap: float = 0.0, interpret: bool = False):
    """Flash sliding-window attention with native GQA.

    q: (B·H, S, hd); k, v: (B·KH, S, hd).  The kv BlockSpec index map
    folds the query head onto its kv group (``b // G``) — grouped keys
    are never materialised per-head.  Returns (B·H, S, hd).
    """
    BH, S, hd = q.shape
    BKH = k.shape[0]
    assert BH % BKH == 0, "q heads must be a multiple of kv heads"
    G = BH // BKH
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, "S must tile"
    nq, nk = S // bq, S // bk
    scale = float(1.0 / np.sqrt(hd))

    kernel = functools.partial(
        _swa_kernel, bq=bq, bk=bk, nk=nk, window=window, causal=causal,
        scale=scale, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def swa_attention_ref(q, k, v, *, window: int = 0, causal: bool = True):
    """Pure-jnp oracle: masked softmax attention."""
    BH, S, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
    if window:
        ok &= kp > qp - window
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)) \
        .astype(q.dtype)
