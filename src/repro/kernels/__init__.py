"""Pallas TPU kernels for the paper's compute hot-spot: the fused
stencil + partial-reduce sweep (§3.3), plus the sliding-window flash
attention kernel used by the sequence-stencil layers of the LM stack.

Every kernel ships with a pure-jnp oracle in :mod:`repro.kernels.ref` and a
jit'd public wrapper in :mod:`repro.kernels.ops`; tests sweep shapes/dtypes
and assert allclose in interpret mode (this container is CPU-only; TPU is
the target).
"""
from .stencil2d import stencil2d_fused, KernelTaps
from . import ops, ref

__all__ = ["stencil2d_fused", "KernelTaps", "ops", "ref"]
