"""AdamW in pure JAX with mixed-precision master weights.

Params live in the model dtype (bf16 in production); the optimizer carries
fp32 master copies and moments.  Sharding: the states inherit the param's
PartitionSpec plus ZeRO-1 extension over the ``data`` axis (see
``repro.sharding.specs.zero1_spec``) — the classic optimizer-state
sharding used at 1000-node scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    master: Any       # fp32 master params
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0

    def init(self, params) -> AdamState:
        # copy=True: an fp32 param must not share its buffer with the
        # master (both are donated by the train step)
        f32 = lambda t: jax.tree.map(
            lambda x: jnp.array(x, jnp.float32, copy=True), t)
        zeros = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return AdamState(step=jnp.zeros((), jnp.int32), master=f32(params),
                         m=zeros(params), v=zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamState, params):
        """Returns (new_params, new_state, stats)."""
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.where(
            self.grad_clip > 0,
            jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9)), 1.0)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, g32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state.v, g32)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(mw, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * mw
            return mw - lr * u
        master = jax.tree.map(upd, state.master, m, v)

        def cast(mw, p):
            if mw.dtype == p.dtype:
                # barrier prevents XLA from aliasing the param output to
                # the master output — both are donated on the next step
                return jax.lax.optimization_barrier(mw)
            return mw.astype(p.dtype)
        new_params = jax.tree.map(cast, master, params)
        stats = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32),
                 "clip_scale": scale}
        return new_params, AdamState(step, master, m, v), stats


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
