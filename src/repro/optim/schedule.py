"""LR schedules (pure functions of the step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup: int, total: int,
                       floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor × peak``."""
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, peak_lr * cos)
    return lr


def constant(lr_value: float):
    def lr(step):
        return jnp.full((), lr_value, jnp.float32)
    return lr
