from .adam import AdamW, AdamState, global_norm
from .schedule import cosine_with_warmup, constant

__all__ = ["AdamW", "AdamState", "global_norm", "cosine_with_warmup",
           "constant"]
