"""Deterministic fault injection for Loop-of-stencil-reduce farms.

A :class:`FaultPlan` is a STATIC, seeded schedule of faults, attached to
a loop through the ``fault_hook`` seam in
:class:`repro.core.pattern.LoopOfStencilReduce`: the hook intercepts the
fused per-lane reduce value INSIDE the jitted lane body — after the real
stencil+reduce, before the convergence condition and the sentinel — so
an injected fault exercises exactly the detection path a real NaN-ed or
non-converging item would, at zero cost to the fault-free build (no
hook, no extra ops).

Faults address LANES (device slots), not stream items: a NaN event on
lane 2 poisons WHATEVER item occupies slot 2 when the trigger sweep
arrives, exactly like flaky hardware or a corrupted resident frame
would.  That is what makes retry-into-a-fresh-slot a meaningful
recovery: the retried item escapes the fault, and the slot keeps
failing occupants until the engine's ``slot_patience`` retires it.

Stream-item corruption (``corrupt_indices``) is the complementary axis:
the fault follows the ITEM (a NaN planted in its input array), so it is
caught by the admission-time finite check however often it is retried.

Everything is pure numpy/static-python at plan-build time and pure
jittable masking inside the hook — the same plan replays bit-identically
on every run, device count and backend (the chaos tests' foundation).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A static fault schedule over ``lanes`` device slots.

    ``nan_events``       — ``(lane, from_sweep)`` pairs: the lane's
                           reduce value reads NaN from that sweep on
                           (the sentinel's poison detector must fire).
    ``stall_events``     — ``(lane, until_sweep)`` pairs: the lane's
                           reduce value is pinned at ``stall_value``
                           while ``it < until_sweep`` — it cannot
                           converge, so it either trips the sentinel's
                           divergence patience or exhausts the
                           iteration budget (``until_sweep`` beyond
                           ``max_iters`` = a permanent stall).
    ``corrupt_indices``  — stream positions whose ITEMS get a NaN
                           planted at the prep boundary
                           (:meth:`corrupt_stream`) — admission-check
                           fodder.
    ``preempt_at_segment`` — a PROCESS fault: the dispatcher is killed
                           after completing this many segments (1-based)
                           via the ``on_segment`` seam in
                           ``FarmEngine.run_continuous`` /
                           ``ContinuousEngine.run``.  See
                           :meth:`preempt_hook`.
    """
    lanes: int
    nan_events: Tuple[Tuple[int, int], ...] = ()
    stall_events: Tuple[Tuple[int, int], ...] = ()
    corrupt_indices: Tuple[int, ...] = ()
    stall_value: float = 1e9
    preempt_at_segment: "int | None" = None

    def __post_init__(self):
        for lane, _ in (*self.nan_events, *self.stall_events):
            if not 0 <= lane < self.lanes:
                raise ValueError(
                    f"fault lane {lane} outside [0, lanes={self.lanes})")

    @classmethod
    def seeded(cls, seed: int, lanes: int, *, n_nan: int = 1,
               n_stall: int = 1, nan_from_max: int = 4,
               stall_until: int = 1 << 20, n_corrupt: int = 0,
               n_items: int = 0, stall_value: float = 1e9,
               preempt_within: int = 0) -> "FaultPlan":
        """Draw a reproducible plan: ``n_nan`` + ``n_stall`` DISTINCT
        victim lanes (never more than ``lanes - 1`` total — at least one
        lane always stays healthy, so every chaos test has a clean
        control group), NaN triggers in ``[1, nan_from_max]``, and
        ``n_corrupt`` corrupted stream positions out of ``n_items``.
        ``preempt_within > 0`` additionally draws a kill point
        ``preempt_at_segment`` uniformly from ``[1, preempt_within]``.
        Same seed → same plan, bit for bit."""
        rng = np.random.default_rng(seed)
        n_victims = min(n_nan + n_stall, max(lanes - 1, 0))
        victims = rng.choice(lanes, size=n_victims, replace=False)
        n_nan = min(n_nan, n_victims)
        nan_events = tuple(
            (int(l), int(rng.integers(1, nan_from_max + 1)))
            for l in victims[:n_nan])
        stall_events = tuple((int(l), int(stall_until))
                             for l in victims[n_nan:])
        corrupt: Tuple[int, ...] = ()
        if n_corrupt and n_items:
            corrupt = tuple(int(i) for i in np.sort(rng.choice(
                n_items, size=min(n_corrupt, n_items), replace=False)))
        preempt = (int(rng.integers(1, preempt_within + 1))
                   if preempt_within > 0 else None)
        return cls(lanes=lanes, nan_events=nan_events,
                   stall_events=stall_events, corrupt_indices=corrupt,
                   stall_value=stall_value, preempt_at_segment=preempt)

    # -- the device-side seam ---------------------------------------------
    def reduce_hook(self):
        """The jittable ``(r, it) -> r`` hook for
        ``LoopOfStencilReduce.fault_hook``: per-lane masked overwrites
        of the fused reduce value (a handful of (lanes,) ops — nothing
        touches the grid).  ``r`` and ``it`` are (lanes,) vectors."""
        import jax.numpy as jnp

        nan_events, stall_events = self.nan_events, self.stall_events
        stall_value = self.stall_value

        def hook(r, it):
            lanes = jnp.arange(r.shape[0])
            for lane, from_sweep in nan_events:
                mask = jnp.logical_and(lanes == lane, it >= from_sweep)
                r = jnp.where(mask, jnp.asarray(jnp.nan, r.dtype), r)
            for lane, until in stall_events:
                mask = jnp.logical_and(lanes == lane, it < until)
                r = jnp.where(mask, jnp.asarray(stall_value, r.dtype),
                              r)
            return r
        return hook

    def instrument(self, loop):
        """A copy of ``loop`` carrying this plan's hook (the original is
        untouched — run both to compare faulted vs fault-free)."""
        return dataclasses.replace(loop, fault_hook=self.reduce_hook())

    # -- the process-fault seam -------------------------------------------
    def preempt_hook(self, mode: str = "exit"):
        """An ``on_segment(segments_done)`` callback that preempts the
        process once ``segments_done`` reaches ``preempt_at_segment``.

        ``mode="exit"`` dies via ``os._exit(PREEMPTED_EXIT)`` — no
        ``finally`` blocks, no atexit, no flushing: the closest a test
        gets to SIGKILL-on-spot-reclaim while staying portable.  The
        ``recovery.run_to_completion`` harness respawns on that exit
        code.  ``mode="raise"`` raises
        :class:`~repro.resilience.recovery.PreemptionError` instead, for
        in-process tests that resume inside the same interpreter (the
        engine's ``finally`` DOES run — strictly gentler than a kill, so
        subprocess tests stay the authority on crash-hardness).

        Fires at most once per process (a resumed run that passes the
        same plan again is not re-killed unless it re-reaches the
        threshold counting from ITS OWN segment 0 — pass ``None``
        recovery-side to disarm instead)."""
        if self.preempt_at_segment is None:
            return None
        import os as _os

        from .recovery import PREEMPTED_EXIT, PreemptionError
        threshold = self.preempt_at_segment
        fired = []

        def hook(segments_done: int):
            if fired or segments_done < threshold:
                return
            fired.append(segments_done)
            if mode == "raise":
                raise PreemptionError(
                    f"seeded preemption at segment {segments_done}")
            _os._exit(PREEMPTED_EXIT)
        return hook

    # -- the prep-boundary seam -------------------------------------------
    def corrupt_item(self, item):
        """Plant one NaN in the main leaf of ``item`` (a copy)."""
        if isinstance(item, tuple):
            return (self.corrupt_item(item[0]), *item[1:])
        arr = np.array(item, copy=True)
        if np.issubdtype(arr.dtype, np.floating) and arr.size:
            arr.flat[arr.size // 2] = np.nan
        return arr

    def corrupt_stream(self, items):
        """Lazily yield ``items`` with the planned positions corrupted —
        drop-in for a FarmEngine source."""
        bad = set(self.corrupt_indices)
        for i, item in enumerate(items):
            yield self.corrupt_item(item) if i in bad else item
