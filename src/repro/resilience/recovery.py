"""Crash/preemption recovery: snapshots, write-ahead journal, respawn.

Three cooperating pieces, shared by training checkpoints, streaming
farms, and the serve tier:

* **Atomic directory publish** — the step-atomic rename protocol that
  ``train/checkpoint.py`` pioneered, generalized and fixed: the old copy
  of a step is renamed *aside* before the new one is published, so there
  is no window in which neither exists (``rmtree`` before ``os.replace``
  had one).  Readers tolerate stray ``.tmp-*`` / ``.old-*`` dirs left by
  a crash, and a missing final dir can be recovered from its ``.old``.

* **Structure-preserving snapshots** — unlike ``checkpoint.restore``,
  which needs a template pytree, engine snapshots carry *dynamic*
  structure (a variable number of in-flight occupants, a retry queue of
  unknown length).  ``save_snapshot`` serializes an arbitrary tree of
  dict/list/tuple/str/int/float/bool/None with array leaves hoisted into
  one ``.npz`` (bf16 as uint16 views + a dtype tag), and
  ``load_snapshot`` rebuilds the identical structure with ``np.ndarray``
  leaves — no template required.  Arrays are *logical* (unsharded), so a
  snapshot written at lanes=L / mesh=M restores onto any other
  lane count or mesh (elastic resume).

* **Write-ahead result journal** — an append-only, fsync'd JSONL file of
  emitted results.  Every record line carries its own CRC32, so replay
  stops cleanly at a torn tail (a crash mid-append).  A resumed run
  replays the journal to re-emit pre-crash results and suppresses their
  indices, giving exactly-once emission across restarts.

``run_to_completion`` is the kill-and-respawn harness: it re-execs a
child command while it exits with ``PREEMPTED_EXIT`` (the seeded
process-fault exit code used by ``FaultPlan.preempt_hook``).
"""
from __future__ import annotations

import base64
import dataclasses
import io
import json
import os
import shutil
import subprocess
import sys
import zlib
from typing import Any, Callable, Iterator, List, Optional

import numpy as np

try:  # bf16 round-trips through uint16 views; jax supplies the dtype
    import jax.numpy as jnp
    _BF16 = jnp.bfloat16
except Exception:  # pragma: no cover - jax is a hard dep of this repo
    jnp = None
    _BF16 = None

# exit code a seeded preemption uses (os._exit — no finally blocks run,
# like a SIGKILL'd spot instance); the respawn harness treats it as
# "preempted, restart", anything else as a real failure.
PREEMPTED_EXIT = 17


class PreemptionError(RuntimeError):
    """Raised by ``FaultPlan.preempt_hook(mode="raise")`` — the
    in-process stand-in for a kill, used by tests that resume inside
    the same interpreter."""


# ---------------------------------------------------------------------------
# atomic directory publish (shared with train/checkpoint.py)
# ---------------------------------------------------------------------------

def fresh_tmp_dir(parent: str, tag: str) -> str:
    """Create and return an empty ``<parent>/.tmp-<tag>`` staging dir."""
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, f".tmp-{tag}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    return tmp


def publish_dir(tmp: str, final: str) -> str:
    """Atomically publish staging dir ``tmp`` as ``final``.

    Crash-safe at every point: if ``final`` already exists it is renamed
    aside to ``.old-<name>`` first, then ``tmp`` is renamed in, then the
    old copy is deleted.  A crash between any two steps leaves either
    the old or the new copy (or both) on disk — never neither.  Readers
    (``latest_step_in`` / ``recover_stray``) resolve leftovers.
    """
    parent = os.path.dirname(final)
    name = os.path.basename(final)
    old = os.path.join(parent, f".old-{name}")
    if os.path.exists(old):  # leftover from an earlier crash
        shutil.rmtree(old)
    had_prev = os.path.exists(final)
    if had_prev:
        os.replace(final, old)  # rename aside, NOT rmtree: old stays whole
    os.replace(tmp, final)      # atomic publish
    if had_prev:
        shutil.rmtree(old)      # only now is the old copy unreachable
    return final


def sweep_strays(parent: str) -> None:
    """Best-effort removal of ``.tmp-*`` / ``.old-*`` crash leftovers.

    ``.old-<name>`` dirs are only removed when ``<name>`` exists (the
    publish completed); otherwise they are the sole surviving copy and
    are recovered by promotion instead of deletion.
    """
    if not os.path.isdir(parent):
        return
    for d in os.listdir(parent):
        path = os.path.join(parent, d)
        if d.startswith(".tmp-"):
            shutil.rmtree(path, ignore_errors=True)
        elif d.startswith(".old-"):
            final = os.path.join(parent, d[len(".old-"):])
            if os.path.exists(final):
                shutil.rmtree(path, ignore_errors=True)
            else:  # crash after rename-aside, before publish: promote
                os.replace(path, final)


def list_steps(parent: str, prefix: str = "step_") -> List[int]:
    """Published step numbers under ``parent``, stray-tolerant."""
    if not os.path.isdir(parent):
        return []
    sweep_strays(parent)
    out = []
    for d in os.listdir(parent):
        if d.startswith(prefix) and not d.startswith("."):
            try:
                out.append(int(d[len(prefix):]))
            except ValueError:
                continue
    return sorted(out)


# ---------------------------------------------------------------------------
# structure-preserving snapshots
# ---------------------------------------------------------------------------

_LEAF = "__leaf__"
_TUPLE = "__tuple__"


def _is_array(x: Any) -> bool:
    if isinstance(x, (np.ndarray, np.generic)):
        return True
    return hasattr(x, "dtype") and hasattr(x, "shape") and hasattr(x, "__array__")


def _encode(obj: Any, leaves: List[np.ndarray]) -> Any:
    if _is_array(obj):
        idx = len(leaves)
        leaves.append(np.asarray(obj))
        return {_LEAF: idx}
    if isinstance(obj, dict):
        return {str(k): _encode(v, leaves) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUPLE: [_encode(v, leaves) for v in obj]}
    if isinstance(obj, list):
        return [_encode(v, leaves) for v in obj]
    if obj is None or isinstance(obj, (bool, str)):
        return obj
    if isinstance(obj, (int, float)):
        return obj.item() if isinstance(obj, np.generic) else obj
    raise TypeError(f"snapshot cannot serialize {type(obj).__name__}")


def _decode(obj: Any, leaves: dict) -> Any:
    if isinstance(obj, dict):
        if set(obj) == {_LEAF}:
            return leaves[obj[_LEAF]]
        if set(obj) == {_TUPLE}:
            return tuple(_decode(v, leaves) for v in obj[_TUPLE])
        return {k: _decode(v, leaves) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v, leaves) for v in obj]
    return obj


def save_snapshot(snap_dir: str, step: int, tree: Any, *,
                  keep: int = 2) -> str:
    """Write ``tree`` (dicts/lists/tuples/scalars + array leaves) as the
    atomically-published ``<snap_dir>/step_<step>``.  Keeps the newest
    ``keep`` snapshots."""
    tmp = fresh_tmp_dir(snap_dir, str(step))
    leaves: List[np.ndarray] = []
    skeleton = _encode(tree, leaves)
    arrays, dtypes = {}, {}
    for i, arr in enumerate(leaves):
        dtypes[str(i)] = str(arr.dtype)
        if _BF16 is not None and arr.dtype == _BF16:
            arr = arr.view(np.uint16)
            dtypes[str(i)] = "bfloat16"
        arrays[str(i)] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "n_leaves": len(leaves), "dtypes": dtypes,
                "skeleton": skeleton}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    final = publish_dir(tmp, os.path.join(snap_dir, f"step_{step:010d}"))
    for s in list_steps(snap_dir)[:-keep]:
        shutil.rmtree(os.path.join(snap_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    return final


def latest_snapshot_step(snap_dir: str) -> Optional[int]:
    steps = list_steps(snap_dir)
    return steps[-1] if steps else None


def load_snapshot(snap_dir: str, *, step: Optional[int] = None) -> Any:
    """Rebuild the tree written by ``save_snapshot``.  Returns ``None``
    when no snapshot has been published yet (a fresh run)."""
    if step is None:
        step = latest_snapshot_step(snap_dir)
        if step is None:
            return None
    path = os.path.join(snap_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = {}
    for i in range(manifest["n_leaves"]):
        arr = data[str(i)]
        if manifest["dtypes"][str(i)] == "bfloat16" and _BF16 is not None:
            arr = arr.view(_BF16)
        leaves[i] = arr
    return _decode(manifest["skeleton"], leaves)


# ---------------------------------------------------------------------------
# write-ahead result journal
# ---------------------------------------------------------------------------

_ND = "__nd__"


def _to_jsonable(v: Any) -> Any:
    if v is None or isinstance(v, (bool, str)):
        return v
    if _is_array(v):
        arr = np.asarray(v)
        if _BF16 is not None and arr.dtype == _BF16:
            buf = io.BytesIO()
            np.save(buf, arr.view(np.uint16), allow_pickle=False)
            return {_ND: base64.b64encode(buf.getvalue()).decode("ascii"),
                    "bf16": True}
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        return {_ND: base64.b64encode(buf.getvalue()).decode("ascii")}
    if isinstance(v, (int, float)):
        return v.item() if isinstance(v, np.generic) else v
    if isinstance(v, (list, tuple)):
        return [_to_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _to_jsonable(x) for k, x in v.items()}
    raise TypeError(f"journal cannot serialize {type(v).__name__}")


def _from_jsonable(v: Any) -> Any:
    if isinstance(v, dict):
        if _ND in v:
            arr = np.load(io.BytesIO(base64.b64decode(v[_ND])),
                          allow_pickle=False)
            if v.get("bf16") and _BF16 is not None:
                arr = arr.view(_BF16)
            return arr
        return {k: _from_jsonable(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_from_jsonable(x) for x in v]
    return v


class Journal:
    """Append-only, fsync'd, CRC-framed JSONL write-ahead log.

    Each line is ``<crc32 hex8> <json>\\n`` where the CRC covers the json
    text.  ``replay`` yields decoded records up to (not including) the
    first torn or corrupt line — a crash mid-``append`` loses at most the
    record being written, which by WAL ordering was not yet emitted.
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "ab")

    def append(self, record: dict) -> None:
        text = json.dumps(_to_jsonable(record), separators=(",", ":"))
        crc = zlib.crc32(text.encode("utf-8")) & 0xFFFFFFFF
        self._fh.write(f"{crc:08x} {text}\n".encode("utf-8"))
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        try:
            self._fh.close()
        except Exception:
            pass

    @staticmethod
    def replay(path: str) -> Iterator[dict]:
        if not os.path.exists(path):
            return
        with open(path, "rb") as fh:
            for raw in fh:
                line = raw.decode("utf-8", errors="replace")
                if not line.endswith("\n"):
                    return  # torn tail: crash mid-append
                body = line[:-1]
                if len(body) < 10 or body[8] != " ":
                    return
                text = body[9:]
                try:
                    if int(body[:8], 16) != (zlib.crc32(text.encode("utf-8"))
                                             & 0xFFFFFFFF):
                        return
                    rec = json.loads(text)
                except (ValueError, json.JSONDecodeError):
                    return
                yield _from_jsonable(rec)


# ---------------------------------------------------------------------------
# recovery config + respawn harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Where and how often an engine persists its recovery state.

    * ``dir``      — root; snapshots under ``<dir>/snapshots``, journal at
                     ``<dir>/journal.jsonl``.
    * ``snapshot_every`` — snapshot cadence in segments (RPO: at most this
                     many segments of *compute* are redone on resume; no
                     emitted result is ever redone thanks to the journal).
    * ``fsync``    — fsync each journal append (turn off only in tests).
    * ``keep``     — retained snapshot count.
    """
    dir: str
    snapshot_every: int = 1
    fsync: bool = True
    keep: int = 2

    @property
    def snap_dir(self) -> str:
        return os.path.join(self.dir, "snapshots")

    @property
    def journal_path(self) -> str:
        return os.path.join(self.dir, "journal.jsonl")


def run_to_completion(argv: List[str], *, max_restarts: int = 8,
                      env: Optional[dict] = None,
                      on_restart: Optional[Callable[[int], None]] = None,
                      timeout: Optional[float] = None) -> int:
    """Run ``argv`` as a subprocess, respawning while it exits with
    ``PREEMPTED_EXIT``.  Returns the number of restarts on success;
    raises on any other non-zero exit or when ``max_restarts`` is hit.

    This is the test/bench stand-in for a cluster scheduler restarting a
    preempted worker: the child is expected to pick ``--resume`` state up
    from its recovery dir on each respawn.
    """
    restarts = 0
    while True:
        proc = subprocess.run(argv, env=env, timeout=timeout)
        if proc.returncode == 0:
            return restarts
        if proc.returncode != PREEMPTED_EXIT:
            raise RuntimeError(
                f"child failed with exit {proc.returncode} (not a "
                f"preemption): {' '.join(argv)}")
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError(
                f"child still preempting after {max_restarts} restarts")
        if on_restart is not None:
            on_restart(restarts)
