"""Resilience tier — deterministic fault injection for the farm layers.

The chaos-test counterpart of the sentinel/quarantine machinery in
:mod:`repro.core`: a seeded :class:`~repro.resilience.faults.FaultPlan`
poisons chosen lanes at chosen sweeps (NaN), stalls lanes (a reduce
value pinned above the convergence threshold), and corrupts stream
items at the prep boundary — so CI can assert exactly-once delivery,
no cross-lane contamination and graceful degradation under the exact
same fault schedule on every run.

PR 7 adds the PROCESS-fault axis: :mod:`~repro.resilience.recovery`
holds the snapshot + write-ahead-journal layer (atomic directory
publish, structure-preserving snapshots, CRC-framed fsync'd journal,
kill-and-respawn harness), and ``FaultPlan`` grew
``preempt_at_segment`` / :meth:`~repro.resilience.faults.FaultPlan.preempt_hook`
so the chaos suite can kill a run at a seeded segment boundary and
assert the resumed run is exactly-once and bit-identical.
"""
from .faults import FaultPlan
from .recovery import (PREEMPTED_EXIT, Journal, PreemptionError,
                       RecoveryConfig, latest_snapshot_step, load_snapshot,
                       run_to_completion, save_snapshot)

__all__ = ["FaultPlan", "RecoveryConfig", "Journal", "PreemptionError",
           "PREEMPTED_EXIT", "save_snapshot", "load_snapshot",
           "latest_snapshot_step", "run_to_completion"]
