"""Resilience tier — deterministic fault injection for the farm layers.

The chaos-test counterpart of the sentinel/quarantine machinery in
:mod:`repro.core`: a seeded :class:`~repro.resilience.faults.FaultPlan`
poisons chosen lanes at chosen sweeps (NaN), stalls lanes (a reduce
value pinned above the convergence threshold), and corrupts stream
items at the prep boundary — so CI can assert exactly-once delivery,
no cross-lane contamination and graceful degradation under the exact
same fault schedule on every run.
"""
from .faults import FaultPlan

__all__ = ["FaultPlan"]
