"""Paper §4.3 — two-phase video restoration over a frame stream.

pipe(read, detect, ofarm(restore), write): adaptive-median detection
(escalating 3×3→7×7 stencil) + iterative edge-preserving regularisation
(Loop-of-stencil-reduce -d), streamed through the lane-resident
FarmEngine: the detection pass is the per-item ``prep`` stage, the
restoration loop runs in persistent lane slots that are refilled in
place with each next frame (device buffers persist across stream items,
as in the paper's FastFlow realisation), and host-side double buffering
overlaps read/write with device compute.

    PYTHONPATH=src python examples/video_restoration.py \
        [--frames 8] [--noise 0.3] [--res vga] [--lanes 2]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FarmEngine, LoopOfStencilReduce
from repro.kernels import ops, ref as R

RES = {"vga": (480, 640), "720p": (720, 1280), "tiny": (96, 160)}


def synth_video(shape, frames, noise, seed=0):
    yy, xx = np.mgrid[0:shape[0], 0:shape[1]]
    rng = np.random.default_rng(seed)
    for t in range(frames):
        base = 0.5 + 0.3 * np.sin(xx / 25.0 + t / 3) \
            * np.cos(yy / 18.0) + 0.2 * (((xx + 4 * t) // 40 + yy // 30)
                                         % 2)
        clean = np.clip(base, 0, 1).astype(np.float32)
        imp = rng.uniform(size=shape) < noise
        sp = np.where(rng.uniform(size=shape) < 0.5, 0.0, 1.0)
        yield clean, np.where(imp, sp, clean).astype(np.float32)


def psnr(a, b):
    return -10 * np.log10(np.mean((np.asarray(a) - np.asarray(b)) ** 2)
                          + 1e-12)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--noise", type=float, default=0.3)
    ap.add_argument("--res", choices=list(RES), default="tiny")
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--backend", default="pallas",
                    choices=("jnp", "pallas", "pallas-multistep"),
                    help="per-lane loop body (pallas = persistent lane "
                         "frames refilled in place, the engine-tier "
                         "path; interpret-mode on CPU — use jnp for "
                         "big grids on CPU-only hosts)")
    args = ap.parse_args()

    pairs = list(synth_video(RES[args.res], args.frames, args.noise))
    cleans = [c for c, _ in pairs]
    noisys = [n for _, n in pairs]

    # detection is the farm's per-item prep stage: AMF mask + repaired
    # initial guess become the lane's grid and env fields
    def detect(frame):
        mask, repaired = ops.adaptive_median_detect(frame)
        return repaired, (repaired, mask)

    restore_loop = LoopOfStencilReduce(
        f=R.restore_taps(2.0), k=1, combine="max", delta=R.abs_delta,
        cond=lambda r: r < 1e-3, boundary="reflect", max_iters=50,
        backend=args.backend)

    eng = FarmEngine(restore_loop, lanes=args.lanes, prep=detect)
    done = []
    t0 = time.perf_counter()
    n = eng.run(noisys, done.append)
    dt = time.perf_counter() - t0

    ps_in = np.mean([psnr(noisys[i], cleans[i]) for i in range(n)])
    ps_out = np.mean([psnr(done[i].a, cleans[i]) for i in range(n)])
    its = [int(done[i].iters) for i in range(n)]
    print(f"restored {n} {args.res} frames @ {args.noise:.0%} noise in "
          f"{dt:.2f}s ({n / dt:.2f} fps; {eng.stats['rounds']} rounds "
          f"through {args.lanes} lane slots)")
    print(f"host transfer: {eng.stats['h2d_bytes'] / max(n, 1):.0f} B/item"
          f" in, {eng.stats['d2h_bytes'] / max(n, 1):.0f} B/item out")
    print(f"PSNR {ps_in:.1f} -> {ps_out:.1f} dB; iterations/frame: {its}")


if __name__ == "__main__":
    main()
