"""Quickstart: the Loop-of-stencil-reduce pattern in five minutes.

Runs Conway's Game of Life (the paper's Fig. 1 example) and a Jacobi
solve through the public API, then shows the -d and -s variants and the
streaming farm.  CPU-friendly; finishes in seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (FarmEngine, LoopOfStencilReduce,
                        loop_of_stencil_reduce, loop_of_stencil_reduce_d,
                        loop_of_stencil_reduce_s)


def main():
    rng = np.random.default_rng(0)

    # -- Game of Life: base variant --------------------------------------
    # stencil f = the GoL rule over a 3×3 neighbourhood (taps protocol);
    # reduce ⊕ = sum of alive cells; condition c = extinction.
    def gol(get):
        n = sum(get(di, dj) for di in (-1, 0, 1) for dj in (-1, 0, 1)
                if (di, dj) != (0, 0))
        return jnp.where((n == 3) | ((get(0, 0) > 0) & (n == 2)), 1.0, 0.0)

    world = jnp.asarray(rng.integers(0, 2, (64, 64)), jnp.float32)
    res = loop_of_stencil_reduce(1, gol, "sum", lambda alive: alive <= 0,
                                 world, max_iters=200)
    print(f"[GoL]     ran {int(res.iters)} generations, "
          f"{int(res.reduced)} cells alive")

    # -- Jacobi: -d variant (convergence on the delta) --------------------
    def jacobi(get):
        return 0.25 * (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1))

    u0 = jnp.asarray(rng.normal(size=(96, 96)), jnp.float32)
    res = loop_of_stencil_reduce_d(
        1, jacobi, lambda new, old: jnp.abs(new - old), "max",
        lambda d: d < 1e-4, u0, max_iters=5000)
    print(f"[Jacobi]  converged in {int(res.iters)} iterations "
          f"(max |Δ| = {float(res.reduced):.2e})")

    # -- -s variant: loop state in the condition --------------------------
    res = loop_of_stencil_reduce_s(
        1, jacobi, "sum", lambda r, steps: steps >= 10, u0,
        init=lambda: jnp.asarray(0, jnp.int32),
        update=lambda s, a, it: s + 1)
    print(f"[Jacobi-s] fixed-budget run stopped at {int(res.iters)} steps")

    # -- streaming farm (1:1 mode): items converge independently ----------
    # farm_run drives the whole batch as ONE done-masked while_loop over
    # a stacked (lanes, grid) carry — each lane to its own trip count
    runner = LoopOfStencilReduce(
        f=jacobi, k=1, combine="max", identity=-jnp.inf,
        cond=lambda d: d < 1e-4, delta=lambda n, o: jnp.abs(n - o),
        max_iters=5000)
    batch = jnp.stack([u0, u0 * 5.0, u0 * 0.1])
    out = runner.farm_run(batch)
    print(f"[farm]    per-item trip counts: {out.iters.tolist()}")

    # -- FarmEngine: a whole stream through persistent lane slots ---------
    # backend="pallas" is the point: frames are built once per lane slot
    # and REFILLED in place with each next item — no re-pad, no re-alloc,
    # no host round-trip of the frame (interpret-mode kernels on CPU, so
    # the demo uses a smaller grid + tolerance to stay quick)
    v0 = u0[:48, :48]
    streamer = LoopOfStencilReduce(
        f=jacobi, k=1, combine="max", identity=-jnp.inf,
        cond=lambda d: d < 1e-2, delta=lambda n, o: jnp.abs(n - o),
        max_iters=600, backend="pallas", block=(48, 128))
    eng = FarmEngine(streamer, lanes=2)
    iters = []
    n = eng.run([v0 * s for s in (1.0, 5.0, 0.1, 2.0, 0.5)],
                lambda res: iters.append(int(res.iters)))
    print(f"[stream]  {n} items through 2 persistent lane slots "
          f"({eng.stats['rounds']} rounds, backend=pallas); "
          f"trip counts: {iters}")


if __name__ == "__main__":
    main()
