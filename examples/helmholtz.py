"""Paper §4.1 — Helmholtz equation solver (iterative Jacobi).

Solves (∇² − α)u = −f with the fused Pallas sweep (interpret mode on
CPU) inside one on-device while_loop, then verifies the discrete residual.

    PYTHONPATH=src python examples/helmholtz.py [--size 256] [--pallas]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas kernel (interpret mode on CPU)")
    args = ap.parse_args()

    n = args.size
    dx = 1.0 / n
    rng = np.random.default_rng(0)
    fxy = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    u0 = jnp.zeros((n, n), jnp.float32)

    t0 = time.perf_counter()
    u, delta, iters = ops.jacobi_solve(
        u0, fxy, alpha=args.alpha, dx=dx, tol=args.tol, max_iters=20000,
        use_pallas=args.pallas)
    u.block_until_ready()
    dt = time.perf_counter() - t0

    up = jnp.pad(u, 1)
    neigh = up[:-2, 1:-1] + up[2:, 1:-1] + up[1:-1, :-2] + up[1:-1, 2:]
    res = (4 + args.alpha * dx * dx) * u - neigh - dx * dx * fxy
    print(f"size={n}x{n}  iters={int(iters)}  max|Δ|={float(delta):.2e}  "
          f"residual={float(jnp.abs(res[1:-1, 1:-1]).max()):.2e}  "
          f"wall={dt:.2f}s  backend={'pallas' if args.pallas else 'jnp'}")


if __name__ == "__main__":
    main()
