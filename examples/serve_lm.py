"""Serving example: batched generation with the decode loop as
Loop-of-stencil-reduce-s (KV cache persistent in device memory, on-device
EOS reduce).  Loads a checkpoint from examples/train_lm.py when present.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --reduced

``--continuous`` serves RAGGED prompts through continuous batching
instead (per-sequence KV-slot refill, mid-batch emission): requests with
wildly different prompt lengths AND token budgets stream through ONE
engine binding of ``--batch`` persistent slots (padded per-slot prefill
with a prompt-length mask) and are printed in COMPLETION order.

``--recover-dir <dir>`` arms preemption recovery on the continuous
path (WAL journal + per-segment snapshots, DESIGN.md §Recovery);
``--resume`` restarts a killed serve from that dir — pre-crash results
replay from the journal, in-flight decodes continue mid-generation
(even with a different ``--batch``):

    PYTHONPATH=src python examples/serve_lm.py --continuous \\
        --recover-dir /tmp/serve_rec            # kill it mid-run...
    PYTHONPATH=src python examples/serve_lm.py --continuous \\
        --recover-dir /tmp/serve_rec --resume   # ...finishes the rest
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: per-sequence KV-slot "
                         "refill, results in completion order")
    ap.add_argument("--requests", type=int, default=8,
                    help="request count for --continuous (> --batch "
                         "slots, so slots get reused mid-batch)")
    ap.add_argument("--recover-dir", default=None,
                    help="arm preemption recovery (journal + "
                         "snapshots) on the continuous path")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed --continuous run from "
                         "--recover-dir (replays + continues; submit "
                         "nothing new)")
    args = ap.parse_args()
    if args.resume and not args.recover_dir:
        ap.error("--resume needs --recover-dir")
    if args.resume:
        # the snapshot's token cap sizes the decode buffers — adopt it
        # so the resumed engine binds identically to the killed one
        from repro.resilience import RecoveryConfig
        from repro.resilience.recovery import load_snapshot
        st = load_snapshot(RecoveryConfig(dir=args.recover_dir).snap_dir)
        if st is not None and st.get("kind") == "serve":
            args.max_new = int(st["cap"])

    cfg = get_reduced(args.arch)     # reduced config: CPU-friendly
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size,
                                      (args.batch, args.prompt_len)))
    gcfg = GenerateConfig(max_new_tokens=args.max_new, eos_id=1,
                          temperature=args.temperature, seed=0)

    if args.continuous:
        from repro.serve.batcher import Batcher, Request

        b = Batcher(cfg, params, gcfg, max_batch=args.batch,
                    cache_dtype=jnp.float32)
        budgets = [max(1, (i * 7) % args.max_new + 1)
                   for i in range(args.requests)]
        # ragged prompts: one slot pool serves every length
        plens = [max(2, (args.prompt_len - 3 * i) % args.prompt_len + 1)
                 for i in range(args.requests)]
        if not args.resume:    # a resumed run picks its requests up
            for i, bud in enumerate(budgets):  # from the snapshot
                b.submit(Request(
                    rid=i, max_new_tokens=bud,
                    prompt=np.asarray(rng.integers(
                        2, cfg.vocab_size, plens[i]), np.int32)))
        recovery = None
        if args.recover_dir:
            from repro.resilience import RecoveryConfig
            recovery = RecoveryConfig(dir=args.recover_dir)
        t0 = time.perf_counter()
        results = b.run_continuous(recovery=recovery,
                                   resume=args.resume)
        dt = time.perf_counter() - t0
        eng = b.engines[0]
        total = sum(len(r.tokens) for r in results)
        print(f"[serve_lm] {args.arch} (reduced, continuous): "
              f"{len(results)} ragged requests through {args.batch} KV "
              f"slots (ONE engine binding) in {dt:.2f}s "
              f"({total / dt:.1f} tok/s, "
              f"{eng.stats['segments']} segments, "
              f"{eng.stats['prefills']} slot prefills, "
              f"{eng.stats['idle_slot_steps']} idle slot-steps)")
        if args.resume:
            print(f"[serve_lm] resumed: "
                  f"{eng.stats['replayed_items']} replayed from the "
                  f"journal, {eng.stats['recovered_occupants']} decodes "
                  f"continued mid-generation, recovery took "
                  f"{eng.stats['recovery_seconds']:.3f}s")
        for r in results:           # completion order
            print(f"  rid{r.rid} prompt={plens[r.rid]} "
                  f"budget={budgets[r.rid]} "
                  f"len={len(r.tokens)}: {r.tokens[:8].tolist()}...")
        return

    t0 = time.perf_counter()
    out, lengths, iters = generate(cfg, params, prompt, gcfg,
                                   cache_dtype=jnp.float32)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    total = int(lengths.sum())
    print(f"[serve_lm] {args.arch} (reduced): generated {total} tokens "
          f"over {args.batch} sequences in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {int(iters)} loop steps)")
    for b in range(args.batch):
        print(f"  seq{b} len={int(lengths[b])}: "
              f"{out[b, :min(int(lengths[b]), 12)].tolist()}...")


if __name__ == "__main__":
    main()
