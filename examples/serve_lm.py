"""Serving example: batched generation with the decode loop as
Loop-of-stencil-reduce-s (KV cache persistent in device memory, on-device
EOS reduce).  Loads a checkpoint from examples/train_lm.py when present.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --reduced
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)     # reduced config: CPU-friendly
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size,
                                      (args.batch, args.prompt_len)))
    gcfg = GenerateConfig(max_new_tokens=args.max_new, eos_id=1,
                          temperature=args.temperature, seed=0)

    t0 = time.perf_counter()
    out, lengths, iters = generate(cfg, params, prompt, gcfg,
                                   cache_dtype=jnp.float32)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    total = int(lengths.sum())
    print(f"[serve_lm] {args.arch} (reduced): generated {total} tokens "
          f"over {args.batch} sequences in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {int(iters)} loop steps)")
    for b in range(args.batch):
        print(f"  seq{b} len={int(lengths[b])}: "
              f"{out[b, :min(int(lengths[b]), 12)].tolist()}...")


if __name__ == "__main__":
    main()
