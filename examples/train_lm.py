"""End-to-end training driver: ~100M-parameter LM on the synthetic task.

Demonstrates the full substrate working together: config system → model
zoo → data pipeline → AdamW → Trainer (the Loop-of-stencil-reduce-s
pattern with checkpoint/restart + NaN rollback).

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 40
    PYTHONPATH=src python examples/train_lm.py --steps 300    # ~100M run

Resume: re-running with the same --ckpt-dir picks up at the last step.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import ArchConfig
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.optim import AdamW, cosine_with_warmup
from repro.train import Trainer, TrainConfig

PRESETS = {
    # ~110M params: a qwen3-shaped dense decoder
    "100m": ArchConfig(
        name="demo-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
        vocab_size=32768, qk_norm=True, act="silu", dtype="float32",
        remat=False),
    "tiny": ArchConfig(
        name="demo-tiny", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=2048, act="silu", dtype="float32", remat=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="runs/train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: T.init_params(cfg, k),
                       jax.random.PRNGKey(0))))
    print(f"[train_lm] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=cosine_with_warmup(args.lr, args.steps // 10,
                                      args.steps), weight_decay=0.01)
    trainer = Trainer(cfg, TrainConfig(
        steps=args.steps, accum=args.accum, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=10), opt)
    trainer.install_preemption_handler()
    params, opt_state, info = trainer.run(params,
                                          lambda s: data.batches(s))
    h = info["history"]
    if h:
        print(f"[train_lm] loss {h[0]:.3f} -> {h[-1]:.3f} over "
              f"{info['steps']} steps ({info['faults']} faults)")


if __name__ == "__main__":
    main()
