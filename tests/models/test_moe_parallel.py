"""shard_map expert-parallel MoE ≡ GSPMD-auto dense path (subprocess,
8 placeholder devices) — modulo the documented capacity semantics."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


@pytest.mark.slow
def test_expert_parallel_matches_dense_dispatch():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.models.layers import init_moe, moe
        from repro.models.moe_parallel import expert_parallel_moe

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        E, D, F, topk = 8, 32, 64, 2
        params = init_moe(jax.random.PRNGKey(0), D, E, F, 1, 48, True,
                          jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 16, D)) * 0.3, jnp.float32)

        # generous capacity => no drops on either path => exact match
        y_ref, aux_ref = moe(params, x, top_k=topk, dropless=True)
        with mesh:
            y_ep, aux_ep = jax.jit(lambda p, xx: expert_parallel_moe(
                p, xx, top_k=topk, act="silu", capacity_factor=8.0,
                mesh=mesh, dp_axes=("data",)))(params, x)
        err = np.abs(np.asarray(y_ep) - np.asarray(y_ref)).max()
        assert err < 2e-5, err
        # lb_loss uses per-data-shard statistics (mean of products !=
        # product of means): same expectation, small per-batch skew
        lb = abs(float(aux_ep["lb_loss"]) - float(aux_ref["lb_loss"]))
        assert lb < 0.05, lb
        print("OKMOE")
    """ % SRC)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OKMOE" in out.stdout


@pytest.mark.slow
def test_expert_parallel_batch_one():
    """B=1 (long-context decode) runs token-replicated over data."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.layers import init_moe, moe
        from repro.models.moe_parallel import expert_parallel_moe
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = init_moe(jax.random.PRNGKey(0), 32, 8, 64, 0, 0, True,
                          jnp.float32)
        x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 1, 32)),
                        jnp.float32)
        y_ref, _ = moe(params, x, top_k=2, dropless=True)
        with mesh:
            y_ep, _ = jax.jit(lambda p, xx: expert_parallel_moe(
                p, xx, top_k=2, act="silu", capacity_factor=8.0,
                mesh=mesh, dp_axes=("data",)))(params, x)
        assert np.abs(np.asarray(y_ep) - np.asarray(y_ref)).max() < 2e-5
        print("OKB1")
    """ % SRC)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OKB1" in out.stdout
