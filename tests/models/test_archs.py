"""Per-arch smoke tests (assignment deliverable f): reduced config, one
forward + one train step on CPU, asserting shapes and no NaNs; plus
decode≡forward consistency and SSD oracle checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_reduced
from repro.models import transformer as T
from repro.optim import AdamW
from repro.train.objective import grad_accum_step, lm_loss

B, S = 2, 32


def make_batch(cfg, rng, with_labels=True):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.vision_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_patches, cfg.vision_embed_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch, rng):
        cfg = get_reduced(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0), max_position=64)
        logits, aux = jax.jit(
            lambda p, b: T.forward(cfg, p, b))(params,
                                               make_batch(cfg, rng, False))
        exp_s = S + (cfg.vision_patches or 0)
        assert logits.shape == (B, exp_s, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any())

    def test_one_train_step_no_nan(self, arch, rng):
        cfg = get_reduced(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0), max_position=64)
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        batch = make_batch(cfg, rng)
        grads, loss, metrics = grad_accum_step(cfg, params, batch, accum=2)
        params, opt_state, stats = opt.update(grads, opt_state, params)
        assert np.isfinite(float(loss))
        assert np.isfinite(float(stats["grad_norm"]))
        # loss is a plausible CE for a |V|-way guess
        assert 0.0 < float(loss) < 2 * np.log(cfg.padded_vocab) + 10


@pytest.mark.parametrize("arch", ["gemma2-9b", "deepseek-moe-16b",
                                  "mamba2-130m", "jamba-v0.1-52b",
                                  "whisper-base", "phi-3-vision-4.2b"])
def test_decode_matches_forward(arch, rng):
    """prefill+decode ≡ teacher-forced forward (cache correctness)."""
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), max_position=64)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)))
    batch = {"tokens": tokens}
    enc_out = cross = patch = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.normal(size=(B, cfg.encoder_seq,
                                               cfg.d_model)), jnp.float32)
        batch["frames"] = frames
        enc_out = T.encode(cfg, params, frames)
        cross = T.prefill_cross_caches(cfg, params, enc_out)
    if cfg.vision_patches:
        patch = jnp.asarray(rng.normal(
            size=(B, cfg.vision_patches, cfg.vision_embed_dim)),
            jnp.float32)
        batch["patch_embeds"] = patch
    full, _ = T.forward(cfg, params, batch)

    P = cfg.vision_patches or 0
    caches = T.init_cache(cfg, B, max_seq=16 + P, dtype=jnp.float32)
    lg, caches = T.step_with_cache(cfg, params, caches, tokens[:, :8], 0,
                                   patch_embeds=patch, enc_out=enc_out,
                                   cross_caches=cross)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, :8 + P]), atol=2e-3)
    for t in range(8, 16):
        lg, caches = T.decode_step(cfg, params, caches, tokens[:, t:t + 1],
                                   P + t, enc_out=enc_out,
                                   cross_caches=cross)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, P + t]), atol=2e-3)


def test_exact_configs_match_assignment():
    """The full configs carry the assigned hyperparameters exactly."""
    spec = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }
    for arch, (L, D, H, KH, F, V) in spec.items():
        c = get_config(arch)
        got_f = c.expert_d_ff if arch == "deepseek-moe-16b" else (
            c.expert_d_ff if arch == "qwen3-moe-30b-a3b" else c.d_ff)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                got_f, c.vocab_size) == (L, D, H, KH, F, V), arch


def test_moe_extras():
    ds = get_config("deepseek-moe-16b")
    assert (ds.n_experts, ds.top_k, ds.n_shared_experts) == (64, 6, 2)
    qw = get_config("qwen3-moe-30b-a3b")
    assert (qw.n_experts, qw.top_k) == (128, 8)
    jb = get_config("jamba-v0.1-52b")
    assert (jb.n_experts, jb.top_k, jb.attn_period, jb.attn_offset) \
        == (16, 2, 8, 4)
    mb = get_config("mamba2-130m")
    assert mb.ssm_state == 128


def test_ssd_chunked_vs_sequential_oracle(rng):
    from repro.models import ssm
    dims = ssm.ssm_dims(d_model=48, expand=2, head_dim=16, state=8)
    Bt, S_ = 2, 300          # non-multiple of chunk: exercises padding
    nh, hd, n = dims["nheads"], dims["head_dim"], dims["state"]
    x = jnp.asarray(rng.normal(size=(Bt, S_, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bt, S_, nh)), jnp.float32)
    A = jnp.asarray(np.log(rng.uniform(1, 8, nh)), jnp.float32)
    Bv = jnp.asarray(rng.normal(size=(Bt, S_, 1, n)), jnp.float32)
    Cv = jnp.asarray(rng.normal(size=(Bt, S_, 1, n)), jnp.float32)
    Dv = jnp.asarray(rng.normal(size=(nh,)), jnp.float32)
    yc, hc = ssm.ssd_chunked(x, dt, A, Bv, Cv, Dv, dims=dims)
    yr, hr = ssm.ssd_ref(x, dt, A, Bv, Cv, Dv, dims=dims)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=3e-4)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=3e-4)


class TestRingCache:
    """Sliding-window ring-buffer KV cache (window < max_seq)."""

    def test_ring_engaged_for_local_layers(self):
        cfg = get_reduced("gemma2-9b")
        c = T.init_cache(cfg, 2, max_seq=16, dtype=jnp.float32)
        local, global_ = c["unit"][0], c["unit"][1]
        assert local["k"].shape[2] == cfg.sliding_window
        assert "pos" in local and "pos" not in global_
        assert global_["k"].shape[2] == 16

    def test_decode_past_window_stays_exact(self, rng):
        """Decoding far beyond the window wraps the ring repeatedly and
        must still match the teacher-forced forward."""
        cfg = get_reduced("gemma2-9b")           # window = 8
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        S = 40                                    # 5 ring revolutions
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)))
        full, _ = T.forward(cfg, params, {"tokens": tokens})
        caches = T.init_cache(cfg, 2, max_seq=S, dtype=jnp.float32)
        lg, caches = T.step_with_cache(cfg, params, caches,
                                       tokens[:, :4], 0)
        for t in range(4, S):
            lg, caches = T.decode_step(cfg, params, caches,
                                       tokens[:, t:t + 1], t)
            np.testing.assert_allclose(
                np.asarray(lg[:, 0]), np.asarray(full[:, t]), atol=2e-3)

    def test_prefill_longer_than_window(self, rng):
        """Prefill S > W keeps only the last W keys — decode continues
        correctly from a wrapped ring."""
        cfg = get_reduced("gemma2-9b")
        params = T.init_params(cfg, jax.random.PRNGKey(1))
        S = 24
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)))
        full, _ = T.forward(cfg, params, {"tokens": tokens})
        caches = T.init_cache(cfg, 2, max_seq=S, dtype=jnp.float32)
        lg, caches = T.step_with_cache(cfg, params, caches,
                                       tokens[:, :20], 0)  # 20 > W=8
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, 19]), atol=2e-3)
        for t in range(20, S):
            lg, caches = T.decode_step(cfg, params, caches,
                                       tokens[:, t:t + 1], t)
            np.testing.assert_allclose(
                np.asarray(lg[:, 0]), np.asarray(full[:, t]), atol=2e-3)


class TestInt8KVCache:
    """int8-quantised KV cache: ≈2× cache bytes for bounded logit error."""

    def test_decode_tracks_forward_within_quant_tolerance(self, rng):
        cfg = get_reduced("yi-9b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        S = 24
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)))
        full, _ = T.forward(cfg, params, {"tokens": tokens})
        caches = T.init_cache(cfg, 2, max_seq=S, dtype=jnp.float32,
                              quant=True)
        assert caches["unit"][0]["k"].dtype == jnp.int8
        lg, caches = T.step_with_cache(cfg, params, caches,
                                       tokens[:, :8], 0)
        errs = [float(jnp.abs(lg - full[:, :8]).max())]
        corr = []
        for t in range(8, S):
            lg, caches = T.decode_step(cfg, params, caches,
                                       tokens[:, t:t + 1], t)
            errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
            corr.append(float(np.corrcoef(
                np.asarray(lg[:, 0]).ravel(),
                np.asarray(full[:, t]).ravel())[0, 1]))
        # bounded absolute logit error, near-perfect logit agreement
        # (random-weight logits cluster within ~0.1, so argmax identity
        # is not a meaningful criterion here; real checkpoints separate
        # the top tokens by >> the quantisation error)
        assert max(errs) < 0.15, max(errs)
        assert min(corr) > 0.995, min(corr)

    def test_quant_roundtrip_error_bounded(self, rng):
        from repro.models.attention import _dequantize_kv, _quantize_kv
        x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)), jnp.float32)
        q, s = _quantize_kv(x)
        back = _dequantize_kv(q, s, jnp.float32)
        rel = float(jnp.abs(back - x).max()
                    / (jnp.abs(x).max() + 1e-9))
        assert rel < 0.01                     # ≤ half a quant step
