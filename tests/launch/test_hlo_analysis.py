"""HLO analyzer: validated against XLA cost_analysis on loop-free modules
and against analytic FLOPs with while-loop trip multipliers."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as HA

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def test_dot_flops_loop_free_matches_xla():
    def f(x, w):
        return jax.nn.relu(x @ w) @ w
    x = jnp.zeros((64, 256), jnp.float32)
    w = jnp.zeros((256, 256), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    costs = HA.analyze(comp.as_text(), n_partitions=1)
    ca = comp.cost_analysis()
    if isinstance(ca, list):        # jax 0.4.x: one dict per device
        ca = ca[0]
    want = ca["flops"]
    np.testing.assert_allclose(costs.flops, want, rtol=0.05)


def test_scan_trip_count_multiplies_flops():
    def f(x, ws):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jnp.zeros((32, 128), jnp.float32)
    for n in (3, 9):
        ws = jnp.zeros((n, 128, 128), jnp.float32)
        comp = jax.jit(f).lower(x, ws).compile()
        costs = HA.analyze(comp.as_text(), n_partitions=1)
        analytic = 2 * 32 * 128 * 128 * n
        np.testing.assert_allclose(costs.flops, analytic, rtol=0.05)
        assert n in costs.trip_counts.values()


def test_nested_scans_multiply():
    def f(x, ws):
        def outer(c, _):
            def inner(ci, wi):
                return ci @ wi, None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    x = jnp.zeros((16, 64), jnp.float32)
    ws = jnp.zeros((5, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    costs = HA.analyze(comp.as_text(), n_partitions=1)
    analytic = 2 * 16 * 64 * 64 * 5 * 4
    np.testing.assert_allclose(costs.flops, analytic, rtol=0.05)


def test_dus_in_scan_counts_slice_not_buffer():
    """Scan ys writes must cost O(slice), not O(full stacked output)."""
    def f(x):
        def body(c, _):
            c = c + 1.0
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=50)
        return ys
    x = jnp.zeros((128, 256), jnp.float32)        # slice = 128KB
    comp = jax.jit(f).lower(x).compile()
    costs = HA.analyze(comp.as_text(), n_partitions=1)
    slice_b = 128 * 256 * 4
    # naive full-buffer counting would be ≥ 50 · (50·slice); correct
    # accounting stays within a few slices per iteration
    assert costs.bytes_accessed < 50 * 10 * slice_b


def test_collective_wire_bytes_spmd():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, sys
        from jax.sharding import PartitionSpec as P, NamedSharding
        sys.path.insert(0, %r)
        from repro.launch import hlo_analysis as HA
        from repro.sharding.specs import make_mesh
        mesh = make_mesh((8,), ("model",))
        sx = NamedSharding(mesh, P(None, "model"))
        sw = NamedSharding(mesh, P("model", None))
        def f(x, w):
            return x @ w
        x = jax.ShapeDtypeStruct((32, 256), jnp.float32, sharding=sx)
        w = jax.ShapeDtypeStruct((256, 64), jnp.float32, sharding=sw)
        comp = jax.jit(f, in_shardings=(sx, sw),
                       out_shardings=NamedSharding(mesh, P())).lower(
                           x, w).compile()
        c = HA.analyze(comp.as_text(), n_partitions=8)
        # contracting-dim sharded matmul => one all-reduce of the
        # (32,64) f32 output: ring wire = 2*8192*7/8 per device
        want = 2 * 32 * 64 * 4 * 7 / 8
        assert abs(c.per_collective.get("all-reduce", 0) - want) / want \\
            < 0.05, c.per_collective
        print("OKCOLL")
    """ % SRC)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OKCOLL" in out.stdout
