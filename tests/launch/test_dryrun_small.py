"""Dry-run machinery on a small 8-device mesh (subprocess): proves the
cell builders + shardings lower and compile for representative cells
without paying the 512-device cost in CI."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_small_dryrun(arch: str, shape: str) -> dict:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, %r)
        import jax
        import repro.launch.mesh as M
        from repro.sharding.specs import make_mesh
        # shrink the production mesh for the CI-sized check
        M.make_production_mesh = lambda multi_pod=False, **kw: make_mesh(
            (2, 2, 2) if multi_pod else (4, 2),
            ("pod", "data", "model") if multi_pod else ("data", "model"))
        import dataclasses
        import repro.configs as CFG
        from repro.configs.base import _REGISTRY
        cfg = CFG.get_reduced(%r)
        cfg = dataclasses.replace(cfg, dtype="bfloat16", remat=True,
                                  moe_dropless=False)
        _REGISTRY[cfg.name] = lambda: cfg
        from repro.launch import dryrun
        import repro.launch.cells as C
        C.SHAPES = {
            "train_4k": C.ShapeCell("train_4k", "train", 128, 16),
            "prefill_32k": C.ShapeCell("prefill_32k", "prefill", 256, 8),
            "decode_32k": C.ShapeCell("decode_32k", "decode", 256, 8),
            "long_500k": C.ShapeCell("long_500k", "decode", 512, 1),
        }
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            rec = dryrun.run_cell(cfg.name, %r, "pod", d, verbose=False)
            rec2 = dryrun.run_cell(cfg.name, %r, "multipod", d,
                                   verbose=False)
        print(json.dumps({"pod": rec.get("ok"), "err": rec.get("error"),
                          "multipod": rec2.get("ok"),
                          "err2": rec2.get("error")}))
    """ % (SRC, arch, shape, shape))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen3-1.7b", "train_4k"),
    ("deepseek-moe-16b", "train_4k"),
    ("mamba2-130m", "decode_32k"),
    ("jamba-v0.1-52b", "long_500k"),
    ("whisper-base", "prefill_32k"),
])
def test_cell_lowers_on_small_mesh(arch, shape):
    res = run_small_dryrun(arch, shape)
    assert res["pod"], res.get("err")
    assert res["multipod"], res.get("err2")
