"""Sharding policy unit tests: divisibility-aware spec rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.sharding import specs as SH


from repro.sharding.specs import make_abstract_mesh


@pytest.fixture(scope="module")
def mesh():
    # spec rules only read mesh.shape / axis_names — a 1-device mesh with
    # logical sizes is enough for unit tests? No: sizes matter. Use the
    # abstract mesh API instead.
    return make_abstract_mesh((16, 16), ("data", "model"))


class TestParamSpecRules:
    def test_embedding_shards_vocab(self, mesh):
        cfg = get_config("gemma2-9b")
        spec = SH.param_spec(cfg, "embed", (256000, 3584), mesh)
        assert spec[0] == "model" and spec[1] is None

    def test_gqa_divisible_heads(self, mesh):
        cfg = get_config("yi-9b")                 # 32H, kv=4
        wq = SH.param_spec(cfg, "unit/0/attn/wq", (48, 4096, 32, 128),
                           mesh)
        assert wq[2] == "model"                   # heads sharded
        wk = SH.param_spec(cfg, "unit/0/attn/wk", (48, 4096, 4, 128),
                           mesh)
        assert all(s is None for s in wk)         # kv<tp: replicated

    def test_context_parallel_replicates_attention(self, mesh):
        cfg = get_config("phi3-medium-14b")       # 40H: seq-parallel
        assert cfg.attn_sequence_parallel
        wq = SH.param_spec(cfg, "unit/0/attn/wq", (40, 5120, 40, 128),
                           mesh)
        assert all(s is None for s in wq)

    def test_experts_shard_on_model(self, mesh):
        cfg = get_config("qwen3-moe-30b-a3b")
        w = SH.param_spec(cfg, "unit/0/moe/w_up", (48, 128, 2048, 768),
                          mesh)
        assert w[1] == "model"

    def test_mlp_column_row(self, mesh):
        cfg = get_config("yi-9b")
        up = SH.param_spec(cfg, "unit/0/mlp/up", (48, 4096, 11008), mesh)
        down = SH.param_spec(cfg, "unit/0/mlp/down", (48, 11008, 4096),
                             mesh)
        assert up[2] == "model" and down[1] == "model"

    def test_norms_replicated(self, mesh):
        cfg = get_config("yi-9b")
        ln = SH.param_spec(cfg, "unit/0/ln1", (48, 4096), mesh)
        assert all(s is None for s in ln)


class TestZero1:
    def test_adds_data_axis_on_free_dim(self, mesh):
        from jax.sharding import PartitionSpec as P
        spec = SH.zero1_spec(P(None, "model"), (4096, 11008), mesh)
        assert spec[0] == "data"                  # 4096 % 16 == 0

    def test_skips_when_nothing_divides(self, mesh):
        from jax.sharding import PartitionSpec as P
        spec = SH.zero1_spec(P(), (7,), mesh)
        assert all(s is None for s in spec)


class TestBatchSpec:
    def test_composes_pod_and_data(self):
        m = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
        spec = SH.batch_spec(m, 256)
        assert spec[0] == ("pod", "data")

    def test_batch_one_unsharded(self):
        m = make_abstract_mesh((16, 16), ("data", "model"))
        spec = SH.batch_spec(m, 1)
        assert spec[0] is None
