"""End-to-end behaviour tests for the paper's system: the three paper
applications run through the Loop-of-stencil-reduce machinery and produce
physically sensible results (paper §4 structure)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LoopOfStencilReduce, GridPartition, farm, pipe,
                        StreamRunner, loop_of_stencil_reduce_d)
from repro.kernels import ops, ref as R


class TestHelmholtzApp:
    def test_converges_to_fixed_point(self, rng):
        """Jacobi fixed point satisfies the discrete Helmholtz relation."""
        n, alpha, dx = 48, 0.8, 0.1
        fxy = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
        u, delta, iters = ops.jacobi_solve(
            jnp.zeros((n, n), jnp.float32), fxy, alpha=alpha, dx=dx,
            tol=1e-6, max_iters=4000)
        # residual of (4+αdx²)u - Σneigh u - dx² f ≈ 0 at interior points
        up = jnp.pad(u, 1)
        neigh = (up[:-2, 1:-1] + up[2:, 1:-1] + up[1:-1, :-2]
                 + up[1:-1, 2:])
        res = (4 + alpha * dx * dx) * u - neigh - dx * dx * fxy
        assert float(jnp.abs(res[1:-1, 1:-1]).max()) < 1e-3
        assert int(iters) < 4000


class TestSobelApp:
    def test_stream_of_images(self, rng):
        """pipe(read, sobel, write) over a stream (paper §4.2)."""
        import jax
        frames = [jnp.asarray(rng.uniform(size=(32, 64)), jnp.float32)
                  for _ in range(7)]
        outs = []
        worker = jax.jit(jax.vmap(lambda im: ops.sobel(im)[0]))
        n = StreamRunner(worker=worker,
                         source=lambda: iter(frames),
                         sink=lambda o: outs.append(o), batch=3).run()
        assert n == 7
        want, _ = ops.sobel(frames[0])
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want),
                                   atol=1e-5)

    def test_edge_detector_finds_edges(self):
        img = np.zeros((40, 80), np.float32)
        img[:, 40:] = 1.0                      # vertical edge
        out, _ = ops.sobel(jnp.asarray(img))
        col_resp = np.asarray(out).mean(axis=0)
        assert col_resp[39:41].max() > 10 * (col_resp[:30].mean() + 1e-6)


class TestRestorationApp:
    def test_two_phase_pipeline(self, rng):
        """pipe(read, detect, ofarm(restore), write) (paper §4.3)."""
        yy, xx = np.mgrid[0:48, 0:64]
        frame = np.clip(0.5 + 0.4 * np.sin(xx / 9.0) * np.cos(yy / 7.0),
                        0, 1).astype(np.float32)
        imp = rng.uniform(size=frame.shape) < 0.3
        sp = np.where(rng.uniform(size=frame.shape) < 0.5, 0.0, 1.0)
        noisy = jnp.asarray(np.where(imp, sp, frame), jnp.float32)

        def detect(x):
            mask, repaired = ops.adaptive_median_detect(x)
            return repaired, mask

        def restore(args):
            u0, mask = args
            out, d, it = ops.restore(u0, mask, max_iters=50)
            return out
        restored = pipe(detect, restore)(noisy)

        def psnr(x):
            return -10 * np.log10(np.mean((np.asarray(x) - frame) ** 2)
                                  + 1e-12)
        assert psnr(restored) > psnr(noisy) + 8.0


class TestGameOfLife:
    def test_blinker_oscillates(self):
        """The paper's Fig. 1 example, through the core pattern."""
        a0 = np.zeros((8, 8), np.float32)
        a0[4, 3:6] = 1.0                      # horizontal blinker
        res = LoopOfStencilReduce(
            f=R.gol_taps(), k=1, combine="sum", identity=0.0,
            cond=lambda r: False, max_iters=2).run(jnp.asarray(a0))
        want = np.zeros((8, 8), np.float32)
        want[4, 3:6] = 1.0                    # period-2: back to start
        np.testing.assert_array_equal(np.asarray(res.a), want)
