"""Trainer integration: learning on the synthetic task, checkpoint
resume, NaN-fault rollback, fused on-device segments, elastic restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.optim import AdamW, cosine_with_warmup
from repro.train import Trainer, TrainConfig, checkpoint as C


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen3-1.7b")
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                       global_batch=8, seed=1)
    return cfg, data


class TestLearning:
    def test_loss_decreases(self, setup):
        cfg, data = setup
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=cosine_with_warmup(3e-3, 10, 60), weight_decay=0.01)
        tr = Trainer(cfg, TrainConfig(steps=60, log_every=1000), opt)
        _, _, info = tr.run(params, lambda s: data.batches(s),
                            log=lambda *a: None)
        h = info["history"]
        assert h[-1] < h[0] - 0.5, (h[0], h[-1])

    def test_grad_accum_invariance(self, setup):
        """accum=1 and accum=4 compute (nearly) the same gradients."""
        from repro.train.objective import grad_accum_step
        cfg, data = setup
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, data.batch_at(0))
        g1, l1, _ = grad_accum_step(cfg, params, batch, accum=1)
        g4, l4, _ = grad_accum_step(cfg, params, batch, accum=4)
        np.testing.assert_allclose(float(l1), float(l4), rtol=1e-4)
        flat1 = jax.tree.leaves(g1)
        flat4 = jax.tree.leaves(g4)
        for a, b in zip(flat1, flat4):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-3)


class TestCheckpoint:
    def test_roundtrip_and_resume(self, setup):
        cfg, data = setup
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(cfg, TrainConfig(steps=12, ckpt_dir=d,
                                          ckpt_every=5, log_every=100), opt)
            p1, o1, info1 = tr.run(params, lambda s: data.batches(s),
                                   log=lambda *a: None)
            assert C.latest_step(d) == 12
            # a fresh trainer resumes at 12 and continues to 15
            tr2 = Trainer(cfg, TrainConfig(steps=15, ckpt_dir=d,
                                           ckpt_every=100, log_every=100),
                          opt)
            fresh = T.init_params(cfg, jax.random.PRNGKey(7))
            _, _, info2 = tr2.run(fresh, lambda s: data.batches(s),
                                  log=lambda *a: None)
            assert info2["steps"] == 15

    def test_bf16_leaves_roundtrip(self):
        tree = {"a": jnp.ones((4, 3), jnp.bfloat16) * 1.5,
                "b": {"c": jnp.arange(5, dtype=jnp.int32)},
                "s": jnp.asarray(3, jnp.int32)}
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 3, tree)
            got, step, _ = C.restore(d, tree)
            assert step == 3
            assert got["a"].dtype == jnp.bfloat16
            np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                          np.asarray(tree["a"], np.float32))

    def test_atomicity_retention(self):
        tree = {"x": jnp.ones((2,))}
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4, 5):
                C.save(d, s, tree, keep=2)
            steps = sorted(os.listdir(d))
            assert steps == ["step_0000000004", "step_0000000005"]


class TestFaultTolerance:
    def test_nan_rollback_and_batch_skip(self, setup):
        cfg, data = setup
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)

        poisoned = {0: False}

        def batches(start):
            for b in data.batches(start):
                step = poisoned["n"] = poisoned.get("n", start) + 1
                if step == 8 and not poisoned[0]:
                    poisoned[0] = True
                    b = dict(b)
                    b["tokens"] = b["tokens"] * 0 + cfg.padded_vocab - 1
                    # poisoned batch alone isn't NaN; force one via loss:
                yield b

        # instead of indirect poisoning, inject NaN through params once:
        class NanOnce(Trainer):
            count = 0

            def __init__(self, *a, **k):
                super().__init__(*a, **k)
                inner = self.train_step

                def wrapped(p, o, b):
                    NanOnce.count += 1
                    p2, o2, m = inner(p, o, b)
                    if NanOnce.count == 6:
                        m = dict(m)
                        m["total_loss"] = jnp.asarray(jnp.nan)
                    return p2, o2, m
                self.train_step = wrapped

        with tempfile.TemporaryDirectory() as d:
            tr = NanOnce(cfg, TrainConfig(steps=10, ckpt_dir=d,
                                          ckpt_every=4, log_every=100),
                         opt)
            _, _, info = tr.run(params, lambda s: data.batches(s),
                                log=lambda *a: None)
            assert info["faults"] == 1
            assert info["steps"] == 10           # completed despite fault
            assert all(np.isfinite(info["history"]))


class TestFusedSegment:
    def test_k_steps_on_device(self, setup):
        cfg, data = setup
        params = T.init_params(cfg, jax.random.PRNGKey(2))
        opt = AdamW(lr=1e-3)
        tr = Trainer(cfg, TrainConfig(steps=4), opt)
        stk = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[data.batch_at(i) for i in range(4)])
        p, o, last_loss, iters = tr.run_fused(params, opt.init(params), stk)
        assert int(iters) == 4
        assert np.isfinite(float(last_loss))
