"""int8 + error-feedback gradient compression (pod-axis reduction)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.train.compression import quantize_int8

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


class TestQuantize:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    def test_error_bounded_by_half_step(self, seed, peers):
        x = jnp.asarray(np.random.default_rng(seed)
                        .normal(size=(64,)).astype(np.float32))
        q, scale = quantize_int8(x, peers)
        err = np.abs(np.asarray(q, np.float32) * float(scale)
                     - np.asarray(x))
        assert err.max() <= float(scale) * 0.5 + 1e-6
        assert q.dtype == jnp.int8

    def test_overflow_safe_for_n_peers(self):
        x = jnp.full((8,), 123.0)
        q, _ = quantize_int8(x, 2)
        assert int(np.abs(np.asarray(q)).max()) <= 63   # 127 // 2


@pytest.mark.slow
def test_ef_psum_unbiased_over_steps():
    """Across repeated steps, error feedback recovers the exact mean:
    cumulative compressed sum → cumulative true sum."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P, AxisType
        from repro.train.compression import ef_int8_psum
        mesh = jax.make_mesh((2,), ("pod",), axis_types=(AxisType.Auto,))
        rng = np.random.default_rng(0)
        gs = jnp.asarray(rng.normal(size=(2, 20, 256)).astype(np.float32))

        def run(gs_local):
            gs_local = gs_local[0]      # shard_map keeps a size-1 lead dim
            def body(err, g):
                s, err = ef_int8_psum(g, err, "pod")
                return err, s
            err0 = jnp.zeros((256,), jnp.float32)
            _, sums = jax.lax.scan(body, err0, gs_local)
            return sums
        f = jax.shard_map(run, mesh=mesh, in_specs=P("pod", None, None),
                          out_specs=P(None, None), check_vma=False)
        sums = f(gs)                      # (20, 256) compressed psums
        true = gs.sum(axis=0)             # (20, 256) exact per-step sums
        cum_c = np.cumsum(np.asarray(sums), axis=0)
        cum_t = np.cumsum(np.asarray(true), axis=0)
        # error feedback: cumulative drift stays bounded by ~one quant
        # step, so the RELATIVE error shrinks with the horizon
        rel = np.abs(cum_c[-1] - cum_t[-1]).max() / (
            np.abs(cum_t[-1]).max() + 1e-9)
        assert rel < 0.02, rel
        # and per-step compressed sums track the truth coarsely
        assert np.corrcoef(cum_c[-1], cum_t[-1])[0, 1] > 0.999
        print("OKEF")
    """ % SRC)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OKEF" in out.stdout
