"""Serving batcher: bucketing, ragged prompts, result integrity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig
from repro.serve.batcher import Batcher, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_ragged_prompts_batched_and_answered(served, rng):
    cfg, params = served
    gcfg = GenerateConfig(max_new_tokens=6, eos_id=1, temperature=0.0)
    b = Batcher(cfg, params, gcfg, max_batch=4)
    lens = [5, 8, 7, 12, 16, 3]
    for i, L in enumerate(lens):
        b.submit(Request(rid=i, prompt=np.asarray(
            rng.integers(2, cfg.vocab_size, L), np.int32)))
    results = b.run_all()
    assert sorted(r.rid for r in results) == list(range(6))
    for r in results:
        assert 1 <= len(r.tokens) <= 6

def test_round_mode_honors_request_budgets(served, rng):
    """Regression: round-mode `run_all` silently ignored
    `Request.max_new_tokens` (only the continuous path honored it) —
    per-request budgets must ride the done-mask in BOTH paths and yield
    identical tokens (round-vs-continuous budget parity)."""
    cfg, params = served
    gcfg = GenerateConfig(max_new_tokens=9, eos_id=1, temperature=0.0)
    budgets = [2, 9, 4, 1, 6]
    prompts = [np.asarray(rng.integers(2, cfg.vocab_size, 6), np.int32)
               for _ in budgets]

    def mk():
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        for i, (p, bud) in enumerate(zip(prompts, budgets)):
            b.submit(Request(rid=i, prompt=p, max_new_tokens=bud))
        return b

    round_res = {r.rid: r.tokens for r in mk().run_all()}
    for rid, toks in round_res.items():
        assert len(toks) <= budgets[rid], (rid, len(toks))
    cont_res = {r.rid: r.tokens for r in mk().run_continuous()}
    for rid in round_res:
        np.testing.assert_array_equal(round_res[rid], cont_res[rid])

    with pytest.raises(ValueError, match="budget"):
        b = Batcher(cfg, params, gcfg, max_batch=2)
        b.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=99))
        b.run_all()


class _CountingArray:
    """Stands in for a device-resident array handed to `_drain`: counts
    whole-array pulls and REFUSES element indexing (the regression —
    `int(lengths[i])` in a Python loop is one blocking transfer per
    request)."""

    def __init__(self, arr):
        self._arr = np.asarray(arr)
        self.pulls = 0

    def __array__(self, dtype=None, copy=None):
        self.pulls += 1
        return self._arr if dtype is None else self._arr.astype(dtype)

    def __getitem__(self, i):
        raise AssertionError(
            "per-element device indexing in _drain — one blocking "
            "transfer per request breaks the one-pull-per-batch "
            "contract")


def test_drain_pulls_each_batch_array_once(served, rng):
    """The double-buffered drain must keep the one-transfer-per-batch
    contract: ONE whole-array pull for the tokens and ONE for the
    lengths, never a per-request element pull."""
    cfg, params = served
    gcfg = GenerateConfig(max_new_tokens=4, eos_id=1, temperature=0.0)
    b = Batcher(cfg, params, gcfg, max_batch=3)
    batch = [Request(rid=i, prompt=np.asarray(
        rng.integers(2, cfg.vocab_size, 5), np.int32)) for i in range(3)]
    gen = np.asarray(rng.integers(2, cfg.vocab_size, (3, 4)), np.int32)
    lengths = _CountingArray(np.asarray([2, 4, 1], np.int32))
    out = []
    b._drain((batch, gen, lengths), out)
    assert lengths.pulls == 1, lengths.pulls
    assert [len(r.tokens) for r in out] == [2, 4, 1]


def test_batched_equals_solo_greedy(served, rng):
    """A request's greedy continuation is the same whether it is served
    alone or inside a batch."""
    cfg, params = served
    gcfg = GenerateConfig(max_new_tokens=5, eos_id=1, temperature=0.0)
    prompt = np.asarray(rng.integers(2, cfg.vocab_size, 8), np.int32)

    solo = Batcher(cfg, params, gcfg, max_batch=1)
    solo.submit(Request(rid=0, prompt=prompt))
    r_solo = solo.run_all()[0]

    multi = Batcher(cfg, params, gcfg, max_batch=3)
    for i in range(3):
        multi.submit(Request(
            rid=i, prompt=prompt if i == 1 else np.asarray(
                rng.integers(2, cfg.vocab_size, 8), np.int32)))
    r_multi = [r for r in multi.run_all() if r.rid == 1][0]
    np.testing.assert_array_equal(r_solo.tokens, r_multi.tokens)
