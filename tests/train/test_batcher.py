"""Serving batcher: bucketing, ragged prompts, result integrity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig
from repro.serve.batcher import Batcher, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_ragged_prompts_batched_and_answered(served, rng):
    cfg, params = served
    gcfg = GenerateConfig(max_new_tokens=6, eos_id=1, temperature=0.0)
    b = Batcher(cfg, params, gcfg, max_batch=4)
    lens = [5, 8, 7, 12, 16, 3]
    for i, L in enumerate(lens):
        b.submit(Request(rid=i, prompt=np.asarray(
            rng.integers(2, cfg.vocab_size, L), np.int32)))
    results = b.run_all()
    assert sorted(r.rid for r in results) == list(range(6))
    for r in results:
        assert 1 <= len(r.tokens) <= 6

def test_batched_equals_solo_greedy(served, rng):
    """A request's greedy continuation is the same whether it is served
    alone or inside a batch."""
    cfg, params = served
    gcfg = GenerateConfig(max_new_tokens=5, eos_id=1, temperature=0.0)
    prompt = np.asarray(rng.integers(2, cfg.vocab_size, 8), np.int32)

    solo = Batcher(cfg, params, gcfg, max_batch=1)
    solo.submit(Request(rid=0, prompt=prompt))
    r_solo = solo.run_all()[0]

    multi = Batcher(cfg, params, gcfg, max_batch=3)
    for i in range(3):
        multi.submit(Request(
            rid=i, prompt=prompt if i == 1 else np.asarray(
                rng.integers(2, cfg.vocab_size, 8), np.int32)))
    r_multi = [r for r in multi.run_all() if r.rid == 1][0]
    np.testing.assert_array_equal(r_solo.tokens, r_multi.tokens)
