"""Serving engine: the decode loop (-s variant) — greedy consistency,
EOS handling, per-sequence trip counts — and continuous batching
(per-sequence KV-slot refill, mid-batch emission)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import ContinuousEngine, GenerateConfig, generate
from repro.serve.batcher import Batcher, Request


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-130m",
                                  "jamba-v0.1-52b"])
def test_greedy_equals_teacher_forced_argmax(arch, rng):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (3, 8)))
    gcfg = GenerateConfig(max_new_tokens=10, eos_id=1, temperature=0.0)
    out, lengths, iters = generate(cfg, params, prompt, gcfg,
                                   cache_dtype=jnp.float32)
    full = jnp.concatenate([prompt, out], axis=1)
    logits, _ = T.forward(cfg, params, {"tokens": full})
    exp = jnp.argmax(logits[:, 7:-1], axis=-1)
    for b in range(3):
        L = int(lengths[b])
        assert (np.asarray(out[b, :L]) == np.asarray(exp[b, :L])).all()


def test_eos_stops_all_lanes_early(rng):
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 4)))
    # pick eos = the actually-argmaxed first token so it stops instantly
    gcfg0 = GenerateConfig(max_new_tokens=4, eos_id=1)
    out, _, _ = generate(cfg, params, prompt, gcfg0,
                         cache_dtype=jnp.float32)
    eos = int(out[0, 0])
    gcfg = GenerateConfig(max_new_tokens=16, eos_id=eos)
    out2, lengths, iters = generate(cfg, params, prompt, gcfg,
                                    cache_dtype=jnp.float32)
    assert int(lengths[0]) == 1
    # post-EOS positions are padded with eos
    assert (np.asarray(out2[0, 1:]) == eos).all()


class TestContinuousBatching:
    """Per-sequence slot refill: short sequences are emitted before long
    ones finish, KV slots are reused mid-batch, and the whole stream
    compiles ONCE per entry point."""

    @pytest.fixture(scope="class")
    def served(self):
        cfg = get_reduced("qwen3-1.7b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_mid_batch_emission_and_slot_reuse(self, served, rng):
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=12, eos_id=1,
                              temperature=0.0)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        budgets = [2, 12, 3, 12, 4]            # wildly different
        prompts = [np.asarray(rng.integers(2, cfg.vocab_size, 6),
                              np.int32) for _ in budgets]
        for i, (p, bud) in enumerate(zip(prompts, budgets)):
            b.submit(Request(rid=i, prompt=p, max_new_tokens=bud))
        results = b.run_continuous()
        assert sorted(r.rid for r in results) == list(range(5))

        # every result equals its solo greedy generate — the reused KV
        # slot carries nothing over from the previous occupant
        for r in results:
            g = GenerateConfig(max_new_tokens=budgets[r.rid], eos_id=1,
                               temperature=0.0)
            solo, lengths, _ = generate(
                cfg, params, jnp.asarray(prompts[r.rid][None]), g,
                cache_dtype=jnp.float32)
            np.testing.assert_array_equal(
                r.tokens, np.asarray(solo[0, :int(lengths[0])]))

        # short sequences are emitted BEFORE long ones finish: rid 0
        # (budget 2) shares the initial cohort with rid 1 (budget 12)
        # and must beat it out; rid 2 takes rid 0's slot mid-batch and
        # still beats rid 1
        pos = {r.rid: k for k, r in enumerate(results)}
        assert pos[0] < pos[1]
        assert pos[2] < pos[1]

        # KV slots reused: 5 requests through 2 slots, ONE compilation
        # of each entry point across all segments and slot prefills
        eng = b.engines[0]
        assert eng.stats["prefills"] == 5
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["prefill_traces"] == 1

    def test_ring_cache_layers_decode_per_sequence(self, rng):
        """Sliding-window (ring-buffer KV) layers under continuous
        batching: each slot writes its OWN ring position (the vmapped
        ragged path of attention._ring_write) — parity vs solo generate
        on gemma2 (window=8, rings wrap within the budget)."""
        cfg = get_reduced("gemma2-9b")
        assert cfg.sliding_window, "arch must carry ring layers"
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        gcfg = GenerateConfig(max_new_tokens=7, eos_id=1,
                              temperature=0.0)
        budgets = [2, 7, 3]
        prompts = [np.asarray(rng.integers(2, cfg.vocab_size, 5),
                              np.int32) for _ in budgets]
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        for i, (p, bud) in enumerate(zip(prompts, budgets)):
            b.submit(Request(rid=i, prompt=p, max_new_tokens=bud))
        results = b.run_continuous()
        assert sorted(r.rid for r in results) == [0, 1, 2]
        for r in results:
            g = GenerateConfig(max_new_tokens=budgets[r.rid], eos_id=1,
                               temperature=0.0)
            solo, lengths, _ = generate(
                cfg, params, jnp.asarray(prompts[r.rid][None]), g,
                cache_dtype=jnp.float32)
            np.testing.assert_array_equal(
                r.tokens, np.asarray(solo[0, :int(lengths[0])]))

    def test_sink_exception_does_not_corrupt_the_engine(self, served,
                                                        rng):
        """A raising emit callback must leave the engine on LIVE buffers
        (regression: donated inputs were only stored back on success)."""
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=3, eos_id=1)
        eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                               cache_dtype=jnp.float32)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 4), np.int32)
        reqs = [Request(rid=i, prompt=prompt) for i in range(2)]

        def boom(rid, toks, status):
            raise RuntimeError("sink failed")
        with pytest.raises(RuntimeError, match="sink failed"):
            eng.run(reqs, boom)
        got = []
        assert eng.run(reqs, lambda rid, toks, status: got.append(rid)) == 2
        assert sorted(got) == [0, 1]

    def test_unsupported_models_and_overbudget_rejected(self, served,
                                                        rng):
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=4, eos_id=1)
        whisper = get_reduced("whisper-base")
        with pytest.raises(ValueError, match="per-sequence positions"):
            ContinuousEngine(whisper, None, gcfg)
        eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                               cache_dtype=jnp.float32)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 4), np.int32)
        with pytest.raises(ValueError, match="budget"):
            eng.run([Request(rid=0, prompt=prompt, max_new_tokens=9)],
                    lambda rid, toks, status: None)
        with pytest.raises(ValueError, match="budget"):
            eng.run([Request(rid=0, prompt=prompt, max_new_tokens=0)],
                    lambda rid, toks, status: None)
        # ragged prompts are admitted into ONE pool now; only a prompt
        # LONGER than the bound slot width is rejected
        eng2 = ContinuousEngine(cfg, params, gcfg, slots=2,
                                cache_dtype=jnp.float32,
                                max_prompt_len=4)
        with pytest.raises(ValueError, match="max_prompt_len"):
            eng2.run([Request(rid=0, prompt=np.concatenate(
                [prompt, prompt]))], lambda rid, toks, status: None)
        # ragged + SSM has no pad-masking path: loud error, and the
        # Batcher falls back to exact-length grouping automatically
        mamba = get_reduced("mamba2-130m")
        eng3 = ContinuousEngine(mamba, None, gcfg, slots=2,
                                cache_dtype=jnp.float32)
        with pytest.raises(ValueError, match="attention-only"):
            eng3.run([Request(rid=0, prompt=prompt),
                      Request(rid=1, prompt=prompt[:2])],
                     lambda rid, toks, status: None)


class TestRaggedContinuous:
    """Ragged-prompt admission into ONE slot pool: the whole queue
    drains through a single `ContinuousEngine` binding at the max
    prompt length (padded per-slot prefill + prompt-length mask), with
    mid-batch completion-order emission, solo-generate parity (the
    no-pad-leak oracle: outputs influenced by a pad would diverge), and
    `idle_slot_steps` strictly below the exact-length-grouped
    baseline."""

    @pytest.fixture(scope="class")
    def served(self):
        cfg = get_reduced("qwen3-1.7b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    @staticmethod
    def _submit(b, cfg, rng, lens, budgets):
        prompts = [np.asarray(rng.integers(2, cfg.vocab_size, L),
                              np.int32) for L in lens]
        for i, (p, bud) in enumerate(zip(prompts, budgets)):
            b.submit(Request(rid=i, prompt=p, max_new_tokens=bud))
        return prompts

    def test_single_binding_parity_and_idle_drop(self, served, rng):
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=10, eos_id=1,
                              temperature=0.0)
        lens = [4, 7, 4, 7, 4]
        budgets = [2, 8, 2, 8, 3]
        rng0 = np.random.default_rng(0)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        prompts = self._submit(b, cfg, rng0, lens, budgets)
        results = b.run_continuous()
        assert len(b.engines) == 1, "must be ONE engine binding"
        assert sorted(r.rid for r in results) == list(range(5))

        # solo-generate parity: the reused slot and the padded prefill
        # leak nothing (values AND lengths)
        for r in results:
            g = GenerateConfig(max_new_tokens=budgets[r.rid], eos_id=1,
                               temperature=0.0)
            solo, L, _ = generate(cfg, params,
                                  jnp.asarray(prompts[r.rid][None]), g,
                                  cache_dtype=jnp.float32)
            np.testing.assert_array_equal(
                r.tokens, np.asarray(solo[0, :int(L[0])]))

        # mid-batch emission: rid 0 (budget 2) beats rid 1 (budget 8)
        # out of the initial cohort despite their different lengths
        pos = {r.rid: k for k, r in enumerate(results)}
        assert pos[0] < pos[1]
        eng = b.engines[0]
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["prefill_traces"] == 1
        assert eng.stats["prefills"] == 5
        idle_single = eng.stats["idle_slot_steps"]

        # exact-length-grouped baseline: same queue, one engine per
        # length group — each group idles its cohort at the group tail
        b2 = Batcher(cfg, params, gcfg, max_batch=2,
                     cache_dtype=jnp.float32)
        self._submit(b2, cfg, np.random.default_rng(0), lens, budgets)
        results2 = b2.run_continuous(exact_groups=True)
        assert len(b2.engines) == 2
        assert sorted(r.rid for r in results2) == list(range(5))
        idle_grouped = sum(e.stats["idle_slot_steps"]
                           for e in b2.engines)
        assert idle_single < idle_grouped, (idle_single, idle_grouped)

    def test_ring_cache_ragged(self, rng):
        """Sliding-window (ring-buffer KV) layers under RAGGED padded
        prefill: each sequence keeps its own last min(W, len) real keys
        (pads map to a dropped slot) — parity vs solo generate on
        gemma2 with prompts straddling the window."""
        cfg = get_reduced("gemma2-9b")
        assert cfg.sliding_window, "arch must carry ring layers"
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        gcfg = GenerateConfig(max_new_tokens=7, eos_id=1,
                              temperature=0.0)
        W = cfg.sliding_window
        lens = [3, W + 1, 5, W + 4, 4]       # short of and past the ring
        budgets = [2, 7, 3, 7, 4]
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        prompts = self._submit(b, cfg, rng, lens, budgets)
        results = b.run_continuous()
        assert len(b.engines) == 1
        assert sorted(r.rid for r in results) == list(range(5))
        for r in results:
            g = GenerateConfig(max_new_tokens=budgets[r.rid], eos_id=1,
                               temperature=0.0)
            solo, L, _ = generate(cfg, params,
                                  jnp.asarray(prompts[r.rid][None]), g,
                                  cache_dtype=jnp.float32)
            np.testing.assert_array_equal(
                r.tokens, np.asarray(solo[0, :int(L[0])]))

    def test_ssm_arch_falls_back_to_exact_groups(self, rng):
        cfg = get_reduced("mamba2-130m")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        gcfg = GenerateConfig(max_new_tokens=4, eos_id=1,
                              temperature=0.0)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        self._submit(b, cfg, rng, [4, 6, 4], [3, 3, 3])
        results = b.run_continuous()
        assert sorted(r.rid for r in results) == [0, 1, 2]
        assert len(b.engines) == 2, \
            "SSM archs must keep exact-length grouping"


def test_temperature_sampling_is_reproducible(rng):
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 4)))
    gcfg = GenerateConfig(max_new_tokens=8, eos_id=1, temperature=0.8,
                          seed=42)
    o1, _, _ = generate(cfg, params, prompt, gcfg, cache_dtype=jnp.float32)
    o2, _, _ = generate(cfg, params, prompt, gcfg, cache_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
