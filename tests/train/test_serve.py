"""Serving engine: the decode loop (-s variant) — greedy consistency,
EOS handling, per-sequence trip counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig, generate


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-130m",
                                  "jamba-v0.1-52b"])
def test_greedy_equals_teacher_forced_argmax(arch, rng):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (3, 8)))
    gcfg = GenerateConfig(max_new_tokens=10, eos_id=1, temperature=0.0)
    out, lengths, iters = generate(cfg, params, prompt, gcfg,
                                   cache_dtype=jnp.float32)
    full = jnp.concatenate([prompt, out], axis=1)
    logits, _ = T.forward(cfg, params, {"tokens": full})
    exp = jnp.argmax(logits[:, 7:-1], axis=-1)
    for b in range(3):
        L = int(lengths[b])
        assert (np.asarray(out[b, :L]) == np.asarray(exp[b, :L])).all()


def test_eos_stops_all_lanes_early(rng):
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 4)))
    # pick eos = the actually-argmaxed first token so it stops instantly
    gcfg0 = GenerateConfig(max_new_tokens=4, eos_id=1)
    out, _, _ = generate(cfg, params, prompt, gcfg0,
                         cache_dtype=jnp.float32)
    eos = int(out[0, 0])
    gcfg = GenerateConfig(max_new_tokens=16, eos_id=eos)
    out2, lengths, iters = generate(cfg, params, prompt, gcfg,
                                    cache_dtype=jnp.float32)
    assert int(lengths[0]) == 1
    # post-EOS positions are padded with eos
    assert (np.asarray(out2[0, 1:]) == eos).all()


def test_temperature_sampling_is_reproducible(rng):
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 4)))
    gcfg = GenerateConfig(max_new_tokens=8, eos_id=1, temperature=0.8,
                          seed=42)
    o1, _, _ = generate(cfg, params, prompt, gcfg, cache_dtype=jnp.float32)
    o2, _, _ = generate(cfg, params, prompt, gcfg, cache_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
