"""Property tests for ragged-prompt continuous serving (hypothesis).

The single-pool admission contract, over random prompt lengths, token
budgets and slot counts:

* every request is served EXACTLY once through ONE engine binding —
  no drops, no duplicates, however admissions interleave;
* NO pad token ever leaks into sampled output: every request's tokens
  equal its solo greedy ``generate`` (which never sees a pad) — any
  pad key entering an attention window, ring slot or sampled logit row
  would diverge the greedy argmax chain;
* per-request budgets are exact: a request emits ``min(budget,
  eos-length)`` tokens.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig, generate
from repro.serve.batcher import Batcher, Request

CAP = 6


@pytest.fixture(scope="module")
def served():
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestRaggedAdmissionInvariants:
    @settings(deadline=None, max_examples=8)
    @given(lens=st.lists(st.integers(1, 9), min_size=1, max_size=6),
           budgets=st.lists(st.integers(1, CAP), min_size=6, max_size=6),
           slots=st.integers(1, 3))
    def test_exactly_once_and_no_pad_leak(self, served, lens, budgets,
                                          slots):
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=CAP, eos_id=1,
                              temperature=0.0)
        rng = np.random.default_rng(sum(lens) + 17 * slots)
        prompts = [np.asarray(rng.integers(2, cfg.vocab_size, L),
                              np.int32) for L in lens]
        b = Batcher(cfg, params, gcfg, max_batch=slots,
                    cache_dtype=jnp.float32)
        for i, p in enumerate(prompts):
            b.submit(Request(rid=i, prompt=p,
                             max_new_tokens=budgets[i % len(budgets)]))
        results = b.run_continuous()

        # exactly once, through ONE binding
        assert len(b.engines) == 1
        assert sorted(r.rid for r in results) == list(range(len(lens)))

        # no pad leak: parity with the solo run, which never pads
        for r in results:
            bud = budgets[r.rid % len(budgets)]
            g = GenerateConfig(max_new_tokens=bud, eos_id=1,
                               temperature=0.0)
            solo, L, _ = generate(cfg, params,
                                  jnp.asarray(prompts[r.rid][None]), g,
                                  cache_dtype=jnp.float32)
            assert len(r.tokens) == int(L[0]) <= bud
            np.testing.assert_array_equal(
                r.tokens, np.asarray(solo[0, :int(L[0])]))

    @settings(deadline=None, max_examples=6)
    @given(lens=st.lists(st.integers(1, 8), min_size=2, max_size=8),
           seed=st.integers(0, 3))
    def test_accounting_invariants(self, served, lens, seed):
        """slot_steps = useful + idle, with useful = Σ emitted decode
        steps — the idle metric never undercounts or goes negative."""
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=4, eos_id=1,
                              temperature=0.0)
        rng = np.random.default_rng(seed)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    cache_dtype=jnp.float32)
        for i, L in enumerate(lens):
            b.submit(Request(rid=i, prompt=np.asarray(
                rng.integers(2, cfg.vocab_size, L), np.int32)))
        results = b.run_continuous()
        assert len(results) == len(lens)
        eng = b.engines[0]
        # each emitted token beyond the prefilled first is one useful
        # segment step
        useful = sum(len(r.tokens) - 1 for r in results)
        assert eng.stats["idle_slot_steps"] >= 0
        assert eng.stats["slot_steps"] == \
            useful + eng.stats["idle_slot_steps"]
