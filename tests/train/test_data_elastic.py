"""Data-pipeline determinism (fault-tolerant replay) + elastic
checkpoint restore onto a different device topology."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import Prefetcher, SyntheticLM

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


class TestSyntheticLM:
    def test_replay_determinism(self):
        """Resuming at step k regenerates byte-identical batches — the
        property that makes checkpoint-restart exact."""
        d1 = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4,
                         seed=3)
        d2 = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4,
                         seed=3)
        run1 = [d1.batch_at(i) for i in range(5)]
        run2 = [d2.batch_at(i) for i in (3, 4)]
        np.testing.assert_array_equal(run1[3]["tokens"],
                                      run2[0]["tokens"])
        np.testing.assert_array_equal(run1[4]["labels"],
                                      run2[1]["labels"])

    def test_labels_are_next_tokens(self):
        d = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=2,
                        seed=0)
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["labels"][:, :-1])

    def test_task_is_learnable_structure(self):
        """The Markov task has real next-token signal (low conditional
        entropy vs uniform)."""
        d = SyntheticLM(vocab_size=1000, seq_len=256, global_batch=8,
                        seed=0)
        b = d.batch_at(0)
        toks = np.asarray(b["tokens"]).reshape(-1)
        # structured: active vocabulary is a strict subset and the
        # unigram entropy sits clearly below uniform (the conditional
        # structure itself is proven by the trainer's loss decrease)
        vals, counts = np.unique(toks, return_counts=True)
        p = counts / counts.sum()
        ent = -(p * np.log(p)).sum()
        assert len(vals) < 600
        assert ent < 0.9 * np.log(1000)

    def test_prefetcher_preserves_order_and_count(self):
        d = SyntheticLM(vocab_size=100, seq_len=8, global_batch=2, seed=1)
        raw = [d.batch_at(i) for i in range(6)]
        pf = Prefetcher(iter(raw))
        got = list(pf)
        assert len(got) == 6
        np.testing.assert_array_equal(np.asarray(got[4]["tokens"]),
                                      raw[4]["tokens"])


@pytest.mark.slow
def test_elastic_restore_onto_sharded_mesh():
    """A checkpoint written on 1 device restores onto an 8-device mesh
    with per-leaf shardings (the elastic-restart path)."""
    from repro.train import checkpoint as C
    tree = {"w": jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16),
            "b": jnp.ones((16,), jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 7, tree)
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=8")
            import sys
            sys.path.insert(0, %r)
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train import checkpoint as C
            mesh = jax.make_mesh((8,), ("data",))
            template = {"w": jnp.zeros((64, 16), jnp.float32),
                        "b": jnp.zeros((16,), jnp.bfloat16)}
            sh = {"w": NamedSharding(mesh, P("data", None)),
                  "b": NamedSharding(mesh, P())}
            tree, step, _ = C.restore(%r, template, shardings=sh)
            assert step == 7
            assert len(tree["w"].sharding.device_set) == 8
            want = np.arange(64 * 16, dtype=np.float32).reshape(64, 16)
            np.testing.assert_array_equal(np.asarray(tree["w"]), want)
            print("OKELASTIC")
        """ % (SRC, d))
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OKELASTIC" in out.stdout
