"""Persistent-halo engine: backend parity + the zero-copy guarantee.

Parity: the pattern must produce identical results (values, reduce,
iteration counts) whichever backend realises the loop body — "jnp"
(shift algebra, pad per application), "pallas" (persistent halo frame),
"pallas-multistep" (temporal blocking) — on the -d Jacobi loop for all
four ⊥ models, in interpret mode.

Zero-copy: no ``pad`` primitive (nor any other full-grid staging op) may
appear inside the ``while_loop`` body of the Pallas-backed solver — the
frame is padded once, outside.  Verified by jaxpr inspection, plus a
strict full-grid-ops-per-iteration comparison against the seed's
pad-per-iteration style loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frames
from repro.core.pattern import LoopOfStencilReduce
from repro.core.semantics import Boundary
from repro.kernels import ops, ref as R

BOUNDARIES = ["zero", "nan", "reflect", "wrap"]


def heat(get, *_):
    lap = (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
           - 4.0 * get(0, 0))
    return get(0, 0) + 0.1 * lap


def _loop(backend, boundary, unroll=1, tol=2e-3, **kw):
    return LoopOfStencilReduce(
        f=heat, k=1, combine="max", cond=lambda r: r < tol,
        delta=R.abs_delta, boundary=boundary, max_iters=60,
        unroll=unroll, backend=backend, interpret=True,
        block=(32, 128), **kw)


class TestBackendParity:
    @pytest.mark.parametrize("boundary", BOUNDARIES)
    def test_pallas_matches_jnp_d_loop(self, boundary, rng):
        a = jnp.asarray(rng.normal(size=(40, 136)), jnp.float32)
        want = _loop("jnp", boundary).run(a)
        got = _loop("pallas", boundary).run(a)
        assert int(got.iters) == int(want.iters)
        if boundary == "nan":        # NaN ⊥ poisons edges in both paths
            assert np.isnan(np.asarray(got.a)).all() \
                == np.isnan(np.asarray(want.a)).all()
            inner = (slice(2, -2), slice(2, -2))
        else:
            inner = (slice(None), slice(None))
            np.testing.assert_allclose(float(got.reduced),
                                       float(want.reduced), atol=1e-6)
        np.testing.assert_allclose(np.asarray(got.a)[inner],
                                   np.asarray(want.a)[inner], atol=1e-5)

    @pytest.mark.parametrize("boundary", BOUNDARIES)
    @pytest.mark.parametrize("T", [2, 3])
    def test_multistep_T_equals_T_single_steps(self, boundary, T, rng):
        a = jnp.asarray(rng.normal(size=(40, 136)), jnp.float32)
        want = _loop("jnp", boundary, unroll=T).run(a)
        got = _loop("pallas-multistep", boundary, unroll=T).run(a)
        assert int(got.iters) == int(want.iters)
        if boundary != "nan":
            np.testing.assert_allclose(np.asarray(got.a),
                                       np.asarray(want.a), atol=1e-5)
            np.testing.assert_allclose(float(got.reduced),
                                       float(want.reduced), atol=1e-6)

    @pytest.mark.parametrize("boundary", ["zero", "reflect"])
    def test_pallas_unrolled_matches_jnp(self, boundary, rng):
        """unroll>1 on the single-step pallas backend: intermediate
        sweeps skip the fused reduce (do_reduce=False) but the final
        one must still feed the condition identically."""
        a = jnp.asarray(rng.normal(size=(40, 136)), jnp.float32)
        want = _loop("jnp", boundary, unroll=2).run(a)
        got = _loop("pallas", boundary, unroll=2).run(a)
        assert int(got.iters) == int(want.iters)
        np.testing.assert_allclose(np.asarray(got.a), np.asarray(want.a),
                                   atol=1e-5)
        np.testing.assert_allclose(float(got.reduced),
                                   float(want.reduced), atol=1e-6)

    def test_env_fields_reach_f(self, rng):
        u0 = jnp.zeros((24, 40), jnp.float32)
        fxy = jnp.asarray(rng.normal(size=(24, 40)), jnp.float32)
        kw = dict(alpha=2.0, dx=0.2, tol=1e-5, max_iters=400)
        ur, dr, ir = ops.jacobi_solve(u0, fxy, backend="jnp", **kw)
        up, dp, ip = ops.jacobi_solve(u0, fxy, backend="pallas", **kw)
        um, dm, im = ops.jacobi_solve(u0, fxy, backend="pallas-multistep",
                                      unroll=3, **kw)
        assert int(ip) == int(ir)
        assert int(ir) <= int(im) < int(ir) + 3   # unroll may overshoot
        np.testing.assert_allclose(np.asarray(up), np.asarray(ur),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(um), np.asarray(ur),
                                   atol=1e-5)

    def test_bad_backend_and_mode_rejected(self):
        with pytest.raises(ValueError):
            LoopOfStencilReduce(f=heat, cond=lambda r: True,
                                backend="cuda")
        loop = LoopOfStencilReduce(f=lambda a: a, cond=lambda r: True,
                                   mode="step", backend="pallas")
        with pytest.raises(ValueError):
            loop.run(jnp.zeros((8, 8)))


class TestFrames:
    @pytest.mark.parametrize("boundary", BOUNDARIES)
    @pytest.mark.parametrize("pad", [1, 3])
    def test_make_frame_matches_jnp_pad(self, boundary, pad, rng):
        """On an exactly block-rounded domain the whole frame must equal
        jnp.pad's realisation of ⊥ (corners included)."""
        a = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
        spec = frames.frame_spec(16, 128, k=1, block=(16, 128), sweeps=pad)
        assert spec.interior == (16, 128)
        got = frames.make_frame(a, spec, boundary)
        want = Boundary(boundary).pad(a, pad)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_refresh_is_edge_sized(self):
        """The refresh touches O(m+n) cells: its jaxpr must not contain
        any update covering the full interior."""
        spec = frames.frame_spec(256, 256, k=1, block=(64, 128))
        fr = jnp.zeros(spec.shape, jnp.float32)
        jaxpr = jax.make_jaxpr(
            lambda x: frames.refresh_frame(x, spec, "reflect"))(fr)
        interior = spec.interior[0] * spec.interior[1]
        for eq in jaxpr.jaxpr.eqns:
            if eq.primitive.name in ("dynamic_update_slice", "scatter"):
                upd = eq.invars[1].aval
                assert np.prod(upd.shape) < interior / 4

    def test_halo_too_wide_rejected(self):
        with pytest.raises(ValueError):
            frames.frame_spec(16, 128, k=1, block=(16, 128), sweeps=20)


def _subjaxprs(eq):
    """Nested sub-jaxprs of an equation (Jaxpr or ClosedJaxpr params)."""
    for v in eq.params.values():
        if hasattr(v, "eqns"):
            yield v
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            yield v.jaxpr


def _flatten_eqns(jx, out):
    """All eqns of ``jx`` including nested sub-jaxprs (pjit/scan/...),
    but NOT Pallas kernel bodies — those are VMEM-tile-internal, not
    HBM staging passes."""
    for eq in jx.eqns:
        out.append(eq)
        if eq.primitive.name == "pallas_call":
            continue
        for sub in _subjaxprs(eq):
            _flatten_eqns(sub, out)


def _while_body_eqns(fn, *args):
    """Equations inside the while_loop bodies of fn's jaxpr, flattened
    through nested sub-jaxprs."""
    bodies = []

    def walk(jx):
        for eq in jx.eqns:
            if eq.primitive.name == "while":
                bodies.append(eq.params["body_jaxpr"].jaxpr)
                continue
            for sub in _subjaxprs(eq):
                walk(sub)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    assert bodies, "no while_loop in jaxpr"
    eqns = []
    for body in bodies:
        _flatten_eqns(body, eqns)
    return eqns


def _full_grid_ops(eqns, min_elems):
    """Ops *producing* a full-grid-sized array (staging passes)."""
    return [e for e in eqns
            if any(hasattr(v, "aval") and v.aval.shape
                   and int(np.prod(v.aval.shape)) >= min_elems
                   for v in e.outvars)]


class TestZeroCopy:
    def setup_method(self, _):
        self.u0 = jnp.zeros((256, 256), jnp.float32)
        self.fxy = jnp.ones((256, 256), jnp.float32)
        self.kw = dict(alpha=0.5, dx=1.0 / 256, tol=1e-6, max_iters=10)

    def _seed_style_loop(self, u0, fxy):
        """The pad-per-iteration strawman this PR retires: one
        frame/unframe per sweep inside the while body."""
        f = R.helmholtz_jacobi_taps(0.5, 1.0 / 256)

        def body(carry):
            u, d, it = carry
            new, d = ops.fused_sweep(
                u, f, env=(fxy,), k=1, combine="max", identity=-jnp.inf,
                measure=R.abs_delta, backend="pallas", interpret=True,
                block=(128, 128))
            return new, d, it + 1

        return jax.lax.while_loop(
            lambda c: jnp.logical_and(c[1] >= 1e-6, c[2] < 10), body,
            (u0, jnp.asarray(jnp.inf, jnp.float32), jnp.asarray(0)))

    def test_no_pad_in_pallas_while_body(self):
        for backend, unroll in (("pallas", 1), ("pallas-multistep", 4)):
            eqns = _while_body_eqns(
                lambda u, e: ops.jacobi_solve(
                    u, e, backend=backend, unroll=unroll, **self.kw),
                self.u0, self.fxy)
            names = [e.primitive.name for e in eqns]
            assert "pallas_call" in names
            assert "pad" not in names, f"{backend}: pad inside while body"

    def test_seed_style_loop_does_pad_per_iteration(self):
        names = [e.primitive.name
                 for e in _while_body_eqns(self._seed_style_loop,
                                           self.u0, self.fxy)]
        assert "pad" in names          # the strawman really pays it

    def test_fewer_full_grid_ops_than_seed_style(self):
        """Strictly fewer full-grid-producing ops per iteration than the
        pad-per-iteration path (CPU-CI realisation of the acceptance
        criterion)."""
        min_elems = 256 * 256
        seed_eqns = _while_body_eqns(self._seed_style_loop,
                                     self.u0, self.fxy)
        pers_eqns = _while_body_eqns(
            lambda u, e: ops.jacobi_solve(u, e, backend="pallas",
                                          **self.kw),
            self.u0, self.fxy)
        n_seed = len(_full_grid_ops(seed_eqns, min_elems))
        n_pers = len(_full_grid_ops(pers_eqns, min_elems))
        assert n_pers < n_seed, (n_pers, n_seed)
