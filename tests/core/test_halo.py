"""Distributed 1:n mode ≡ single-device execution (bit-level).

Multi-device tests run in a SUBPROCESS with 8 placeholder host devices so
the main test process keeps the single-device view (the dry-run rule:
never set the device-count flag globally).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_multidevice(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
rng = np.random.default_rng(0)
b0 = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
def jac(get, *_):
    return 0.25*(get(-1,0)+get(1,0)+get(0,-1)+get(0,1))
solo = LoopOfStencilReduce(f=jac, k=1, combine="max", identity=-jnp.inf,
                           cond=lambda r: r < 1e-4,
                           delta=lambda n,o: jnp.abs(n-o),
                           max_iters=1500).run(b0)
"""


@pytest.mark.slow
class TestDistributedPattern:
    def test_1d_rows_decomposition(self):
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            mesh = jax.make_mesh((8,), ("data",))
            part = GridPartition(mesh=mesh, axis_names=("data",),
                                 array_axes=(0,))
            dist = distributed_loop_of_stencil_reduce(
                jac, "max", lambda r: r < 1e-4, b0, k=1, part=part,
                identity=-jnp.inf, delta=lambda n,o: jnp.abs(n-o),
                max_iters=1500)
            assert int(dist.iters) == int(solo.iters), (dist.iters, solo.iters)
            assert np.allclose(dist.a, solo.a, atol=1e-6)
            print("OK1D")
        """))
        assert "OK1D" in out

    def test_2d_decomposition_with_corners(self):
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            part = GridPartition(mesh=mesh, axis_names=("data", "model"),
                                 array_axes=(0, 1))
            # k=2 stencil with diagonal (corner) taps
            def blur(get, *_):
                s = sum(get(i, j) for i in (-2,-1,0,1,2)
                        for j in (-2,-1,0,1,2))
                return s / 25.0
            one = stencil_taps(blur, b0, 2, "reflect")
            dist = distributed_loop_of_stencil_reduce(
                blur, "max", lambda r: True, b0, k=2, part=part,
                identity=-jnp.inf, boundary="reflect", max_iters=5)
            assert np.allclose(dist.a, one, atol=1e-5)
            print("OK2D")
        """))
        assert "OK2D" in out

    def test_wrap_boundary_ring_exchange(self):
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            mesh = jax.make_mesh((8,), ("data",))
            part = GridPartition(mesh=mesh, axis_names=("data",),
                                 array_axes=(0,))
            one = stencil_taps(lambda g: jac(g), b0, 1, "wrap")
            dist = distributed_loop_of_stencil_reduce(
                jac, "max", lambda r: True, b0, k=1, part=part,
                identity=-jnp.inf, boundary="wrap", max_iters=3)
            assert np.allclose(dist.a, one, atol=1e-6)
            print("OKWRAP")
        """))
        assert "OKWRAP" in out
