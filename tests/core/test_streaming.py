"""Stream tier regressions: the sharded_farm jit wrapper must be built
once, not per call (a fresh ``jax.jit`` wrapper per ``run`` call carries a
fresh compilation cache — every batch retraced and recompiled the
worker)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import farm, ofarm, pipe, sharded_farm


def test_sharded_farm_traces_once():
    mesh = jax.make_mesh((1,), ("data",))
    traces = {"n": 0}

    def worker(x):
        traces["n"] += 1
        return x * 2.0

    run = sharded_farm(worker, mesh)
    batch = jnp.arange(8.0).reshape(8, 1)
    out1 = run(batch)
    out2 = run(batch)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(batch) * 2)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(batch) * 2)
    assert traces["n"] == 1, f"worker retraced {traces['n']}x"


def test_sharded_farm_new_shape_retraces_same_wrapper():
    mesh = jax.make_mesh((1,), ("data",))
    traces = {"n": 0}

    def worker(x):
        traces["n"] += 1
        return x + 1.0

    run = sharded_farm(worker, mesh)
    run(jnp.zeros((4, 2)))
    run(jnp.zeros((4, 2)))          # cache hit
    run(jnp.zeros((8, 2)))          # new shape: one more trace
    assert traces["n"] == 2


def test_farm_of_pipe_still_composes():
    stage = pipe(lambda x: x + 1.0, lambda x: x * 3.0)
    out = farm(stage)(jnp.ones((4, 2)))
    np.testing.assert_allclose(np.asarray(out), np.full((4, 2), 6.0))
    out = ofarm(stage)(jnp.ones((4, 2)))
    np.testing.assert_allclose(np.asarray(out), np.full((4, 2), 6.0))
