"""Stream tier (generic) regressions: the sharded_farm jit wrapper must
be built once, not per call (a fresh ``jax.jit`` wrapper per ``run`` call
carries a fresh compilation cache — every batch retraced and recompiled
the worker), and the StreamRunner must unstack results LAZILY (the sink
consumes item i before item i+1 is sliced) and survive empty sources and
ragged final batches.  The engine tier (FarmEngine) is covered in
tests/core/test_farm.py."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import StreamRunner, farm, ofarm, pipe, sharded_farm


def test_sharded_farm_traces_once():
    mesh = jax.make_mesh((1,), ("data",))
    traces = {"n": 0}

    def worker(x):
        traces["n"] += 1
        return x * 2.0

    run = sharded_farm(worker, mesh)
    batch = jnp.arange(8.0).reshape(8, 1)
    out1 = run(batch)
    out2 = run(batch)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(batch) * 2)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(batch) * 2)
    assert traces["n"] == 1, f"worker retraced {traces['n']}x"


def test_sharded_farm_new_shape_retraces_same_wrapper():
    mesh = jax.make_mesh((1,), ("data",))
    traces = {"n": 0}

    def worker(x):
        traces["n"] += 1
        return x + 1.0

    run = sharded_farm(worker, mesh)
    run(jnp.zeros((4, 2)))
    run(jnp.zeros((4, 2)))          # cache hit
    run(jnp.zeros((8, 2)))          # new shape: one more trace
    assert traces["n"] == 2


def test_farm_of_pipe_still_composes():
    stage = pipe(lambda x: x + 1.0, lambda x: x * 3.0)
    out = farm(stage)(jnp.ones((4, 2)))
    np.testing.assert_allclose(np.asarray(out), np.full((4, 2), 6.0))
    out = ofarm(stage)(jnp.ones((4, 2)))
    np.testing.assert_allclose(np.asarray(out), np.full((4, 2), 6.0))


def test_stream_runner_empty_source():
    sunk = []
    n = StreamRunner(worker=jax.jit(lambda x: x), source=lambda: iter([]),
                     sink=sunk.append, batch=4).run()
    assert n == 0 and sunk == []


def test_stream_runner_ragged_final_batch():
    """5 items through batch=2: two full batches + a final batch of 1 —
    every item must reach the sink exactly once, in order."""
    items = [np.full((3,), float(i), np.float32) for i in range(5)]
    sunk = []
    n = StreamRunner(worker=jax.jit(lambda x: x * 2.0),
                     source=lambda: iter(items),
                     sink=sunk.append, batch=2).run()
    assert n == 5
    for i, out in enumerate(sunk):
        np.testing.assert_allclose(np.asarray(out), 2.0 * i)


def test_stream_runner_unstack_is_lazy():
    """The sink must see item i before item i+1 is sliced — _unstack is
    a generator, not a list of pre-materialised slices."""
    seen_at_slice = []

    class Probe:
        """Tree leaf that records how many sinks ran before each
        __getitem__ (lazy => strictly increasing prefix counts)."""
        shape = (3,)

        def __init__(self):
            self.log = seen_at_slice

        def __getitem__(self, i):
            self.log.append(("slice", i))
            return i

    gen = StreamRunner._unstack((Probe(),))
    first = next(gen)
    seen_at_slice.append(("sink", 0))
    second = next(gen)
    assert seen_at_slice == [("slice", 0), ("sink", 0), ("slice", 1)]
    assert (first, second) == ((0,), (1,))
