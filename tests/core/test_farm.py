"""Lane-resident streaming engine: farm_run parity + lane-slot reuse.

Parity: ``farm_run`` (ONE done-masked while_loop over a stacked
(lanes, frame) carry) must match ``farm(run)`` (vmap of the scalar loop)
lane for lane — values, reduces, per-lane trip counts — on mixed
convergence speeds, across the jnp / pallas / pallas-multistep backends.

Slot reuse: processing stream item i+1 in an existing lane slot performs
no ``jnp.pad``, no full-frame copy, and no re-framing — only the
O(interior) refill plus the ghost-ring refresh.  Verified by jaxpr
inspection of the FarmEngine round, by trace counting across a whole
stream (ONE compilation, ragged final round included), and by the
engine's own host-transfer accounting (interiors cross the boundary,
frames never do).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FarmEngine, LoopOfStencilReduce, farm
from repro.core.executor import auto_unroll, check_unroll_feasible
from repro.core.introspect import flatten_eqns, while_body_eqns
from repro.kernels import ref as R

BACKENDS = ["jnp", "pallas", "pallas-multistep"]


def heat(get, *_):
    lap = (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
           - 4.0 * get(0, 0))
    return get(0, 0) + 0.1 * lap


def mkloop(backend, unroll=1, boundary="reflect", max_iters=60):
    return LoopOfStencilReduce(
        f=heat, k=1, combine="max", cond=lambda r: r < 2e-3,
        delta=R.abs_delta, boundary=boundary, max_iters=max_iters,
        unroll=unroll, backend=backend, interpret=True, block=(32, 128))


def mixed_batch(rng, n=4, shape=(40, 136)):
    """Stacked items with deliberately different convergence speeds."""
    u0 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    scales = (1.0, 5.0, 0.1, 2.0, 0.5, 3.0)
    return jnp.stack([u0 * scales[i % len(scales)] for i in range(n)])


class TestFarmRunParity:
    @pytest.mark.parametrize("backend,unroll", [
        ("jnp", 1), ("pallas", 1), ("pallas", 2),
        ("pallas-multistep", 3)])
    def test_matches_vmapped_run_mixed_trip_counts(self, backend, unroll,
                                                   rng):
        loop = mkloop(backend, unroll)
        batch = mixed_batch(rng)
        want = farm(loop.run)(batch)
        got = loop.farm_run(batch)
        iters = np.asarray(got.iters)
        assert len(set(iters.tolist())) > 1, "want MIXED trip counts"
        np.testing.assert_array_equal(iters, np.asarray(want.iters))
        np.testing.assert_allclose(np.asarray(got.a),
                                   np.asarray(want.a), atol=1e-5)
        np.testing.assert_allclose(np.asarray(got.reduced),
                                   np.asarray(want.reduced), atol=1e-6)

    def test_done0_premasks_lanes(self, rng):
        loop = mkloop("pallas")
        batch = mixed_batch(rng)
        done0 = jnp.asarray([False, True, False, False])
        res = loop.farm_run(batch, done0=done0)
        assert int(res.iters[1]) == 0
        np.testing.assert_allclose(np.asarray(res.a[1]),
                                   np.asarray(batch[1]), atol=0)

    def test_env_fields_per_lane(self, rng):
        loop = LoopOfStencilReduce(
            f=R.restore_taps(2.0), k=1, combine="max",
            cond=lambda r: r < 1e-3, delta=R.abs_delta,
            boundary="reflect", max_iters=24, backend="pallas",
            interpret=True, block=(32, 128))
        batch = mixed_batch(rng, n=3)
        masks = (batch > 1.0).astype(jnp.float32)
        got = loop.farm_run(batch, env=(batch, masks))
        for i in range(3):
            ref = loop.run(batch[i], env=(batch[i], masks[i]))
            assert int(got.iters[i]) == int(ref.iters)
            np.testing.assert_allclose(np.asarray(got.a[i]),
                                       np.asarray(ref.a), atol=1e-5)

    def test_s_variant_and_sharded_rejected(self):
        loop = LoopOfStencilReduce(
            f=heat, cond=lambda r, s: True,
            state_init=lambda: jnp.zeros(()),
            state_update=lambda s, a, it: s)
        with pytest.raises(ValueError, match="-s variant"):
            loop.farm_run(jnp.zeros((2, 8, 128)))
        sharded = LoopOfStencilReduce(
            f=heat, cond=lambda r: True, backend="pallas-sharded",
            partition=object())
        with pytest.raises(ValueError, match="FarmEngine"):
            sharded.farm_run(jnp.zeros((2, 8, 128)))


class TestFarmEngineStream:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_stream_parity_with_per_item_runs(self, backend, rng):
        """5 items through 2 lane slots (2 full rounds + a ragged one):
        every item must match its solo run exactly — the refilled slot
        carries nothing over from the previous occupant."""
        loop = mkloop(backend, unroll=3 if "multistep" in backend else 1)
        items = [np.asarray(x) for x in mixed_batch(rng, n=5)]
        eng = FarmEngine(loop, lanes=2)
        outs = []
        n = eng.run(items, outs.append)
        assert n == 5 and eng.stats["rounds"] == 3
        for it, res in zip(items, outs):
            ref = loop.run(jnp.asarray(it))
            assert int(res.iters) == int(ref.iters)
            np.testing.assert_allclose(np.asarray(res.a),
                                       np.asarray(ref.a), atol=1e-5)

    def test_empty_source_and_oversize_batch(self):
        eng = FarmEngine(mkloop("pallas"), lanes=2)
        assert eng.run(lambda: iter([]), lambda r: None) == 0
        with pytest.raises(ValueError, match="exceeds"):
            eng.round(np.zeros((3, 8, 128), np.float32))

    def test_one_compilation_across_the_stream(self, rng):
        """The whole stream — ragged final round included — must hit ONE
        compilation of the round function: the host pads short batches
        to the lane count, so shapes never change."""
        traces = {"n": 0}

        def counted_heat(get, *_):
            traces["n"] += 1
            return heat(get)

        loop = LoopOfStencilReduce(
            f=counted_heat, k=1, combine="max", cond=lambda r: r < 2e-3,
            delta=R.abs_delta, boundary="zero", max_iters=12,
            backend="pallas", interpret=True, block=(32, 128))
        items = [np.asarray(x) for x in mixed_batch(rng, n=7)]
        eng = FarmEngine(loop, lanes=3)
        n = eng.run(items[:3], lambda r: None)
        assert n == 3
        after_first = traces["n"]
        assert after_first > 0
        n = eng.run(items[3:], lambda r: None)      # incl. ragged round
        assert n == 4
        assert traces["n"] == after_first, \
            f"worker retraced: {traces['n']} != {after_first}"

    def test_host_transfer_is_interior_sized(self, rng):
        """Per item, exactly the (m, n) interior crosses the host
        boundary in each direction (plus the scalar reduce/iters) — the
        (m+2p, n+2p) frames never do."""
        m, n_ = 40, 136
        loop = mkloop("pallas", max_iters=8)
        items = [np.asarray(x) for x in mixed_batch(rng, n=4,
                                                    shape=(m, n_))]
        eng = FarmEngine(loop, lanes=2)
        count = eng.run(items, lambda r: None)
        cell = 4                                   # f32
        want_h2d = eng.stats["rounds"] * 2 * m * n_ * cell
        want_d2h = eng.stats["rounds"] * 2 * (m * n_ * cell + cell + 4)
        assert eng.stats["h2d_bytes"] == want_h2d
        assert eng.stats["d2h_bytes"] == want_d2h
        frame_bytes = (m + 2) * (n_ + 2) * cell
        assert eng.stats["h2d_bytes"] / count < frame_bytes


def _round_jaxpr(backend, rng, unroll=1):
    """Trace one FarmEngine round (slots already bound — this is the
    steady-state 'process item i+1 in an existing slot' program)."""
    loop = mkloop(backend, unroll=unroll, max_iters=8)
    eng = FarmEngine(loop, lanes=2)
    items = np.stack([np.asarray(x) for x in mixed_batch(rng, n=2)])
    eng.round(items)                     # binds + fills the slots
    active = jnp.ones((2,), bool)
    return jax.make_jaxpr(eng._round_impl)(
        eng._frames, eng._env_frames, jnp.asarray(items), active)


class TestLaneSlotReuse:
    """The acceptance criterion, by jaxpr inspection: stream item i+1
    lands in an existing lane slot with no pad, no full-frame copy and
    no re-framing — only the O(interior) refill + ghost refresh."""

    @pytest.mark.parametrize("backend,unroll",
                             [("pallas", 1), ("pallas-multistep", 3)])
    def test_no_pad_no_reframe_in_round(self, backend, unroll, rng):
        jaxpr = _round_jaxpr(backend, rng, unroll)
        eqns = flatten_eqns(jaxpr.jaxpr, [])
        names = [e.primitive.name for e in eqns]
        assert "pad" not in names, "re-framing pad in the streaming round"

        # no re-allocation of the frame stack: nothing materialises a
        # fresh full-frame-sized float array (the bool done-mask select
        # is the only frame-sized broadcast allowed)
        lanes, fh, fw = 2, 42, 138                 # (40,136) + 2*pad
        frame_elems = lanes * fh * fw
        for e in eqns:
            if e.primitive.name in ("broadcast_in_dim", "iota"):
                for v in e.outvars:
                    if (np.issubdtype(v.aval.dtype, np.floating)
                            and int(np.prod(v.aval.shape)) >= frame_elems):
                        raise AssertionError(
                            f"full-frame allocation in round: {e}")

        # every dynamic_update_slice writes at most the interior stack
        # (the refill) — a full-frame copy would exceed it
        interior_elems = lanes * 40 * 136
        for e in eqns:
            if e.primitive.name == "dynamic_update_slice":
                upd = e.invars[1].aval
                assert int(np.prod(upd.shape)) <= interior_elems, \
                    f"super-interior DUS in round: {upd.shape}"

    @pytest.mark.parametrize("backend", ["pallas", "pallas-multistep"])
    def test_while_body_is_the_persistent_kernel(self, backend, rng):
        """Inside the shared while body: the vmapped fused kernel and
        the edge-sized ghost refresh — no pad, no interior-sized copies
        beyond the kernel's own frame round-trip."""
        loop = mkloop(backend, unroll=3 if "multistep" in backend else 1,
                      max_iters=8)
        eng = FarmEngine(loop, lanes=2)
        items = np.stack([np.asarray(x) for x in mixed_batch(rng, n=2)])
        eng.round(items)
        active = jnp.ones((2,), bool)
        eqns = while_body_eqns(
            lambda fr, it, act: eng._round_impl(fr, (), it, act)[2],
            eng._frames, jnp.asarray(items), active)
        names = [e.primitive.name for e in eqns]
        assert "pallas_call" in names
        assert "pad" not in names


SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_multidevice(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


SHARDED_PRELUDE = """
import os, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core import FarmEngine, GridPartition, LoopOfStencilReduce
from repro.kernels import ref as R
rng = np.random.default_rng(0)
items = [np.asarray(rng.normal(size=(64, 64)), np.float32) * s
         for s in (1.0, 5.0, 0.1, 2.0, 3.0, 0.5, 4.0)]

def heat(get, *_):
    lap = get(-1,0)+get(1,0)+get(0,-1)+get(0,1)-4.0*get(0,0)
    return get(0,0)+0.1*lap

def mkloop(backend, part=None, unroll=1):
    return LoopOfStencilReduce(
        f=heat, k=1, combine="max", cond=lambda r: r < 2e-3,
        delta=R.abs_delta, boundary="zero", max_iters=40, unroll=unroll,
        backend=backend, partition=part, interpret=True, block=(16, 128))

refs = [mkloop("jnp").run(jnp.asarray(it)) for it in items]

def check(eng):
    outs = []
    n = eng.run(items, outs.append)
    assert n == len(items), n
    for res, ref in zip(outs, refs):
        assert int(res.iters) == int(ref.iters), (res.iters, ref.iters)
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(ref.a),
                                   atol=1e-5)
"""


@pytest.mark.slow
class TestFarmEngineSharded:
    """The 1:1×1:n compositions, in an 8-virtual-device subprocess."""

    def test_lanes_over_data_axis(self):
        out = run_multidevice(SHARDED_PRELUDE + """
mesh = jax.make_mesh((4,), ("data",))
check(FarmEngine(mkloop("pallas"), lanes=4, mesh=mesh))
check(FarmEngine(mkloop("jnp"), lanes=4, mesh=mesh))
print("OKLANES")
""")
        assert "OKLANES" in out

    def test_composed_lanes_times_spatial(self):
        """Lanes over 'data' x each lane's frame ppermute-decomposed
        over 'model' — the full two-tier composition, unroll 1 and
        auto."""
        out = run_multidevice(SHARDED_PRELUDE + """
from repro.core.executor import auto_unroll
mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))
check(FarmEngine(mkloop("pallas-sharded", part), lanes=4, mesh=mesh))
# unroll='auto' checks the condition every T sweeps: parity against the
# jnp path at the SAME resolved T (iters overshoot by < T vs unroll=1)
T = auto_unroll(64, 64, k=1, block=(16, 128), part=part)
assert T > 1, T
refs = [mkloop("jnp", unroll=T).run(jnp.asarray(it)) for it in items]
check(FarmEngine(mkloop("pallas-sharded", part, unroll="auto"),
                 lanes=4, mesh=mesh))
print("OKCOMPOSED")
""")
        assert "OKCOMPOSED" in out

    def test_validation(self):
        from repro.core import GridPartition
        mesh = jax.make_mesh((1,), ("data",))
        part = GridPartition(mesh=mesh, axis_names=("data",),
                             array_axes=(0,))
        loop = LoopOfStencilReduce(
            f=heat, cond=lambda r: True, backend="pallas-sharded",
            partition=part)
        with pytest.raises(ValueError, match="mesh="):
            FarmEngine(loop, lanes=2)
        with pytest.raises(ValueError, match="collides"):
            FarmEngine(loop, lanes=1, mesh=mesh, lane_axis="data")
        from types import SimpleNamespace
        fake2 = SimpleNamespace(axis_names=("data",), shape={"data": 2})
        with pytest.raises(ValueError, match="divide"):
            FarmEngine(mkloop("pallas"), lanes=3, mesh=fake2)


class TestAutoUnroll:
    def test_respects_local_feasibility_ceiling(self):
        class FakeMesh:
            shape = {"data": 8}

        class FakePart:
            mesh = FakeMesh()
            axis_names = ("data",)
            array_axes = (0,)
            shards = (8,)

        # 8 shards of a 64-row grid: local m = 8, so k·T < 8
        T = auto_unroll(64, 64, k=1, part=FakePart())
        assert 1 <= T < 8
        # single device, roomy grid: deeper blocking is allowed
        assert auto_unroll(512, 512, k=1) >= T

    def test_infeasible_explicit_T_raises_with_context(self):
        class FakeMesh:
            shape = {"data": 8}

        class FakePart:
            mesh = FakeMesh()
            axis_names = ("data",)
            array_axes = (0,)
            shards = (8,)

        with pytest.raises(ValueError, match="T <= 7"):
            check_unroll_feasible(64, 64, 8, k=1, part=FakePart())
        check_unroll_feasible(64, 64, 4, k=1, part=FakePart())  # fine

    def test_auto_resolves_on_run(self, rng):
        loop = mkloop("pallas-multistep", unroll="auto", max_iters=12)
        a = jnp.asarray(rng.normal(size=(40, 136)), jnp.float32)
        res = loop.run(a)
        T = auto_unroll(40, 136, k=1, block=(32, 128))
        assert T > 1
        ref = mkloop("jnp", unroll=T, max_iters=12).run(a)
        assert int(res.iters) == int(ref.iters)
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(ref.a),
                                   atol=1e-4)

    def test_bad_unroll_rejected(self):
        with pytest.raises(ValueError, match="unroll"):
            mkloop("pallas", unroll=0)
        with pytest.raises(ValueError, match="unroll"):
            mkloop("pallas", unroll="deep")
