"""Lane-resident streaming engine: farm_run parity + lane-slot reuse.

Parity: ``farm_run`` (ONE done-masked while_loop over a stacked
(lanes, frame) carry) must match ``farm(run)`` (vmap of the scalar loop)
lane for lane — values, reduces, per-lane trip counts — on mixed
convergence speeds, across the jnp / pallas / pallas-multistep backends.

Slot reuse: processing stream item i+1 in an existing lane slot performs
no ``jnp.pad``, no full-frame copy, and no re-framing — only the
O(interior) refill plus the ghost-ring refresh.  Verified by jaxpr
inspection of the FarmEngine round, by trace counting across a whole
stream (ONE compilation, ragged final round included), and by the
engine's own host-transfer accounting (interiors cross the boundary,
frames never do).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FarmEngine, LoopOfStencilReduce, farm
from repro.core.executor import auto_unroll, check_unroll_feasible
from repro.core.introspect import flatten_eqns, while_body_eqns
from repro.kernels import ref as R

BACKENDS = ["jnp", "pallas", "pallas-multistep"]


def heat(get, *_):
    lap = (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
           - 4.0 * get(0, 0))
    return get(0, 0) + 0.1 * lap


def mkloop(backend, unroll=1, boundary="reflect", max_iters=60):
    return LoopOfStencilReduce(
        f=heat, k=1, combine="max", cond=lambda r: r < 2e-3,
        delta=R.abs_delta, boundary=boundary, max_iters=max_iters,
        unroll=unroll, backend=backend, interpret=True, block=(32, 128))


def mixed_batch(rng, n=4, shape=(40, 136)):
    """Stacked items with deliberately different convergence speeds."""
    u0 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    scales = (1.0, 5.0, 0.1, 2.0, 0.5, 3.0)
    return jnp.stack([u0 * scales[i % len(scales)] for i in range(n)])


class TestFarmRunParity:
    @pytest.mark.parametrize("backend,unroll", [
        ("jnp", 1), ("pallas", 1), ("pallas", 2),
        ("pallas-multistep", 3)])
    def test_matches_vmapped_run_mixed_trip_counts(self, backend, unroll,
                                                   rng):
        loop = mkloop(backend, unroll)
        batch = mixed_batch(rng)
        want = farm(loop.run)(batch)
        got = loop.farm_run(batch)
        iters = np.asarray(got.iters)
        assert len(set(iters.tolist())) > 1, "want MIXED trip counts"
        np.testing.assert_array_equal(iters, np.asarray(want.iters))
        np.testing.assert_allclose(np.asarray(got.a),
                                   np.asarray(want.a), atol=1e-5)
        np.testing.assert_allclose(np.asarray(got.reduced),
                                   np.asarray(want.reduced), atol=1e-6)

    def test_done0_premasks_lanes(self, rng):
        loop = mkloop("pallas")
        batch = mixed_batch(rng)
        done0 = jnp.asarray([False, True, False, False])
        res = loop.farm_run(batch, done0=done0)
        assert int(res.iters[1]) == 0
        np.testing.assert_allclose(np.asarray(res.a[1]),
                                   np.asarray(batch[1]), atol=0)

    def test_env_fields_per_lane(self, rng):
        loop = LoopOfStencilReduce(
            f=R.restore_taps(2.0), k=1, combine="max",
            cond=lambda r: r < 1e-3, delta=R.abs_delta,
            boundary="reflect", max_iters=24, backend="pallas",
            interpret=True, block=(32, 128))
        batch = mixed_batch(rng, n=3)
        masks = (batch > 1.0).astype(jnp.float32)
        got = loop.farm_run(batch, env=(batch, masks))
        for i in range(3):
            ref = loop.run(batch[i], env=(batch[i], masks[i]))
            assert int(got.iters[i]) == int(ref.iters)
            np.testing.assert_allclose(np.asarray(got.a[i]),
                                       np.asarray(ref.a), atol=1e-5)

    def test_s_variant_and_sharded_rejected(self):
        loop = LoopOfStencilReduce(
            f=heat, cond=lambda r, s: True,
            state_init=lambda: jnp.zeros(()),
            state_update=lambda s, a, it: s)
        with pytest.raises(ValueError, match="-s variant"):
            loop.farm_run(jnp.zeros((2, 8, 128)))
        sharded = LoopOfStencilReduce(
            f=heat, cond=lambda r: True, backend="pallas-sharded",
            partition=object())
        with pytest.raises(ValueError, match="FarmEngine"):
            sharded.farm_run(jnp.zeros((2, 8, 128)))


class TestFarmEngineStream:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_stream_parity_with_per_item_runs(self, backend, rng):
        """5 items through 2 lane slots (2 full rounds + a ragged one):
        every item must match its solo run exactly — the refilled slot
        carries nothing over from the previous occupant."""
        loop = mkloop(backend, unroll=3 if "multistep" in backend else 1)
        items = [np.asarray(x) for x in mixed_batch(rng, n=5)]
        eng = FarmEngine(loop, lanes=2)
        outs = []
        n = eng.run(items, outs.append)
        assert n == 5 and eng.stats["rounds"] == 3
        for it, res in zip(items, outs):
            ref = loop.run(jnp.asarray(it))
            assert int(res.iters) == int(ref.iters)
            np.testing.assert_allclose(np.asarray(res.a),
                                       np.asarray(ref.a), atol=1e-5)

    def test_empty_source_and_oversize_batch(self):
        eng = FarmEngine(mkloop("pallas"), lanes=2)
        assert eng.run(lambda: iter([]), lambda r: None) == 0
        with pytest.raises(ValueError, match="exceeds"):
            eng.round(np.zeros((3, 8, 128), np.float32))

    def test_one_compilation_across_the_stream(self, rng):
        """The whole stream — ragged final round included — must hit ONE
        compilation of the round function: the host pads short batches
        to the lane count, so shapes never change."""
        traces = {"n": 0}

        def counted_heat(get, *_):
            traces["n"] += 1
            return heat(get)

        loop = LoopOfStencilReduce(
            f=counted_heat, k=1, combine="max", cond=lambda r: r < 2e-3,
            delta=R.abs_delta, boundary="zero", max_iters=12,
            backend="pallas", interpret=True, block=(32, 128))
        items = [np.asarray(x) for x in mixed_batch(rng, n=7)]
        eng = FarmEngine(loop, lanes=3)
        n = eng.run(items[:3], lambda r: None)
        assert n == 3
        after_first = traces["n"]
        assert after_first > 0
        n = eng.run(items[3:], lambda r: None)      # incl. ragged round
        assert n == 4
        assert traces["n"] == after_first, \
            f"worker retraced: {traces['n']} != {after_first}"

    def test_host_transfer_is_interior_sized(self, rng):
        """Per item, exactly the (m, n) interior crosses the host
        boundary in each direction (plus the scalar reduce/iters) — the
        (m+2p, n+2p) frames never do."""
        m, n_ = 40, 136
        loop = mkloop("pallas", max_iters=8)
        items = [np.asarray(x) for x in mixed_batch(rng, n=4,
                                                    shape=(m, n_))]
        eng = FarmEngine(loop, lanes=2)
        count = eng.run(items, lambda r: None)
        cell = 4                                   # f32
        want_h2d = eng.stats["rounds"] * 2 * m * n_ * cell
        want_d2h = eng.stats["rounds"] * 2 * (m * n_ * cell + cell + 4)
        assert eng.stats["h2d_bytes"] == want_h2d
        assert eng.stats["d2h_bytes"] == want_d2h
        frame_bytes = (m + 2) * (n_ + 2) * cell
        assert eng.stats["h2d_bytes"] / count < frame_bytes


def _round_jaxpr(backend, rng, unroll=1):
    """Trace one FarmEngine round (slots already bound — this is the
    steady-state 'process item i+1 in an existing slot' program)."""
    loop = mkloop(backend, unroll=unroll, max_iters=8)
    eng = FarmEngine(loop, lanes=2)
    items = np.stack([np.asarray(x) for x in mixed_batch(rng, n=2)])
    eng.round(items)                     # binds + fills the slots
    active = jnp.ones((2,), bool)
    return jax.make_jaxpr(eng._round_impl)(
        eng._frames, eng._env_frames, jnp.asarray(items), active)


class TestLaneSlotReuse:
    """The acceptance criterion, by jaxpr inspection: stream item i+1
    lands in an existing lane slot with no pad, no full-frame copy and
    no re-framing — only the O(interior) refill + ghost refresh."""

    @pytest.mark.parametrize("backend,unroll",
                             [("pallas", 1), ("pallas-multistep", 3)])
    def test_no_pad_no_reframe_in_round(self, backend, unroll, rng):
        jaxpr = _round_jaxpr(backend, rng, unroll)
        eqns = flatten_eqns(jaxpr.jaxpr, [])
        names = [e.primitive.name for e in eqns]
        assert "pad" not in names, "re-framing pad in the streaming round"

        # no re-allocation of the frame stack: nothing materialises a
        # fresh full-frame-sized float array (the bool done-mask select
        # is the only frame-sized broadcast allowed)
        lanes, fh, fw = 2, 42, 138                 # (40,136) + 2*pad
        frame_elems = lanes * fh * fw
        for e in eqns:
            if e.primitive.name in ("broadcast_in_dim", "iota"):
                for v in e.outvars:
                    if (np.issubdtype(v.aval.dtype, np.floating)
                            and int(np.prod(v.aval.shape)) >= frame_elems):
                        raise AssertionError(
                            f"full-frame allocation in round: {e}")

        # every dynamic_update_slice writes at most the interior stack
        # (the refill) — a full-frame copy would exceed it
        interior_elems = lanes * 40 * 136
        for e in eqns:
            if e.primitive.name == "dynamic_update_slice":
                upd = e.invars[1].aval
                assert int(np.prod(upd.shape)) <= interior_elems, \
                    f"super-interior DUS in round: {upd.shape}"

    @pytest.mark.parametrize("backend", ["pallas", "pallas-multistep"])
    def test_while_body_is_the_persistent_kernel(self, backend, rng):
        """Inside the shared while body: the vmapped fused kernel and
        the edge-sized ghost refresh — no pad, no interior-sized copies
        beyond the kernel's own frame round-trip."""
        loop = mkloop(backend, unroll=3 if "multistep" in backend else 1,
                      max_iters=8)
        eng = FarmEngine(loop, lanes=2)
        items = np.stack([np.asarray(x) for x in mixed_batch(rng, n=2)])
        eng.round(items)
        active = jnp.ones((2,), bool)
        eqns = while_body_eqns(
            lambda fr, it, act: eng._round_impl(fr, (), it, act)[2],
            eng._frames, jnp.asarray(items), active)
        names = [e.primitive.name for e in eqns]
        assert "pallas_call" in names
        assert "pad" not in names


def countdown(get, *_):
    """Every cell decrements by 1 per sweep — an item whose max value is
    v converges in EXACTLY v sweeps (cond: max < 0.5), so trip-count
    spreads are programmable per item."""
    return get(0, 0) - 1.0


def mk_countdown(backend, max_iters=256, unroll=1):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=max_iters, unroll=unroll,
        backend=backend, interpret=True, block=(32, 128))


def trip_items(trips, shape=(8, 128)):
    """Stream items with the given per-item trip counts."""
    base = np.linspace(0.1, 0.9, shape[0] * shape[1], dtype=np.float32)
    base = base.reshape(shape)
    return [base + float(t) - 1.0 for t in trips]


SPREADS = {
    "uniform": [6, 6, 6, 6, 6, 6],
    "bimodal": [1, 200, 1, 200, 1, 1],
    "straggler": [2, 2, 2, 200, 2, 2],
}


class TestContinuousFarm:
    """The tentpole acceptance: continuous refill matches the round farm
    and the sequential reference item for item, while strictly cutting
    the done-masked lane sweeps the straggler barrier burns."""

    @pytest.mark.parametrize("spread", list(SPREADS))
    def test_parity_and_waste_drop_jnp(self, spread):
        trips = SPREADS[spread]
        items = trip_items(trips)
        loop = mk_countdown("jnp")

        # sequential reference: farm(run) over the stacked batch
        want = farm(loop.run)(jnp.stack(items))
        np.testing.assert_array_equal(np.asarray(want.iters), trips)

        eng_round = FarmEngine(loop, lanes=2)
        round_outs = []
        assert eng_round.run(items, round_outs.append) == len(items)

        eng_cont = FarmEngine(loop, lanes=2, segment=8)
        cont_outs = []
        assert eng_cont.run(items, cont_outs.append,
                            continuous=True) == len(items)
        cont_outs.sort(key=lambda r: r.index)

        for i, (ro, co) in enumerate(zip(round_outs, cont_outs)):
            assert co.index == i
            assert int(ro.iters) == int(co.iters) == trips[i]
            np.testing.assert_array_equal(np.asarray(ro.a), co.a)
            np.testing.assert_array_equal(np.asarray(want.a[i]), co.a)

        # the metric: total lane sweeps strictly drop whenever the
        # spread gives the barrier something to waste
        assert eng_cont.lane_steps <= eng_round.lane_steps
        if spread != "uniform":
            assert eng_cont.lane_steps < eng_round.lane_steps
            assert (eng_cont.stats["wasted_lane_steps"]
                    < eng_round.stats["wasted_lane_steps"])

    @pytest.mark.parametrize("backend,unroll",
                             [("pallas", 1), ("pallas-multistep", 3)])
    def test_parity_and_waste_drop_pallas(self, backend, unroll):
        trips = [3, 42, 3, 3, 42, 3]
        items = trip_items(trips)
        loop = mk_countdown(backend, max_iters=60, unroll=unroll)

        eng_round = FarmEngine(loop, lanes=2)
        round_outs = []
        assert eng_round.run(items, round_outs.append) == len(items)

        eng_cont = FarmEngine(loop, lanes=2, segment=6)
        cont_outs = []
        assert eng_cont.run(items, cont_outs.append,
                            continuous=True) == len(items)
        cont_outs.sort(key=lambda r: r.index)
        for i, (ro, co) in enumerate(zip(round_outs, cont_outs)):
            assert int(ro.iters) == int(co.iters)
            np.testing.assert_allclose(np.asarray(ro.a), co.a, atol=1e-5)
        assert eng_cont.wasted_lane_steps < eng_round.wasted_lane_steps

    def test_completion_order_beats_the_barrier(self):
        """A 1-sweep item sharing a cohort with a 200-sweep straggler is
        emitted FIRST in continuous mode — the round barrier would hold
        it until the straggler converged."""
        items = trip_items([200, 1, 1, 1])
        eng = FarmEngine(mk_countdown("jnp"), lanes=2, segment=8)
        trips = []
        eng.run(items, lambda r: trips.append(int(r.iters)))
        assert trips == [200, 1, 1, 1]          # barrier: item 0 first
        eng = FarmEngine(mk_countdown("jnp"), lanes=2, segment=8)
        order = []
        eng.run(items, lambda r: order.append(r.index), continuous=True)
        assert order[0] == 1 and order[-1] == 0, order

    def test_one_compilation_across_segments_and_refills(self):
        """The whole continuous stream — every segment, every refill,
        the ragged tail included — hits ONE compilation of each entry
        point (the carry shapes round-trip unchanged)."""
        traces = {"n": 0}

        def counted(get, *_):
            traces["n"] += 1
            return countdown(get)

        loop = LoopOfStencilReduce(
            f=counted, k=1, combine="max", cond=lambda r: r < 0.5,
            boundary="zero", max_iters=64, backend="pallas",
            interpret=True, block=(32, 128))
        eng = FarmEngine(loop, lanes=3, segment=5)
        n = eng.run(trip_items([2, 9, 4, 17, 3, 5, 2]),
                    lambda r: None, continuous=True)
        assert n == 7
        assert eng.stats["segment_traces"] == 1
        # chained + ring-seeded initial cohort: the classic per-slot
        # refill never compiles on a fault-free stream
        assert eng.stats["refill_traces"] == 0
        assert eng.stats["refills"] == 7
        after_first = traces["n"]
        assert after_first > 0
        # a second stream through the same engine state must not retrace
        eng.run(trip_items([4, 2]), lambda r: None, continuous=True)
        assert traces["n"] == after_first, "continuous worker retraced"
        assert eng.stats["segment_traces"] == 1

    def test_ragged_tail_and_empty_source(self):
        eng = FarmEngine(mk_countdown("jnp"), lanes=4, segment=4)
        assert eng.run(lambda: iter([]), lambda r: None,
                       continuous=True) == 0
        outs = []
        assert eng.run(trip_items([5, 2]), outs.append,
                       continuous=True) == 2    # items < lanes
        outs.sort(key=lambda r: r.index)
        assert [int(o.iters) for o in outs] == [5, 2]

    def test_mode_mixing_rejected(self):
        eng = FarmEngine(mk_countdown("jnp"), lanes=2)
        eng.run(trip_items([2, 3]), lambda r: None)
        with pytest.raises(ValueError, match="round mode"):
            eng.run(trip_items([2]), lambda r: None, continuous=True)
        eng = FarmEngine(mk_countdown("jnp"), lanes=2, segment=3)
        eng.run(trip_items([2]), lambda r: None, continuous=True)
        with pytest.raises(ValueError, match="continuous mode"):
            eng.round(np.stack(trip_items([2, 3])))
        with pytest.raises(ValueError, match="segment"):
            FarmEngine(mk_countdown("jnp"), lanes=2, segment=0)

    def test_composed_sharded_continuous_accepted(self):
        """The PR-4 rejection is GONE: a composed (lanes × spatial)
        engine streams continuously — parity and waste are pinned by
        the multi-device matrix in TestComposedContinuous; here the
        1×1-mesh degenerate case runs in process."""
        from repro.core import GridPartition
        mesh = jax.make_mesh((1, 1), ("lanes", "model"))
        part = GridPartition(mesh=mesh, axis_names=("model",),
                             array_axes=(0,))
        loop = LoopOfStencilReduce(
            f=countdown, cond=lambda r: r < 0.5, combine="max",
            backend="pallas-sharded", partition=part, interpret=True,
            block=(32, 128))
        eng = FarmEngine(loop, lanes=1, mesh=mesh, lane_axis="lanes",
                         segment=4)
        outs = []
        assert eng.run(trip_items([3, 5]), outs.append,
                       continuous=True) == 2
        outs.sort(key=lambda r: r.index)
        assert [int(o.iters) for o in outs] == [3, 5]

    def test_sink_exception_does_not_corrupt_the_engine(self):
        """A raising sink degrades each affected item to a failed
        StreamResult on ``dead_letter`` instead of killing the stream
        (the other in-flight slots' items survive), and leaves the
        engine on LIVE buffers — a second run must work (regression:
        a second run crashed on deleted buffers)."""
        eng = FarmEngine(mk_countdown("jnp"), lanes=2, segment=4)

        def boom(r):
            raise RuntimeError("sink failed")
        assert eng.run(trip_items([2, 3, 4]), boom, continuous=True) == 3
        assert eng.stats["sink_errors"] == 3
        failed = [r for r in eng.dead_letter
                  if r.error and "sink failed" in r.error]
        assert sorted(r.index for r in failed) == [0, 1, 2]
        assert all(r.status == "failed" for r in failed)
        outs = []
        assert eng.run(trip_items([2, 3, 4]), outs.append,
                       continuous=True) == 3
        assert sorted(r.index for r in outs) == [0, 1, 2]

    def test_env_fields_survive_refill(self, rng):
        """Per-item env fields ride the continuous refill: every item's
        result must match its solo run with ITS OWN env — a slot that
        kept the previous occupant's env would diverge."""
        loop = LoopOfStencilReduce(
            f=R.restore_taps(2.0), k=1, combine="max",
            cond=lambda r: r < 1e-3, delta=R.abs_delta,
            boundary="reflect", max_iters=24, backend="pallas",
            interpret=True, block=(32, 128))
        items = [np.asarray(x) for x in mixed_batch(rng, n=5)]

        def prep(item):
            return item, (item, (item > 1.0).astype(jnp.float32))

        eng = FarmEngine(loop, lanes=2, prep=prep, segment=6)
        outs = []
        assert eng.run(items, outs.append, continuous=True) == 5
        outs.sort(key=lambda r: r.index)
        for it, res in zip(items, outs):
            a0, envs = prep(jnp.asarray(it))
            ref = loop.run(a0, env=envs)
            assert int(res.iters) == int(ref.iters)
            np.testing.assert_allclose(res.a, np.asarray(ref.a),
                                       atol=1e-5)


def _segment_jaxpr(backend, unroll=1):
    """Trace one steady-state continuous segment (slots bound and the
    carry mid-stream — the program every segment of the stream reuses)."""
    loop = mk_countdown(backend, max_iters=32, unroll=unroll)
    eng = FarmEngine(loop, lanes=2, segment=4)
    eng.run(trip_items([3, 5, 4]), lambda r: None, continuous=True)
    r, it, done, hw = eng._cont_carry
    return eng, jax.make_jaxpr(eng._segment_entry)(
        eng._frames, eng._env_frames, r, it, done, hw)


class TestContinuousJaxpr:
    """The zero-copy claim for the segmented loop, structurally: the
    steady-state segment and the per-slot refill contain no pad, no
    full-frame allocation and no super-interior copies."""

    @pytest.mark.parametrize("backend,unroll",
                             [("pallas", 1), ("pallas-multistep", 3)])
    def test_segment_has_no_pad_or_reframe(self, backend, unroll):
        eng, jaxpr = _segment_jaxpr(backend, unroll)
        eqns = flatten_eqns(jaxpr.jaxpr, [])
        names = [e.primitive.name for e in eqns]
        assert "pad" not in names, "re-framing pad in the segment"
        lanes, (fh, fw) = 2, eng._lspec.frame.shape
        frame_elems = lanes * fh * fw
        for e in eqns:
            if e.primitive.name in ("broadcast_in_dim", "iota"):
                for v in e.outvars:
                    if (np.issubdtype(v.aval.dtype, np.floating)
                            and int(np.prod(v.aval.shape)) >= frame_elems):
                        raise AssertionError(
                            f"full-frame allocation in segment: {e}")

    @pytest.mark.parametrize("backend,unroll",
                             [("pallas", 1), ("pallas-multistep", 3)])
    def test_segment_while_body_is_the_persistent_kernel(self, backend,
                                                         unroll):
        eng, _ = _segment_jaxpr(backend, unroll)
        r, it, done, hw = eng._cont_carry
        eqns = while_body_eqns(
            lambda fr, rr, ii, dd, hh: eng._segment_entry(fr, (), rr, ii,
                                                          dd, hh)[0],
            eng._frames, r, it, done, hw)
        names = [e.primitive.name for e in eqns]
        assert "pallas_call" in names
        assert "pad" not in names

    @pytest.mark.parametrize("backend,unroll",
                             [("pallas", 1), ("pallas-multistep", 3)])
    def test_refill_writes_at_most_one_interior(self, backend, unroll):
        """The per-slot refill: ONE (1, m, n) interior write plus edge-
        strip ghost refreshes — nothing frame-stack-sized materialises,
        no pad, no re-framing."""
        eng, _ = _segment_jaxpr(backend, unroll)
        r, it, done, hw = eng._cont_carry
        item = jnp.asarray(trip_items([3])[0])
        jaxpr = jax.make_jaxpr(eng._refill_impl)(
            eng._frames, eng._env_frames, r, it, done, hw,
            jnp.asarray(0, jnp.int32), item)
        eqns = flatten_eqns(jaxpr.jaxpr, [])
        names = [e.primitive.name for e in eqns]
        assert "pad" not in names, "re-framing pad in the refill"
        spec = eng._lspec.frame
        interior_elems = spec.m * spec.n
        for e in eqns:
            if e.primitive.name == "dynamic_update_slice":
                upd = e.invars[1].aval
                assert int(np.prod(upd.shape)) <= interior_elems, \
                    f"super-interior DUS in refill: {upd.shape}"
            if e.primitive.name in ("broadcast_in_dim", "iota"):
                for v in e.outvars:
                    if (np.issubdtype(v.aval.dtype, np.floating)
                            and int(np.prod(v.aval.shape))
                            >= 2 * np.prod(spec.shape)):
                        raise AssertionError(
                            f"frame-stack allocation in refill: {e}")


class TestEnvStreamItems:
    """Tuple stream items ``(a, *env)`` carry externally produced env
    fields through both modes, and EVERY leaf — env included — is
    guarded against mid-stream shape/dtype drift (regression: only the
    main leaf was checked, so a drifted env leaf reached the jitted
    refill and died as an opaque XLA shape error)."""

    @staticmethod
    def _mkloop():
        return LoopOfStencilReduce(
            f=R.restore_taps(2.0), k=1, combine="max",
            cond=lambda r: r < 1e-3, delta=R.abs_delta,
            boundary="reflect", max_iters=24, backend="pallas",
            interpret=True, block=(32, 128))

    @staticmethod
    def _items(rng, n=5):
        base = [np.asarray(x) for x in mixed_batch(rng, n=n)]
        return [(b, b, (b > 1.0).astype(np.float32)) for b in base]

    @pytest.mark.parametrize("continuous", [False, True])
    def test_tuple_items_match_solo_runs(self, continuous, rng):
        loop = self._mkloop()
        items = self._items(rng)
        eng = FarmEngine(loop, lanes=2, segment=6)
        outs = []
        assert eng.run(items, outs.append, continuous=continuous) == 5
        if continuous:
            outs.sort(key=lambda r: r.index)
        for it, res in zip(items, outs):
            ref = loop.run(jnp.asarray(it[0]),
                           env=(jnp.asarray(it[1]), jnp.asarray(it[2])))
            assert int(res.iters) == int(ref.iters)
            np.testing.assert_allclose(np.asarray(res.a),
                                       np.asarray(ref.a), atol=1e-5)

    @pytest.mark.parametrize("continuous", [False, True])
    def test_env_item_drift_is_guarded(self, continuous, rng):
        """A drifted ENV leaf mid-stream must raise the same loud
        build-a-fresh-FarmEngine error the main leaf gets — not an XLA
        shape error from inside the jitted refill."""
        items = self._items(rng, n=4)
        a2 = items[2]
        bad = items[:2] + [(a2[0], a2[1],
                            np.zeros((8, 8), np.float32))]
        eng = FarmEngine(self._mkloop(), lanes=2, segment=6)
        with pytest.raises(ValueError, match="env stream item.*fresh "
                                             "FarmEngine"):
            eng.run(bad, lambda r: None, continuous=continuous)

    def test_env_item_arity_drift_is_guarded(self, rng):
        items = self._items(rng, n=3)
        bad = items[:2] + [(items[2][0], items[2][1])]   # env leaf lost
        eng = FarmEngine(self._mkloop(), lanes=2, segment=6)
        with pytest.raises(ValueError, match="arity changed"):
            eng.run(bad, lambda r: None, continuous=True)


SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_multidevice(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


SHARDED_PRELUDE = """
import os, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core import FarmEngine, GridPartition, LoopOfStencilReduce
from repro.kernels import ref as R
rng = np.random.default_rng(0)
items = [np.asarray(rng.normal(size=(64, 64)), np.float32) * s
         for s in (1.0, 5.0, 0.1, 2.0, 3.0, 0.5, 4.0)]

def heat(get, *_):
    lap = get(-1,0)+get(1,0)+get(0,-1)+get(0,1)-4.0*get(0,0)
    return get(0,0)+0.1*lap

def mkloop(backend, part=None, unroll=1):
    return LoopOfStencilReduce(
        f=heat, k=1, combine="max", cond=lambda r: r < 2e-3,
        delta=R.abs_delta, boundary="zero", max_iters=40, unroll=unroll,
        backend=backend, partition=part, interpret=True, block=(16, 128))

refs = [mkloop("jnp").run(jnp.asarray(it)) for it in items]

def check(eng):
    outs = []
    n = eng.run(items, outs.append)
    assert n == len(items), n
    for res, ref in zip(outs, refs):
        assert int(res.iters) == int(ref.iters), (res.iters, ref.iters)
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(ref.a),
                                   atol=1e-5)
"""


@pytest.mark.slow
class TestFarmEngineSharded:
    """The 1:1×1:n compositions, in an 8-virtual-device subprocess."""

    def test_lanes_over_data_axis(self):
        out = run_multidevice(SHARDED_PRELUDE + """
mesh = jax.make_mesh((4,), ("data",))
check(FarmEngine(mkloop("pallas"), lanes=4, mesh=mesh))
check(FarmEngine(mkloop("jnp"), lanes=4, mesh=mesh))
print("OKLANES")
""")
        assert "OKLANES" in out

    def test_continuous_lanes_over_data_axis(self):
        """Continuous refill with lanes spread over the mesh: each lane
        shard runs its own segments (no collectives cross the lane
        axis); parity vs the solo runs, every item exactly once."""
        out = run_multidevice(SHARDED_PRELUDE + """
mesh = jax.make_mesh((4,), ("data",))
for backend in ("pallas", "jnp"):
    eng = FarmEngine(mkloop(backend), lanes=4, mesh=mesh, segment=6)
    outs = []
    n = eng.run(items, outs.append, continuous=True)
    assert n == len(items), n
    assert sorted(r.index for r in outs) == list(range(len(items)))
    outs.sort(key=lambda r: r.index)
    for res, ref in zip(outs, refs):
        assert int(res.iters) == int(ref.iters), (res.index, res.iters)
        np.testing.assert_allclose(res.a, np.asarray(ref.a), atol=1e-5)
    assert eng.stats["segment_traces"] == 1
    assert eng.stats["refill_traces"] == 0   # seated through the ring
print("OKCONT")
""")
        assert "OKCONT" in out

    def test_composed_prep_is_halo_aware(self):
        """The lifted composed-mode prep: a stencil-shaped prep (reads
        neighbours across what will become shard boundaries) runs on the
        WHOLE item before the spatial split, so its results match the
        single-device reference exactly."""
        out = run_multidevice(SHARDED_PRELUDE + """
mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))

def prep(item):
    blur = (jnp.roll(item, 1, 0) + jnp.roll(item, -1, 0)
            + jnp.roll(item, 1, 1) + jnp.roll(item, -1, 1) + item) / 5.0
    return blur, (jnp.abs(item) > 1.0,)

def restore(get, mask):
    lap = get(-1,0)+get(1,0)+get(0,-1)+get(0,1)-4.0*get(0,0)
    return get(0,0) + 0.1*lap

def mkrestore(backend, part=None):
    return LoopOfStencilReduce(
        f=restore, k=1, combine="max", cond=lambda r: r < 2e-3,
        delta=R.abs_delta, boundary="zero", max_iters=40,
        backend=backend, partition=part, interpret=True, block=(16, 128))

eng = FarmEngine(mkrestore("pallas-sharded", part), lanes=4, mesh=mesh,
                 prep=prep)
outs = []
n = eng.run(items, outs.append)
assert n == len(items), n
jref = mkrestore("jnp")
for it, res in zip(items, outs):
    a0, envs = prep(jnp.asarray(it))
    ref = jref.run(a0, env=envs)
    assert int(res.iters) == int(ref.iters), (res.iters, ref.iters)
    np.testing.assert_allclose(np.asarray(res.a), np.asarray(ref.a),
                               atol=1e-5)
print("OKPREP")
""")
        assert "OKPREP" in out

    def test_composed_lanes_times_spatial(self):
        """Lanes over 'data' x each lane's frame ppermute-decomposed
        over 'model' — the full two-tier composition, unroll 1 and
        auto."""
        out = run_multidevice(SHARDED_PRELUDE + """
from repro.core.executor import auto_unroll
mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))
check(FarmEngine(mkloop("pallas-sharded", part), lanes=4, mesh=mesh))
# unroll='auto' checks the condition every T sweeps: parity against the
# jnp path at the SAME resolved T (iters overshoot by < T vs unroll=1)
T = auto_unroll(64, 64, k=1, block=(16, 128), part=part)
assert T > 1, T
refs = [mkloop("jnp", unroll=T).run(jnp.asarray(it)) for it in items]
check(FarmEngine(mkloop("pallas-sharded", part, unroll="auto"),
                 lanes=4, mesh=mesh))
print("OKCOMPOSED")
""")
        assert "OKCOMPOSED" in out

    def test_validation(self):
        from repro.core import GridPartition
        mesh = jax.make_mesh((1,), ("data",))
        part = GridPartition(mesh=mesh, axis_names=("data",),
                             array_axes=(0,))
        loop = LoopOfStencilReduce(
            f=heat, cond=lambda r: True, backend="pallas-sharded",
            partition=part)
        with pytest.raises(ValueError, match="mesh="):
            FarmEngine(loop, lanes=2)
        with pytest.raises(ValueError, match="collides"):
            FarmEngine(loop, lanes=1, mesh=mesh, lane_axis="data")
        from types import SimpleNamespace
        fake2 = SimpleNamespace(axis_names=("data",), shape={"data": 2})
        with pytest.raises(ValueError, match="divide"):
            FarmEngine(mkloop("pallas"), lanes=3, mesh=fake2)


COMPOSED_PRELUDE = """
import os, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core import FarmEngine, GridPartition, LoopOfStencilReduce

def countdown(get, *_):
    return get(0, 0) - 1.0

def mk(part, max_iters=256):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=max_iters, backend="pallas-sharded",
        partition=part, interpret=True, block=(16, 128))

def trip_items(trips, shape=(32, 64)):
    base = np.linspace(0.1, 0.9, shape[0] * shape[1],
                       dtype=np.float32).reshape(shape)
    return [base + float(t) - 1.0 for t in trips]

mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))
"""


@pytest.mark.slow
class TestComposedContinuous:
    """The tentpole acceptance on the composed (lanes × spatial)
    deployment, in an 8-virtual-device subprocess: continuous refill
    matches round mode item for item on adversarial trip-count spreads,
    strictly cuts the barrier's wasted lane sweeps on non-uniform
    spreads, compiles once per entry point, and is structurally clean
    (no pad, owner-masked interior-sized refill writes, collectives
    along the SPATIAL axes only — nothing crosses the lane axis)."""

    def test_parity_matrix_and_waste_drop(self):
        out = run_multidevice(COMPOSED_PRELUDE + """
SPREADS = {
    "uniform": [6] * 8,
    "bimodal": [1, 200, 1, 1, 200, 1, 1, 1, 1, 1, 1, 1],
    "straggler": [2, 2, 2, 200, 2, 2, 2, 2],
}
for name, trips in SPREADS.items():
    items = trip_items(trips)
    eng_r = FarmEngine(mk(part), lanes=4, mesh=mesh)
    r_outs = []
    assert eng_r.run(items, r_outs.append) == len(trips)
    eng_c = FarmEngine(mk(part), lanes=4, mesh=mesh, segment=8)
    c_outs = []
    assert eng_c.run(items, c_outs.append, continuous=True) == len(trips)
    assert sorted(r.index for r in c_outs) == list(range(len(trips)))
    c_outs.sort(key=lambda r: r.index)
    for i, (ro, co) in enumerate(zip(r_outs, c_outs)):
        assert int(ro.iters) == int(co.iters) == trips[i], (
            name, i, ro.iters, co.iters)
        np.testing.assert_array_equal(np.asarray(ro.a), co.a)
    assert eng_c.stats["segment_traces"] == 1
    assert eng_c.stats["refill_traces"] == 1
    if name != "uniform":
        assert eng_c.wasted_lane_steps < eng_r.wasted_lane_steps, (
            name, eng_c.wasted_lane_steps, eng_r.wasted_lane_steps)
print("OKMATRIX")
""")
        assert "OKMATRIX" in out

    def test_one_compilation_and_completion_order(self):
        """A straggler sharing the pool with 1-sweep items must NOT gate
        their emission, and a second stream through the same engine must
        not retrace."""
        out = run_multidevice(COMPOSED_PRELUDE + """
eng = FarmEngine(mk(part), lanes=4, mesh=mesh, segment=4)
order = []
n = eng.run(trip_items([200, 1, 1, 1, 1, 1]),
            lambda r: order.append(r.index), continuous=True)
assert n == 6, n
assert order[-1] == 0, order       # the straggler emits LAST
assert eng.stats["segment_traces"] == 1
assert eng.stats["refill_traces"] == 1
eng.run(trip_items([2, 3]), lambda r: None, continuous=True)
assert eng.stats["segment_traces"] == 1    # no retrace across streams
assert eng.stats["refill_traces"] == 1
print("OKORDER")
""")
        assert "OKORDER" in out

    def test_steady_state_jaxpr_is_pad_free_and_lane_local(self):
        out = run_multidevice(COMPOSED_PRELUDE + """
from repro.core.introspect import flatten_eqns
eng = FarmEngine(mk(part), lanes=4, mesh=mesh, segment=4)
eng.run(trip_items([3, 5, 4, 2, 6]), lambda r: None, continuous=True)
r, it, done, hw = eng._cont_carry

def collective_axes(eqns):
    axes = set()
    for e in eqns:
        if e.primitive.name in ("ppermute", "psum", "pmax", "pmin",
                                "all_gather", "all_to_all",
                                "reduce_scatter"):
            ax = e.params.get("axis_name", e.params.get("axes", ()))
            if not isinstance(ax, (tuple, list)):
                ax = (ax,)
            axes.update(a for a in ax if isinstance(a, str))
    return axes

# the steady-state SEGMENT: no pad, ghost exchange along the spatial
# axis only, nothing along the lane axis
jaxpr = jax.make_jaxpr(eng._segment_entry)(
    eng._frames, eng._env_frames, r, it, done, hw)
seg = flatten_eqns(jaxpr.jaxpr, [])
names = [e.primitive.name for e in seg]
assert "pad" not in names, "re-framing pad in the composed segment"
axes = collective_axes(seg)
assert "model" in axes, axes
assert "data" not in axes, ("cross-lane collective in segment", axes)

# the per-slot REFILL: no pad, owner-masked writes at most one LOCAL
# interior each, and again no lane-axis collective
item = jnp.asarray(trip_items([3])[0])
jaxpr = jax.make_jaxpr(eng._refill_impl)(
    eng._frames, eng._env_frames, r, it, done, hw,
    jnp.asarray(0, jnp.int32), item)
ref = flatten_eqns(jaxpr.jaxpr, [])
names = [e.primitive.name for e in ref]
assert "pad" not in names, "re-framing pad in the composed refill"
axes = collective_axes(ref)
assert "data" not in axes, ("cross-lane collective in refill", axes)
spec = eng._lspec.local
interior = spec.m * spec.n
for e in ref:
    if e.primitive.name == "dynamic_update_slice":
        upd = e.invars[1].aval
        assert int(np.prod(upd.shape)) <= interior, upd.shape
print("OKJAXPR")
""")
        assert "OKJAXPR" in out

    def test_continuous_prep_and_env_refill(self):
        """Halo-aware prep + per-item env slots ride the composed
        continuous refill: every item must match its solo run with ITS
        OWN env (a slot keeping the previous occupant's env — or a
        non-owner shard clobbering a live slot — would diverge)."""
        out = run_multidevice(SHARDED_PRELUDE + """
mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))

def prep(item):
    blur = (jnp.roll(item, 1, 0) + jnp.roll(item, -1, 0)
            + jnp.roll(item, 1, 1) + jnp.roll(item, -1, 1) + item) / 5.0
    return blur, (jnp.abs(item) > 1.0,)

def restore(get, mask):
    lap = get(-1,0)+get(1,0)+get(0,-1)+get(0,1)-4.0*get(0,0)
    return get(0,0) + 0.1*lap

def mkrestore(backend, part=None):
    return LoopOfStencilReduce(
        f=restore, k=1, combine="max", cond=lambda r: r < 2e-3,
        delta=R.abs_delta, boundary="zero", max_iters=40,
        backend=backend, partition=part, interpret=True, block=(16, 128))

eng = FarmEngine(mkrestore("pallas-sharded", part), lanes=4, mesh=mesh,
                 prep=prep, segment=6)
outs = []
n = eng.run(items, outs.append, continuous=True)
assert n == len(items), n
outs.sort(key=lambda r: r.index)
jref = mkrestore("jnp")
for it, res in zip(items, outs):
    a0, envs = prep(jnp.asarray(it))
    ref = jref.run(a0, env=envs)
    assert int(res.iters) == int(ref.iters), (res.iters, ref.iters)
    np.testing.assert_allclose(res.a, np.asarray(ref.a), atol=1e-5)
print("OKPREPCONT")
""")
        assert "OKPREPCONT" in out


class TestAutoUnroll:
    def test_respects_local_feasibility_ceiling(self):
        class FakeMesh:
            shape = {"data": 8}

        class FakePart:
            mesh = FakeMesh()
            axis_names = ("data",)
            array_axes = (0,)
            shards = (8,)

        # 8 shards of a 64-row grid: local m = 8, so k·T < 8
        T = auto_unroll(64, 64, k=1, part=FakePart())
        assert 1 <= T < 8
        # single device, roomy grid: deeper blocking is allowed
        assert auto_unroll(512, 512, k=1) >= T

    def test_infeasible_explicit_T_raises_with_context(self):
        class FakeMesh:
            shape = {"data": 8}

        class FakePart:
            mesh = FakeMesh()
            axis_names = ("data",)
            array_axes = (0,)
            shards = (8,)

        with pytest.raises(ValueError, match="T <= 7"):
            check_unroll_feasible(64, 64, 8, k=1, part=FakePart())
        check_unroll_feasible(64, 64, 4, k=1, part=FakePart())  # fine

    def test_auto_resolves_on_run(self, rng):
        loop = mkloop("pallas-multistep", unroll="auto", max_iters=12)
        a = jnp.asarray(rng.normal(size=(40, 136)), jnp.float32)
        res = loop.run(a)
        T = auto_unroll(40, 136, k=1, block=(32, 128))
        assert T > 1
        ref = mkloop("jnp", unroll=T, max_iters=12).run(a)
        assert int(res.iters) == int(ref.iters)
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(ref.a),
                                   atol=1e-4)

    def test_bad_unroll_rejected(self):
        with pytest.raises(ValueError, match="unroll"):
            mkloop("pallas", unroll=0)
        with pytest.raises(ValueError, match="unroll"):
            mkloop("pallas", unroll="deep")
