"""pallas-sharded backend: distributed ≡ single-device, zero-copy body.

Parity: the 1:n persistent deployment (per-shard halo frames inside
shard_map, ppermute ghost exchange, monoid collectives) must match the
single-device "jnp" and "pallas" backends — values, reduce, iteration
counts — across 1-D and 2-D decompositions, all four ⊥ models,
sum/max/any monoids, and unroll ∈ {1, 4} (deep-halo temporal blocking).

Zero-copy/communication-avoiding: jaxpr inspection of the sharded
while_loop body shows no ``pad``, no array-sized ``concatenate``, no
full-block ``dynamic_slice`` — only edge-strip traffic — and unroll=4
issues the same ppermute rounds per *body* as unroll=1 while advancing
4 sweeps: 1/4 the exchanges per sweep.

Multi-device tests run in a SUBPROCESS with 8 placeholder host devices so
the main test process keeps the single-device view.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_multidevice(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import LoopOfStencilReduce, GridPartition
from repro.kernels import ref as R
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)

def heat(get, *_):
    lap = get(-1,0)+get(1,0)+get(0,-1)+get(0,1)-4.0*get(0,0)
    return get(0,0)+0.1*lap

def loop(backend, boundary="zero", unroll=1, part=None, combine="max",
         cond=None, delta=R.abs_delta, max_iters=12):
    cond = cond or (lambda r: r < 2e-3)
    return LoopOfStencilReduce(
        f=heat, k=1, combine=combine, cond=cond, delta=delta,
        boundary=boundary, max_iters=max_iters, unroll=unroll,
        backend=backend, partition=part, interpret=True, block=(16, 128))

def check(want, got, boundary):
    assert int(want.iters) == int(got.iters), (want.iters, got.iters)
    wa, ga = np.asarray(want.a), np.asarray(got.a)
    if boundary == "nan":
        # NaN ⊥ poisons a k-per-sweep deep border: the poisoned REGION
        # must match cell-for-cell, and the surviving interior must agree
        np.testing.assert_array_equal(np.isnan(ga), np.isnan(wa))
        np.testing.assert_allclose(ga[~np.isnan(ga)], wa[~np.isnan(wa)],
                                   atol=1e-5)
        return
    np.testing.assert_allclose(ga, wa, atol=1e-5)
    np.testing.assert_allclose(float(got.reduced), float(want.reduced),
                               atol=1e-5)

part1d = lambda: GridPartition(mesh=jax.make_mesh((8,), ("data",)),
                               axis_names=("data",), array_axes=(0,))
part2d = lambda: GridPartition(mesh=jax.make_mesh((4, 2), ("data", "model")),
                               axis_names=("data", "model"),
                               array_axes=(0, 1))
"""


@pytest.mark.slow
class TestShardedParity:
    def test_1d_all_boundaries_both_unrolls(self):
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            part = part1d()
            for boundary in ("zero", "nan", "reflect", "wrap"):
                for unroll in (1, 4):
                    want = loop("pallas", boundary, unroll).run(a)
                    got = loop("pallas-sharded", boundary, unroll,
                               part).run(a)
                    check(want, got, boundary)
            # termination parity: a tolerance the loop actually reaches
            w = loop("jnp", "reflect", 1, max_iters=400,
                     cond=lambda r: r < 2e-2).run(a)
            g = loop("pallas-sharded", "reflect", 1, part, max_iters=400,
                     cond=lambda r: r < 2e-2).run(a)
            assert int(w.iters) < 400, int(w.iters)
            check(w, g, "reflect")
            print("OK1D")
        """))
        assert "OK1D" in out

    def test_2d_decomposition_and_monoids(self):
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            part = part2d()
            for boundary in ("zero", "nan", "reflect", "wrap"):
                for unroll in (1, 4):
                    want = loop("pallas", boundary, unroll).run(a)
                    got = loop("pallas-sharded", boundary, unroll,
                               part).run(a)
                    check(want, got, boundary)
            # sum / any monoids against BOTH single-device backends
            for comb, cond, delta in (
                ("sum", lambda r: r < 1.0, R.abs_delta),
                ("any", lambda r: ~r,
                 lambda n, o: jnp.abs(n - o) > 1e-3),
            ):
                for unroll in (1, 4):
                    wj = loop("jnp", "zero", unroll, combine=comb,
                              cond=cond, delta=delta).run(a)
                    wp = loop("pallas", "zero", unroll, combine=comb,
                              cond=cond, delta=delta).run(a)
                    g = loop("pallas-sharded", "zero", unroll, part,
                             combine=comb, cond=cond, delta=delta).run(a)
                    check(wj, g, "zero")
                    check(wp, g, "zero")
            print("OK2D")
        """))
        assert "OK2D" in out

    def test_env_fields_and_apps(self):
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            from repro.kernels import ops
            fxy = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
            u0 = jnp.zeros((64, 64), jnp.float32)
            kw = dict(alpha=2.0, dx=0.2, tol=1e-4, max_iters=200)
            ur, dr, ir = ops.jacobi_solve(u0, fxy, backend="jnp", **kw)
            us, ds, is_ = ops.jacobi_solve(u0, fxy, part=part1d(), **kw)
            u4, d4, i4 = ops.jacobi_solve(u0, fxy, part=part2d(),
                                          unroll=4, **kw)
            assert int(ir) == int(is_), (ir, is_)
            assert int(ir) <= int(i4) < int(ir) + 4    # unroll overshoot
            np.testing.assert_allclose(np.asarray(us), np.asarray(ur),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(u4), np.asarray(ur),
                                       atol=1e-4)
            print("OKENV")
        """))
        assert "OKENV" in out

    def test_distributed_front_end_sharded_backend(self):
        """distributed_loop_of_stencil_reduce(backend='pallas-sharded')
        delegates to the engine and matches its own jnp path."""
        out = run_multidevice(PRELUDE + textwrap.dedent("""
            from repro.core import distributed_loop_of_stencil_reduce
            part = part1d()
            kw = dict(k=1, part=part, delta=R.abs_delta, max_iters=12,
                      boundary="reflect")
            dj = distributed_loop_of_stencil_reduce(
                heat, "max", lambda r: r < 2e-3, a, **kw)
            dp = distributed_loop_of_stencil_reduce(
                heat, "max", lambda r: r < 2e-3, a,
                backend="pallas-sharded", block=(16, 128),
                interpret=True, **kw)
            assert int(dj.iters) == int(dp.iters)
            np.testing.assert_allclose(np.asarray(dp.a), np.asarray(dj.a),
                                       atol=1e-5)
            print("OKFRONT")
        """))
        assert "OKFRONT" in out


JAXPR_HELPERS = """
from repro.core.introspect import while_body_eqns, max_outsize as outsize
"""


@pytest.mark.slow
class TestShardedZeroCopy:
    def test_no_staging_ops_and_ppermute_rounds(self):
        """The acceptance criterion, by jaxpr inspection: the sharded
        while body holds no pad, no array-sized concatenate, no
        full-block dynamic_slice; unroll=4 issues <= the ppermute
        rounds of unroll=1 per body while advancing 4 sweeps (=> 1/4
        the ICI messages per sweep)."""
        out = run_multidevice(PRELUDE + JAXPR_HELPERS + textwrap.dedent("""
            part = part1d()
            BLOCK = (64 // 8) * 64          # one shard's domain cells

            def counts(unroll, boundary):
                fn = lambda x: loop("pallas-sharded", boundary, unroll,
                                    part).run(x).a
                eqns = while_body_eqns(fn, a)
                names = [e.primitive.name for e in eqns]
                assert "pallas_call" in names
                assert "pad" not in names, f"pad in body ({boundary})"
                big_cat = [e for e in eqns
                           if e.primitive.name == "concatenate"
                           and outsize(e) >= BLOCK]
                assert not big_cat, "array-sized concatenate in body"
                big_ds = [e for e in eqns
                          if e.primitive.name == "dynamic_slice"
                          and outsize(e) >= BLOCK]
                assert not big_ds, "full-block dynamic_slice in body"
                return names.count("ppermute")

            for boundary in ("zero", "reflect", "wrap"):
                c1 = counts(1, boundary)
                c4 = counts(4, boundary)
                assert c1 > 0
                # same rounds per body, 4 sweeps per body => 1/4 per sweep
                assert c4 <= c1, (c4, c1)
                assert c4 / 4 <= c1 / 4
            print("OKZC")
        """))
        assert "OKZC" in out


class TestShardedValidation:
    def test_partition_required(self):
        import jax.numpy as jnp
        from repro.core import LoopOfStencilReduce
        with pytest.raises(ValueError, match="partition"):
            LoopOfStencilReduce(f=lambda g: g.center,
                                cond=lambda r: True,
                                backend="pallas-sharded")

    def test_uneven_decomposition_rejected(self):
        import jax.numpy as jnp
        from types import SimpleNamespace
        from repro.core import LoopOfStencilReduce
        # duck-typed partition: the divisibility check runs before any
        # mesh machinery, so a stub with a 3-way axis suffices
        part = SimpleNamespace(
            mesh=SimpleNamespace(shape={"data": 3}),
            axis_names=("data",), array_axes=(0,))
        loop = LoopOfStencilReduce(
            f=lambda g: g.center, cond=lambda r: True,
            backend="pallas-sharded", partition=part)
        with pytest.raises(ValueError, match="divide"):
            loop.run(jnp.zeros((8, 128), jnp.float32))

    def test_state_variant_rejected(self):
        import jax
        import jax.numpy as jnp
        from repro.core import GridPartition, LoopOfStencilReduce
        mesh = jax.make_mesh((1,), ("data",))
        part = GridPartition(mesh=mesh, axis_names=("data",),
                             array_axes=(0,))
        loop = LoopOfStencilReduce(
            f=lambda g: g.center, cond=lambda r, s: True,
            state_init=lambda: jnp.zeros(()),
            state_update=lambda s, a, it: s,
            backend="pallas-sharded", partition=part)
        with pytest.raises(ValueError, match="-s variant"):
            loop.run(jnp.zeros((8, 128), jnp.float32))


class TestBoundaryPadDedup:
    """halo's per-axis ⊥ padding now routes through Boundary.pad(axes=)
    — one helper, three call sites (semantics, TapAccessor, halo)."""

    @pytest.mark.parametrize("boundary", ["zero", "nan", "reflect", "wrap"])
    def test_axes_subset_matches_full_pad(self, boundary, rng):
        import jax.numpy as jnp
        from repro.core.semantics import Boundary
        a = jnp.asarray(rng.normal(size=(6, 7)), jnp.float32)
        b = Boundary(boundary)
        full = np.asarray(b.pad(a, 2))
        only0 = np.asarray(b.pad(a, 2, axes=(0,)))
        assert only0.shape == (10, 7)
        np.testing.assert_array_equal(only0, full[:, 2:-2])
        both = np.asarray(b.pad(a, 2, axes=(0, 1)))
        np.testing.assert_array_equal(both, full)

    def test_no_axes_is_identity(self, rng):
        import jax.numpy as jnp
        from repro.core.semantics import Boundary
        a = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
        out = Boundary("reflect").pad(a, 3, axes=())
        np.testing.assert_array_equal(np.asarray(out), np.asarray(a))
