"""Chained dispatch — device-resident segment chaining + staging ring.

The contracts of ``FarmEngine(chained=True)`` (the default):

  bit-identity     — on a fault-free stream the chained pipeline emits
                     the SAME results (payload, reduced, iters, status,
                     order of indexes per slot) as ``chained=False``
  exactly-once     — every index emits exactly one StreamResult
  one compilation  — the fused ``_chain_fn`` entry traces ONCE across a
                     ragged stream (and across a second stream through
                     the same engine), as do staging and the classic
                     refill used for the initial fill
  no host sync     — in steady state the drain of segment t reads its
                     metadata only AFTER segment t+1 is dispatched, one
                     ``_meta_read`` per drained segment, and never
                     touches device arrays element-wise
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FarmEngine, LoopOfStencilReduce
from repro.core.executor import auto_unroll
from repro.core.frames import (refill_lane_frames,
                               refill_lanes_env_masked,
                               refill_lanes_masked, stage_ring_write)


def countdown(get, *_):
    return get(0, 0) - 1.0


def mk_countdown(max_iters=64, backend="jnp"):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=max_iters, backend=backend,
        interpret=True, block=(32, 128))


def trip_items(trips, shape=(8, 128)):
    base = np.linspace(0.1, 0.9, shape[0] * shape[1],
                       dtype=np.float32).reshape(shape)
    return [base + float(t) - 1.0 for t in trips]


TRIPS = [3, 9, 5, 7, 4, 6, 2, 8, 5, 3, 11, 2]


def stream(eng, items, **kw):
    got = {}

    def sink(r):
        assert r.index not in got, f"duplicate emission for {r.index}"
        got[r.index] = r

    n = eng.run_continuous(items, sink, **kw)
    assert n == len(got)
    return got


# ---------------------------------------------------------------------------
# bit-identity + exactly-once
# ---------------------------------------------------------------------------


class TestBitIdentity:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_chained_matches_synchronous(self, backend):
        items = trip_items(TRIPS)
        got_c = stream(FarmEngine(mk_countdown(backend=backend),
                                  lanes=4, segment=4), items)
        got_s = stream(FarmEngine(mk_countdown(backend=backend),
                                  lanes=4, segment=4, chained=False),
                       items)
        assert set(got_c) == set(got_s) == set(range(len(items)))
        for i in got_c:
            assert got_c[i].status == got_s[i].status == "ok"
            assert int(got_c[i].iters) == int(got_s[i].iters)
            np.testing.assert_array_equal(np.asarray(got_c[i].a),
                                          np.asarray(got_s[i].a))
            np.testing.assert_array_equal(
                np.asarray(got_c[i].reduced),
                np.asarray(got_s[i].reduced))

    def test_stats_parity_with_synchronous(self):
        items = trip_items(TRIPS)
        eng_c = FarmEngine(mk_countdown(), lanes=4, segment=4)
        eng_s = FarmEngine(mk_countdown(), lanes=4, segment=4,
                           chained=False)
        stream(eng_c, items)
        stream(eng_s, items)
        # same refill count; the chained pipeline may run extra
        # (zero-step, early-exited) trailing segments but never fewer
        assert eng_c.stats["refills"] == eng_s.stats["refills"]
        assert eng_c.stats["segments"] >= eng_s.stats["segments"]
        # lane-step waste identical: the chain freezes finished lanes
        # exactly as the synchronous loop does
        assert (eng_c.stats["wasted_lane_steps"]
                == eng_s.stats["wasted_lane_steps"])

    def test_single_item_and_single_lane(self):
        got = stream(FarmEngine(mk_countdown(), lanes=1, segment=4),
                     trip_items([5]))
        assert set(got) == {0} and got[0].status == "ok"
        assert int(got[0].iters) == 5


# ---------------------------------------------------------------------------
# one compilation across a ragged stream (and a second stream)
# ---------------------------------------------------------------------------


class TestTraceCounts:
    def test_one_compilation_across_ragged_streams(self):
        eng = FarmEngine(mk_countdown(), lanes=4, segment=4)
        stream(eng, trip_items(TRIPS))
        assert eng.stats["chain_traces"] == 1
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["stage_traces"] == 1
        # the initial cohort seats through the ring too — the classic
        # per-slot refill never even compiles on a fault-free stream
        assert eng.stats["refill_traces"] == 0
        # a SECOND ragged stream through the same engine: zero retraces
        stream(eng, trip_items([4, 1, 6, 2, 9]))
        assert eng.stats["chain_traces"] == 1
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["stage_traces"] == 1
        assert eng.stats["refill_traces"] == 0

    def test_synchronous_path_never_traces_the_chain(self):
        eng = FarmEngine(mk_countdown(), lanes=4, segment=4,
                         chained=False)
        stream(eng, trip_items(TRIPS[:6]))
        assert eng.stats["chain_traces"] == 0
        assert eng.stats["stage_traces"] == 0
        assert eng.stats["segment_traces"] == 1


# ---------------------------------------------------------------------------
# steady-state no-host-sync guard
# ---------------------------------------------------------------------------


class TestNoHostSync:
    def test_drain_reads_only_after_next_dispatch(self):
        """The pipeline contract itself: every steady-state segment's
        ONE metadata read happens strictly AFTER the next segment is
        already dispatched (the device never waits on the host), and
        there is exactly one ``_meta_read`` per drained segment."""
        eng = FarmEngine(mk_countdown(), lanes=4, segment=4)
        events = []
        chain_fn, meta_read = eng._chain_fn, eng._meta_read

        def spy_chain(*a, **k):
            events.append("dispatch")
            return chain_fn(*a, **k)

        def spy_read(*a):
            events.append("read")
            return meta_read(*a)

        eng._chain_fn, eng._meta_read = spy_chain, spy_read
        try:
            stream(eng, trip_items(TRIPS))
        finally:
            eng._chain_fn, eng._meta_read = chain_fn, meta_read
        n_dispatch = events.count("dispatch")
        n_read = events.count("read")
        assert n_dispatch == eng.stats["segments"] > 0
        assert n_read == n_dispatch     # one read per drained segment
        # read i drains segment i; dispatch i+1 must precede it for
        # every non-tail segment (the tail has nothing left to overlap)
        reads_seen = 0
        for j, ev in enumerate(events):
            if ev != "read":
                continue
            reads_seen += 1
            dispatches_before = events[:j].count("dispatch")
            if reads_seen < n_read:     # steady state (non-tail)
                assert dispatches_before >= reads_seen + 1, (
                    f"segment {reads_seen} was drained before segment "
                    f"{reads_seen + 1} dispatched: {events[:j + 1]}")

    def test_zero_blocking_reads_outside_meta_read(self):
        """_CountingArray-style transfer counter: every per-segment
        metadata pull of the chained drain funnels through ONE
        ``_meta_read`` call — element indexing of device arrays (one
        blocking transfer per slot, the classic loop's cost model)
        never happens."""
        eng = FarmEngine(mk_countdown(), lanes=4, segment=4)
        meta_read = eng._meta_read
        counts = {"reads": 0, "arrays": 0}

        class _NoTouch:
            """Wraps one drained metadata array: whole-array conversion
            is the sanctioned (already-on-host) access; per-element
            device indexing is the regression."""

            def __init__(self, arr):
                self._arr = np.asarray(arr)
                counts["arrays"] += 1

            def __array__(self, dtype=None, copy=None):
                return (self._arr if dtype is None
                        else self._arr.astype(dtype))

            def __getattr__(self, name):
                return getattr(self._arr, name)

            def __getitem__(self, i):
                return self._arr[i]     # host-side numpy by now

        def spy_read(*arrs):
            counts["reads"] += 1
            return tuple(_NoTouch(a) for a in meta_read(*arrs))

        eng._meta_read = spy_read
        try:
            got = stream(eng, trip_items(TRIPS))
        finally:
            eng._meta_read = meta_read
        assert set(got) == set(range(len(TRIPS)))
        assert counts["reads"] == eng.stats["segments"]
        # the whole drain decision state crosses as ONE packed int32
        # vector per segment — not one transfer per metadata field
        assert counts["arrays"] == counts["reads"]


# ---------------------------------------------------------------------------
# frames-level units: masked batch refill + staging ring
# ---------------------------------------------------------------------------


class TestFrameUnits:
    def test_stage_ring_write_and_gather(self):
        ring = jnp.zeros((4, 3, 3), jnp.float32)
        for i in range(5):      # wraps: position 0 written twice
            ring = stage_ring_write(
                ring, jnp.full((3, 3), float(i + 1)), i % 4)
        np.testing.assert_array_equal(
            np.asarray(ring)[:, 0, 0], [5.0, 2.0, 3.0, 4.0])
        pos = jnp.asarray([2, 0, 1])
        np.testing.assert_array_equal(
            np.asarray(ring[pos])[:, 0, 0], [3.0, 5.0, 2.0])

    def test_refill_lanes_masked_matches_per_slot(self):
        from repro.core.frames import frame_spec
        spec = frame_spec(8, 128, k=1, block=(8, 128))
        lanes, p = 3, spec.pad
        rng = np.random.default_rng(1)
        frames = jnp.asarray(rng.normal(size=(lanes, *spec.shape)),
                             jnp.float32)
        fresh = jnp.asarray(rng.normal(size=(lanes, 8, 128)),
                            jnp.float32)
        take = jnp.asarray([True, False, True])
        got = refill_lanes_masked(frames, take, fresh, spec, "zero")
        # reference: keep the untaken lane's interior, refresh ALL
        # ghosts (exactly what the classic per-slot refill's vmapped
        # refresh does to bystander lanes)
        cur = frames[:, p:p + 8, p:p + 128]
        ref_interiors = jnp.where(take[:, None, None], fresh, cur)
        ref = refill_lane_frames(frames, ref_interiors, spec, "zero")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        # the untaken lane's interior is bit-untouched
        np.testing.assert_array_equal(
            np.asarray(got)[1, p:p + 8, p:p + 128],
            np.asarray(frames)[1, p:p + 8, p:p + 128])
        # the taken lanes carry the fresh interiors
        np.testing.assert_array_equal(
            np.asarray(got)[0, p:p + 8, p:p + 128],
            np.asarray(fresh)[0])

    def test_refill_lanes_env_masked_non_halo(self):
        from repro.core.frames import frame_spec
        spec = frame_spec(8, 128, k=1, block=(8, 128))
        mi, ni = spec.interior
        rng = np.random.default_rng(2)
        env = jnp.asarray(rng.normal(size=(3, mi, ni)), jnp.float32)
        fresh = jnp.asarray(rng.normal(size=(3, 8, 128)), jnp.float32)
        take = jnp.asarray([False, True, False])
        got = refill_lanes_env_masked(env, take, fresh, spec, "zero",
                                      halo=False)
        np.testing.assert_array_equal(np.asarray(got)[1, :8, :128],
                                      np.asarray(fresh)[1])
        np.testing.assert_array_equal(np.asarray(got)[0, :8, :128],
                                      np.asarray(env)[0, :8, :128])


# ---------------------------------------------------------------------------
# auto_unroll folds the segment length in (dispatch amortization)
# ---------------------------------------------------------------------------


class TestAutoUnrollSegmentFold:
    def test_segment_fold_raises_T_in_dispatch_bound_regime(self):
        base = auto_unroll(64, 512, k=1, block=(32, 128))
        folded = auto_unroll(64, 512, k=1, block=(32, 128), segment=4)
        assert folded >= base
        # 4-step segments amortize one dispatch over segment*T sweeps;
        # the default 64-sweep target wants T up toward 16, capped at 8
        assert folded == 8

    def test_segment_fold_respects_feasibility(self):
        # tiny local domain: k*T < min(lm, ln) still binds, whatever
        # the amortization target asks for
        T = auto_unroll(6, 512, k=1, block=(32, 128), segment=1)
        assert T * 1 < 6
        assert T == auto_unroll(6, 512, k=1, block=(32, 128),
                                segment=1, dispatch_amortize=10_000)

    def test_no_segment_means_no_fold(self):
        assert (auto_unroll(64, 512, k=1, block=(32, 128))
                == auto_unroll(64, 512, k=1, block=(32, 128),
                               segment=None))

    def test_amortized_segment_left_alone(self):
        base = auto_unroll(64, 512, k=1, block=(32, 128))
        assert auto_unroll(64, 512, k=1, block=(32, 128), segment=256,
                           dispatch_amortize=64) == base


# ---------------------------------------------------------------------------
# repair mode (retries) and drained snapshot boundaries
# ---------------------------------------------------------------------------


class TestChainedResilience:
    def test_retry_repair_recovers_everything(self):
        """Faulted slots push entries onto the retry queue; the chain
        drops to synchronous repair (ring rewound, classic admission),
        recovers every item bit-identically, then resumes — still one
        compilation per entry point."""
        from repro.core.reduce import Sentinel
        from repro.resilience import FaultPlan

        clean = LoopOfStencilReduce(
            f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
            boundary="zero", max_iters=32, backend="jnp",
            interpret=True, block=(32, 128),
            sentinel=Sentinel(nan=True, patience=3))
        plan = FaultPlan(lanes=4, nan_events=((1, 2),),
                         stall_events=((2, 1 << 20),))
        items = trip_items(TRIPS[:8])
        ref = stream(FarmEngine(clean, lanes=4, segment=4), items)
        eng = FarmEngine(plan.instrument(clean), lanes=4, segment=4,
                         max_attempts=3, slot_patience=2)
        got = stream(eng, items)
        assert all(r.status == "ok" for r in got.values()), {
            i: r.status for i, r in got.items()}
        for i, r in got.items():
            np.testing.assert_array_equal(r.a, ref[i].a)
        assert eng.stats["retries"] > 0
        assert eng.stats["chain_traces"] == 1
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["refill_traces"] == 1  # the repair-mode seats

    def test_preempt_resume_keeps_staged_entries(self, tmp_path):
        """A preemption with items sitting in the staging ring (staged
        but not yet seated): the snapshot's queued list carries them,
        and the resumed run emits every index exactly once."""
        from repro.resilience import FaultPlan, PreemptionError
        from repro.resilience.recovery import RecoveryConfig

        trips = [3, 9, 5, 12, 7, 4, 10, 6, 8, 2, 6, 3]
        items = trip_items(trips)
        ref = stream(FarmEngine(mk_countdown(), lanes=2, segment=2),
                     items)
        rec = RecoveryConfig(dir=str(tmp_path), snapshot_every=1,
                             fsync=False)
        plan = FaultPlan(lanes=2, preempt_at_segment=3)
        # stage_depth=8: at the kill point several pulled-ahead items
        # live ONLY in the ring — the snapshot must not lose them
        eng = FarmEngine(mk_countdown(), lanes=2, segment=2,
                         stage_depth=8)
        got0 = {}
        with pytest.raises(PreemptionError):
            eng.run_continuous(
                items, lambda r: got0.__setitem__(r.index, r),
                recovery=rec,
                on_segment=plan.preempt_hook(mode="raise"))
        eng2 = FarmEngine(mk_countdown(), lanes=2, segment=2)
        got = stream(eng2, items, recovery=rec, resume=True)
        assert sorted(got) == list(range(len(items)))
        for i in range(len(items)):
            assert got[i].status == "ok"
            np.testing.assert_array_equal(got[i].a, ref[i].a)
            assert int(got[i].iters) == int(ref[i].iters)


# ---------------------------------------------------------------------------
# serve twin: chained engine matches the synchronous dispatcher
# ---------------------------------------------------------------------------


class TestServeChained:
    def test_batcher_chained_matches_synchronous(self, rng):
        from repro.configs import get_reduced
        from repro.models import transformer as T
        from repro.serve import GenerateConfig
        from repro.serve.batcher import Batcher, Request

        cfg = get_reduced("qwen3-1.7b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        gcfg = GenerateConfig(max_new_tokens=10, eos_id=1,
                              temperature=0.0)
        reqs = [Request(rid=i, prompt=np.asarray(
            rng.integers(2, cfg.vocab_size, 3 + i % 4), np.int32))
            for i in range(7)]

        def drain(chained):
            b = Batcher(cfg, params, gcfg, max_batch=3,
                        cache_dtype=jnp.float32)
            for r in reqs:
                b.submit(Request(rid=r.rid, prompt=r.prompt.copy()))
            res = b.run_continuous(chained=chained)
            eng = b.engines[0]
            return {r.rid: r for r in res}, eng

        got_s, eng_s = drain(False)
        got_c, eng_c = drain(True)
        assert set(got_c) == set(got_s) == set(range(7))
        for rid in got_c:
            assert got_c[rid].status == got_s[rid].status == "ok"
            np.testing.assert_array_equal(got_c[rid].tokens,
                                          got_s[rid].tokens)
        assert eng_c.stats["chain_traces"] == 1
        assert eng_c.stats["segment_traces"] == 1
        assert eng_c.stats["prefill_traces"] == 1
        assert eng_s.stats["chain_traces"] == 0
