"""Property tests: production stencil/reduce ≡ executable formal semantics.

The semantics module transcribes the paper's §3.1 definitions; these tests
are the bridge that lets every other layer (Pallas kernels, distributed
halo, pattern loops) be validated transitively.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import semantics as sem
from repro.core import (Boundary, stencil_taps, stencil_windows,
                        tree_reduce, two_phase_reduce)

BOUNDARIES = ["zero", "reflect", "wrap"]


def arrays_2d(draw, min_side=3, max_side=12):
    h = draw(st.integers(min_side, max_side))
    w = draw(st.integers(min_side, max_side))
    seed = draw(st.integers(0, 2**31 - 1))
    a = np.random.default_rng(seed).normal(size=(h, w)).astype(np.float32)
    return jnp.asarray(a)


@st.composite
def array2d(draw):
    return arrays_2d(draw)


class TestSigmaK:
    @settings(max_examples=25, deadline=None)
    @given(array2d(), st.integers(1, 2),
           st.sampled_from(BOUNDARIES))
    def test_neighborhood_shape_and_center(self, a, k, boundary):
        w = sem.neighborhoods(a, k, boundary)
        assert w.shape == a.shape + (2 * k + 1, 2 * k + 1)
        # the window centre is the item itself (paper: w[k,k] = a[i])
        np.testing.assert_array_equal(np.asarray(w[..., k, k]),
                                      np.asarray(a))

    @settings(max_examples=20, deadline=None)
    @given(array2d(), st.integers(1, 2))
    def test_zero_boundary_is_bottom(self, a, k):
        w = sem.neighborhoods(a, k, "zero")
        # corner item's upper-left neighbours are all ⊥ (=0)
        corner = np.asarray(w[0, 0])
        assert (corner[:k, :k] == 0).all()

    @settings(max_examples=15, deadline=None)
    @given(array2d(), st.integers(1, 2))
    def test_indexed_variant_coordinates(self, a, k):
        w, idx = sem.indexed_neighborhoods(a, k)
        # centre index equals the item coordinate (σ̄_k definition)
        ii, jj = np.meshgrid(np.arange(a.shape[0]), np.arange(a.shape[1]),
                             indexing="ij")
        np.testing.assert_array_equal(np.asarray(idx[..., k, k, 0]), ii)
        np.testing.assert_array_equal(np.asarray(idx[..., k, k, 1]), jj)


class TestStencilEquivalence:
    """stencil_taps (shift algebra) ≡ α(f)∘σ_k (materialised windows)."""

    @settings(max_examples=25, deadline=None)
    @given(array2d(), st.sampled_from(BOUNDARIES))
    def test_laplacian(self, a, boundary):
        def taps(get):
            return (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
                    - 4.0 * get(0, 0))

        def windows(w):
            return (w[..., 0, 1] + w[..., 2, 1] + w[..., 1, 0]
                    + w[..., 1, 2] - 4.0 * w[..., 1, 1])
        out_t = stencil_taps(taps, a, 1, boundary)
        out_w = sem.stencil(windows, a, 1, boundary)
        np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_w),
                                   atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(array2d(), st.integers(1, 2), st.sampled_from(BOUNDARIES))
    def test_window_mean(self, a, k, boundary):
        win = 2 * k + 1

        def taps(get):
            import itertools
            acc = 0.0
            for di, dj in itertools.product(range(-k, k + 1), repeat=2):
                acc = acc + get(di, dj)
            return acc / win ** 2

        def windows(w):
            return w.mean(axis=(-1, -2))
        np.testing.assert_allclose(
            np.asarray(stencil_taps(taps, a, k, boundary)),
            np.asarray(sem.stencil(windows, a, k, boundary)), atol=1e-5)


class TestReduce:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 300), st.integers(0, 2**31 - 1),
           st.sampled_from(["sum", "max", "min"]))
    def test_reduce_equals_numpy(self, n, seed, monoid):
        x = jnp.asarray(np.random.default_rng(seed)
                        .normal(size=(n,)).astype(np.float32))
        from repro.core.reduce import MONOIDS
        op, ident = MONOIDS[monoid]
        want = {"sum": np.sum, "max": np.max, "min": np.min}[monoid](
            np.asarray(x))
        got_tree = tree_reduce(op, x, ident)
        got_2ph = two_phase_reduce(op, x, ident, tile=32)
        got_sem = sem.reduce_all(op, x, ident)
        np.testing.assert_allclose(got_tree, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_2ph, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_sem, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 64), st.integers(0, 2**31 - 1))
    def test_any_all_monoids(self, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.integers(0, 2, n).astype(bool))
        assert bool(tree_reduce(jnp.logical_or, x, False)) == bool(
            np.asarray(x).any())
        assert bool(tree_reduce(jnp.logical_and, x, True)) == bool(
            np.asarray(x).all())
