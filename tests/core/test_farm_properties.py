"""Property tests for continuous-refill invariants (hypothesis).

The dispatcher contract, over random lane counts / item counts / segment
lengths S:

* every stream item is processed EXACTLY once and lands at its own
  index — no drops, no duplicates, regardless of how refills interleave;
* a refilled slot carries NOTHING over from its previous occupant —
  values, trip counts and ghost rings all match the item's solo run
  (stale ghosts would corrupt boundary-reading workers on the persistent
  backends);
* ragged tails (items < lanes, including the empty stream) stay
  done-masked: unoccupied slots never emit.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import FarmEngine, LoopOfStencilReduce
from repro.kernels import ref as R


def countdown(get, *_):
    return get(0, 0) - 1.0


def mk_countdown(backend="jnp", max_iters=64):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=max_iters, backend=backend,
        interpret=True, block=(32, 128))


def trip_items(trips, shape=(6, 10)):
    base = np.linspace(0.1, 0.9, shape[0] * shape[1],
                       dtype=np.float32).reshape(shape)
    return [base + float(t) - 1.0 for t in trips]


class TestRefillInvariants:
    @settings(deadline=None, max_examples=25)
    @given(lanes=st.integers(1, 5),
           trips=st.lists(st.integers(1, 9), min_size=0, max_size=12),
           segment=st.integers(1, 7))
    def test_every_item_exactly_once(self, lanes, trips, segment):
        """Random lane/item/segment geometry: each index emitted once,
        each result equal to its solo run (trip count AND values)."""
        eng = FarmEngine(mk_countdown(), lanes=lanes, segment=segment)
        outs = []
        n = eng.run(trip_items(trips), outs.append, continuous=True)
        assert n == len(trips)
        assert sorted(r.index for r in outs) == list(range(len(trips)))
        outs.sort(key=lambda r: r.index)
        for t, res in zip(trips, outs):
            assert int(res.iters) == t
            np.testing.assert_array_equal(
                res.a, trip_items([t])[0] - float(t))

    @settings(deadline=None, max_examples=25)
    @given(lanes=st.integers(1, 4),
           trips=st.lists(st.integers(1, 9), min_size=1, max_size=10),
           segment=st.integers(1, 7))
    def test_accounting_invariants(self, lanes, trips, segment):
        """lane_steps = useful + wasted, with useful = Σ trip counts —
        the waste metric never undercounts (and never goes negative)."""
        eng = FarmEngine(mk_countdown(), lanes=lanes, segment=segment)
        n = eng.run(trip_items(trips), lambda r: None, continuous=True)
        assert n == len(trips)
        useful = sum(trips)
        assert eng.stats["wasted_lane_steps"] >= 0
        assert eng.lane_steps == useful + eng.stats["wasted_lane_steps"]
        assert eng.stats["refills"] == len(trips)

    @settings(deadline=None, max_examples=8)
    @given(scales=st.lists(
               st.floats(0.2, 6.0, allow_nan=False), min_size=1,
               max_size=6),
           segment=st.integers(1, 6),
           lanes=st.integers(1, 3))
    def test_no_stale_ghost_after_refill(self, scales, segment, lanes):
        """Persistent-frame backend, boundary-READING worker (reflect):
        if a refill left the previous occupant's ghost ring in place,
        the first sweep after the refill would read it and the result
        would diverge from the item's solo run."""
        loop = LoopOfStencilReduce(
            f=R.heat_taps(0.1), k=1, combine="max",
            cond=lambda r: r < 2e-3, delta=R.abs_delta,
            boundary="reflect", max_iters=40, backend="pallas",
            interpret=True, block=(32, 128))
        rng = np.random.default_rng(7)
        base = np.asarray(rng.normal(size=(12, 130)), np.float32)
        items = [base * s for s in scales]
        eng = FarmEngine(loop, lanes=lanes, segment=segment)
        outs = []
        assert eng.run(items, outs.append, continuous=True) == len(items)
        outs.sort(key=lambda r: r.index)
        for it, res in zip(items, outs):
            ref = loop.run(jnp.asarray(it))
            assert int(res.iters) == int(ref.iters)
            np.testing.assert_allclose(res.a, np.asarray(ref.a),
                                       atol=1e-5)

    @settings(deadline=None, max_examples=15)
    @given(lanes=st.integers(2, 6),
           n_items=st.integers(0, 5),
           segment=st.integers(1, 5))
    def test_ragged_tail_done_masked(self, lanes, n_items, segment):
        """items <= lanes: the unoccupied slots must neither emit nor
        stall the stream (they enter every segment done-masked)."""
        n_items = min(n_items, lanes)
        trips = list(range(1, n_items + 1))
        eng = FarmEngine(mk_countdown(), lanes=lanes, segment=segment)
        outs = []
        n = eng.run(trip_items(trips), outs.append, continuous=True)
        assert n == n_items == len(outs)
        assert sorted(r.index for r in outs) == list(range(n_items))
