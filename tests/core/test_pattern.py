"""Loop-of-stencil-reduce pattern: variants ≡ the paper's pseudocode
(reference python-loop interpreters from the semantics module)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (LoopOfStencilReduce, farm, loop_of_stencil_reduce,
                        loop_of_stencil_reduce_d, loop_of_stencil_reduce_s)
from repro.core import semantics as sem


def jac_taps(get):
    return 0.25 * (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1))


def jac_win(w):
    return 0.25 * (w[..., 0, 1] + w[..., 2, 1] + w[..., 1, 0]
                   + w[..., 1, 2])


def field(seed, shape=(24, 24)):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=shape).astype(np.float32))


class TestBaseVariant:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 12))
    def test_matches_reference_interpreter(self, seed, iters):
        """Fixed-iteration run == the paper's repeat/until transcription."""
        a = field(seed)
        import operator
        # condition: sum < threshold chosen so it runs `iters` times is
        # hard to control; use -s style count via max_iters instead
        res = loop_of_stencil_reduce(
            1, jac_taps, "max", lambda r: False, a, max_iters=iters)
        a_ref, r_ref, it_ref = sem.loop_of_stencil_reduce_ref(
            1, jac_win, jnp.maximum, lambda r: False, a,
            identity=-jnp.inf, max_iters=iters)
        assert int(res.iters) == it_ref == iters
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(a_ref),
                                   atol=1e-5)
        np.testing.assert_allclose(float(res.reduced), float(r_ref),
                                   atol=1e-5)

    def test_do_while_runs_at_least_once(self):
        a = field(3)
        res = loop_of_stencil_reduce(1, jac_taps, "max",
                                     lambda r: True, a, max_iters=50)
        assert int(res.iters) == 1       # condition true after first body

    def test_max_iters_cap(self):
        a = field(4)
        res = loop_of_stencil_reduce(1, jac_taps, "max",
                                     lambda r: False, a, max_iters=7)
        assert int(res.iters) == 7


class TestDVariant:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_matches_reference(self, seed):
        a = field(seed, (16, 16))
        delta = lambda n, o: jnp.abs(n - o)
        res = loop_of_stencil_reduce_d(
            1, jac_taps, delta, "max", lambda r: r < 1e-3, a,
            max_iters=500)
        a_ref, r_ref, it_ref = sem.loop_of_stencil_reduce_d_ref(
            1, jac_win, delta, jnp.maximum, lambda r: r < 1e-3, a,
            identity=-jnp.inf, max_iters=500)
        assert int(res.iters) == it_ref
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(a_ref),
                                   atol=1e-5)

    def test_unroll_overshoots_by_less_than_unroll(self):
        a = field(11, (16, 16))
        delta = lambda n, o: jnp.abs(n - o)
        exact = loop_of_stencil_reduce_d(
            1, jac_taps, delta, "max", lambda r: r < 1e-3, a,
            max_iters=500)
        un = loop_of_stencil_reduce_d(
            1, jac_taps, delta, "max", lambda r: r < 1e-3, a,
            max_iters=500, unroll=4)
        assert int(exact.iters) <= int(un.iters) < int(exact.iters) + 4
        assert float(un.reduced) < 1e-3


class TestSVariant:
    def test_state_controls_termination(self):
        a = field(5)
        res = loop_of_stencil_reduce_s(
            1, jac_taps, "sum", lambda r, s: s >= 9, a,
            init=lambda: jnp.asarray(0, jnp.int32),
            update=lambda s, a_, it: s + 1)
        assert int(res.iters) == 9
        assert int(res.state) == 9

    def test_matches_reference(self):
        a = field(6, (12, 12))
        res = loop_of_stencil_reduce_s(
            1, jac_taps, "sum", lambda r, s: s >= 5, a,
            init=lambda: jnp.asarray(0, jnp.int32),
            update=lambda s, a_, it: s + 1)
        a_ref, r_ref, it_ref, s_ref = sem.loop_of_stencil_reduce_s_ref(
            1, jac_win, jnp.add if False else __import__("operator").add,
            lambda r, s: s >= 5, a, identity=0.0,
            init=lambda: 0, update=lambda s: s + 1, max_iters=100)
        assert int(res.iters) == it_ref
        np.testing.assert_allclose(np.asarray(res.a), np.asarray(a_ref),
                                   atol=1e-4)


class TestStreaming:
    def test_farm_lanes_converge_independently(self):
        """1:1 mode: each stream item runs to its own trip count."""
        runner = LoopOfStencilReduce(
            f=jac_taps, k=1, combine="max", identity=-jnp.inf,
            cond=lambda r: r < 1e-3, delta=lambda n, o: jnp.abs(n - o),
            max_iters=2000)
        batch = jnp.stack([field(1), field(2) * 10.0, field(3) * 0.01])
        out = farm(runner.run)(batch)
        solo = [runner.run(batch[i]) for i in range(3)]
        for i in range(3):
            assert int(out.iters[i]) == int(solo[i].iters)
            np.testing.assert_allclose(np.asarray(out.a[i]),
                                       np.asarray(solo[i].a), atol=1e-5)
        # trip counts genuinely differ across lanes
        assert len({int(x) for x in out.iters}) >= 2

    def test_pattern_is_jittable_and_donatable(self):
        runner = LoopOfStencilReduce(
            f=jac_taps, k=1, combine="max", identity=-jnp.inf,
            cond=lambda r: r < 1e-3, delta=lambda n, o: jnp.abs(n - o),
            max_iters=100)
        out = runner.jit_run()(field(9))
        assert out.a.shape == (24, 24)


class TestIndexedVariant:
    """-i: the elemental function receives σ̄_k (value+index windows)."""

    def test_position_weighted_stencil(self):
        a = field(21, (12, 10))

        def f_indexed(w, idx):
            # value of each neighbour weighted by whether its ABSOLUTE
            # row index is even (needs σ̄_k, not σ_k)
            rows = idx[..., 0]
            weight = (rows % 2 == 0).astype(a.dtype)
            return (w * weight).sum(axis=(-1, -2))

        res = loop_of_stencil_reduce(1, f_indexed, "sum",
                                     lambda r: True, a, mode="indexed")
        # manual oracle
        import numpy as np
        an = np.asarray(jnp.pad(a, 1))
        want = np.zeros((12, 10), np.float32)
        for i in range(12):
            for j in range(10):
                for di in (-1, 0, 1):
                    for dj in (-1, 0, 1):
                        if (i + di) % 2 == 0:
                            want[i, j] += an[i + di + 1, j + dj + 1]
        np.testing.assert_allclose(np.asarray(res.a), want, atol=1e-4)
        assert int(res.iters) == 1

    def test_indexed_centre_equals_plain(self):
        """An index-ignoring f̄ gives exactly the base variant."""
        a = field(22, (16, 16))

        def f_idx(w, idx):
            return 0.25 * (w[..., 0, 1] + w[..., 2, 1] + w[..., 1, 0]
                           + w[..., 1, 2])
        r1 = loop_of_stencil_reduce(1, f_idx, "max", lambda r: False, a,
                                    mode="indexed", max_iters=3)
        r2 = loop_of_stencil_reduce(1, jac_taps, "max", lambda r: False,
                                    a, max_iters=3)
        np.testing.assert_allclose(np.asarray(r1.a), np.asarray(r2.a),
                                   atol=1e-5)
