"""Pallas fused stencil+reduce kernel: shape/dtype sweeps vs ref.py oracle
(interpret mode on CPU; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as R
from repro.kernels.stencil2d import stencil2d_fused

SHAPES = [(16, 128), (64, 128), (100, 130), (256, 256), (257, 300),
          (33, 520)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("double_buffer", [False, True])
def test_heat_delta_max(shape, double_buffer, rng):
    a = jnp.asarray(rng.normal(size=shape), jnp.float32)
    f = R.heat_taps(0.1)
    new, red = stencil2d_fused(a, f, k=1, combine="max", identity=-jnp.inf,
                               measure=R.abs_delta, boundary="zero",
                               block=(64, 128),
                               double_buffer=double_buffer, interpret=True)
    wn, wr = R.stencil2d_fused_ref(a, f, k=1, combine="max",
                                   identity=-jnp.inf, measure=R.abs_delta,
                                   boundary="zero")
    np.testing.assert_allclose(np.asarray(new), np.asarray(wn), atol=1e-5)
    np.testing.assert_allclose(float(red), float(wr), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("combine,identity",
                         [("sum", None), ("max", None), ("min", None)])
def test_monoids_and_dtypes(dtype, combine, identity, rng):
    a = jnp.asarray(rng.normal(size=(96, 160)), dtype)
    f = R.sobel_taps()
    new, red = stencil2d_fused(a, f, k=1, combine=combine,
                               identity=identity, boundary="reflect",
                               block=(32, 128), interpret=True)
    wn, wr = R.stencil2d_fused_ref(a, f, k=1, combine=combine,
                                   identity=identity, boundary="reflect")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(new, np.float32),
                               np.asarray(wn, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(float(red), float(wr), atol=tol, rtol=tol)


@pytest.mark.parametrize("k", [1, 2, 3])
def test_radii_and_env(k, rng):
    """k up to 3 (the AMF escalation bound) with env fields."""
    a = jnp.asarray(rng.uniform(size=(80, 144)), jnp.float32)
    fxy = jnp.asarray(rng.normal(size=(80, 144)), jnp.float32)

    def f(get, env):
        import itertools
        acc = env * 0.5
        for di, dj in itertools.product(range(-k, k + 1), repeat=2):
            acc = acc + get(di, dj)
        return acc / (2 * k + 1) ** 2
    new, red = stencil2d_fused(a, f, env=(fxy,), k=k, combine="sum",
                               identity=0.0, boundary="zero",
                               block=(32, 128), interpret=True)
    wn, wr = R.stencil2d_fused_ref(a, f, env=(fxy,), k=k, combine="sum",
                                   identity=0.0, boundary="zero")
    np.testing.assert_allclose(np.asarray(new), np.asarray(wn), atol=1e-4)
    np.testing.assert_allclose(float(red), float(wr), rtol=1e-4)


class TestApps:
    def test_jacobi_solver_converges_and_matches_ref_path(self, rng):
        # alpha strengthens the diagonal => contraction converges quickly
        u0 = jnp.zeros((48, 64), jnp.float32)
        fx = jnp.asarray(rng.normal(size=(48, 64)), jnp.float32)
        kw = dict(alpha=2.0, dx=0.2, tol=1e-5, max_iters=800)
        up, dp_, ip_ = ops.jacobi_solve(u0, fx, use_pallas=True, **kw)
        ur, dr, ir_ = ops.jacobi_solve(u0, fx, use_pallas=False, **kw)
        assert int(ip_) == int(ir_)
        np.testing.assert_allclose(np.asarray(up), np.asarray(ur),
                                   atol=1e-5)
        assert int(ip_) < 800          # converged before the cap

    def test_sobel_pallas_matches_ref(self, rng):
        img = jnp.asarray(rng.uniform(size=(120, 200)), jnp.float32)
        e1, m1 = ops.sobel(img, use_pallas=True)
        e2, m2 = ops.sobel(img, use_pallas=False)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                                   atol=1e-5)
        np.testing.assert_allclose(float(m1), float(m2), rtol=1e-5)

    def test_restoration_two_phase_improves_psnr(self, rng):
        yy, xx = np.mgrid[0:96, 0:160]
        frame = np.clip(0.5 + 0.3 * np.sin(xx / 20.0) * np.cos(yy / 15.0),
                        0, 1).astype(np.float32)
        imp = rng.uniform(size=frame.shape) < 0.3
        sp = np.where(rng.uniform(size=frame.shape) < 0.5, 0.0, 1.0)
        noisy = jnp.asarray(np.where(imp, sp, frame), jnp.float32)
        mask, repaired = ops.adaptive_median_detect(noisy, use_pallas=True)
        out, d, it = ops.restore(repaired, mask, max_iters=60,
                                 use_pallas=True)

        def psnr(x):
            return -10 * np.log10(np.mean((np.asarray(x) - frame) ** 2)
                                  + 1e-12)
        assert psnr(out) > psnr(noisy) + 10.0
        # detection recall on true impulses
        assert (np.asarray(mask)[imp] > 0).mean() > 0.95
        # paper: convergence within 10–30 iterations at these settings
        assert int(it) <= 60

    def test_amf_detect_pallas_matches_ref(self, rng):
        noisy = jnp.asarray(rng.uniform(size=(64, 128)), jnp.float32)
        m1, r1 = ops.adaptive_median_detect(noisy, use_pallas=True)
        m2, r2 = ops.adaptive_median_detect(noisy, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   atol=1e-6)
