"""Sliding-window flash attention kernel vs oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.swa_attention import swa_attention, swa_attention_ref


@pytest.mark.parametrize("shape,window,causal", [
    ((2, 256, 64), 0, True),       # full causal
    ((2, 256, 64), 128, True),     # sliding window = 1 block
    ((1, 512, 128), 256, True),    # window spans 2 blocks
    ((2, 128, 64), 0, False),      # bidirectional (encoder)
    ((1, 256, 64), 64, True),      # window < block
    ((1, 384, 64), 200, True),     # window not block-aligned
])
def test_matches_oracle(shape, window, causal, rng):
    BH, S, hd = shape
    q = jnp.asarray(rng.normal(size=shape), jnp.float32)
    k = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(rng.normal(size=shape), jnp.float32)
    out = swa_attention(q, k, v, window=window, causal=causal,
                        interpret=True)
    ref = swa_attention_ref(q, k, v, window=window, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype, rng):
    q = jnp.asarray(rng.normal(size=(2, 256, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 256, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 256, 64)), dtype)
    out = swa_attention(q, k, v, window=128, interpret=True)
    ref = swa_attention_ref(q, k, v, window=128)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_window_equals_stencil_taps_semantics(rng):
    """A window-1 attention is the identity-ish stencil: each token
    attends only to itself (causal, window=1)."""
    q = jnp.asarray(rng.normal(size=(1, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 64)), jnp.float32)
    out = swa_attention(q, k, v, window=1, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-5)


def test_gqa_grouped_kv_index_map(rng):
    """Native GQA: kv heads indexed via b // G in the BlockSpec."""
    B, H, KH, S, hd = 2, 4, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B * H, S, hd)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B * KH, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B * KH, S, hd)), jnp.float32)
    out = swa_attention(q, kv, v, window=128, interpret=True)
    # oracle: expand kv per head
    G = H // KH
    k_full = jnp.repeat(kv.reshape(B, KH, S, hd), G, axis=1) \
        .reshape(B * H, S, hd)
    v_full = jnp.repeat(v.reshape(B, KH, S, hd), G, axis=1) \
        .reshape(B * H, S, hd)
    ref = swa_attention_ref(q, k_full, v_full, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


def test_model_attention_flash_path_matches_xla(rng):
    """attention() with the flash flag == the XLA einsum path (gemma2-
    style local layer: GQA + window + softcap + RoPE)."""
    import repro.models.attention as A
    from repro.models.attention import attention, init_attention
    B, S, D, H, KH, hd = 2, 256, 64, 4, 2, 64
    p = init_attention(jax.random.PRNGKey(0), D, H, KH, hd, jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, D)) * 0.3, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kw = dict(positions=pos, num_heads=H, num_kv_heads=KH, head_dim=hd,
              rope_theta=1e4, causal=True, window=128, attn_softcap=50.0)
    ref, _ = attention(p, x, **kw)
    A.set_flash_swa(True)
    try:
        out, _ = attention(p, x, **kw)
    finally:
        A.set_flash_swa(False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5)
