"""Temporal-blocking kernel ≡ T single sweeps (all four ⊥ models, env)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil import stencil_taps
from repro.kernels import ref as R
from repro.kernels.multistep import stencil2d_multistep


def heat(get, *_):
    lap = (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
           - 4.0 * get(0, 0))
    return get(0, 0) + 0.1 * lap


def lopsided(get, *_):
    """Mirror-asymmetric stencil: catches boundary models that merely
    evolve a reflected/wrapped continuation instead of re-asserting ⊥
    on every internal sweep."""
    return (0.3 * get(-1, 1) + 0.25 * get(1, 0) + 0.2 * get(0, -1)
            + 0.25 * get(0, 0))


@pytest.mark.parametrize("shape", [(64, 128), (100, 200), (256, 256)])
@pytest.mark.parametrize("T", [1, 2, 4, 8])
def test_T_sweeps_equal_T_single_steps(shape, T, rng):
    a = jnp.asarray(rng.normal(size=shape), jnp.float32)
    want = a
    for _ in range(T):
        prev, want = want, stencil_taps(lambda g: heat(g), want, 1, "zero")
    got, red = stencil2d_multistep(a, heat, k=1, T=T, combine="max",
                                   identity=-jnp.inf,
                                   measure=R.abs_delta,
                                   block=(32, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)
    want_red = float(jnp.max(jnp.abs(want - prev)))
    np.testing.assert_allclose(float(red), want_red, atol=1e-5)


@pytest.mark.parametrize("boundary", ["zero", "nan", "reflect", "wrap"])
@pytest.mark.parametrize("fn", [heat, lopsided])
def test_all_boundaries_with_env(boundary, fn, rng):
    """T sweeps ≡ T× stencil_taps for every ⊥ model, with an env field
    entering f on every internal sweep."""
    T = 3
    a = jnp.asarray(rng.normal(size=(48, 160)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(48, 160)), jnp.float32)

    def f(get, env):
        return fn(get) + 0.05 * env

    want = a
    for _ in range(T):
        prev, want = want, stencil_taps(
            lambda g: f(g, e), want, 1, boundary)
    got, red = stencil2d_multistep(
        a, f, env=(e,), k=1, T=T, combine="max", identity=-jnp.inf,
        measure=R.abs_delta, boundary=boundary, block=(16, 128),
        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)
    if boundary != "nan":
        np.testing.assert_allclose(
            float(red), float(jnp.max(jnp.abs(want - prev))), atol=1e-5)


@pytest.mark.parametrize("double_buffer", [False, True])
def test_double_buffer_paths_agree(double_buffer, rng):
    a = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    got, red = stencil2d_multistep(
        a, heat, k=1, T=4, combine="max", identity=-jnp.inf,
        measure=R.abs_delta, boundary="reflect", block=(32, 128),
        double_buffer=double_buffer, interpret=True)
    want = a
    for _ in range(4):
        prev, want = want, stencil_taps(heat, want, 1, "reflect")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_arithmetic_intensity_improves():
    """Analytic traffic model: ≥3× HBM reduction at T=8, bm=256."""
    bm = bn = 256
    k, T = 1, 8
    single = T * 2 * bm * bn                # read+write per sweep
    blocked = (bm + 2 * k * T) * (bn + 2 * k * T) + bm * bn
    assert single / blocked > 3.0
