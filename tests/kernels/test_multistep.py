"""Temporal-blocking kernel ≡ T single sweeps (zero boundary)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil import stencil_taps
from repro.kernels import ref as R
from repro.kernels.multistep import stencil2d_multistep


def heat(get, *_):
    lap = (get(-1, 0) + get(1, 0) + get(0, -1) + get(0, 1)
           - 4.0 * get(0, 0))
    return get(0, 0) + 0.1 * lap


@pytest.mark.parametrize("shape", [(64, 128), (100, 200), (256, 256)])
@pytest.mark.parametrize("T", [1, 2, 4, 8])
def test_T_sweeps_equal_T_single_steps(shape, T, rng):
    a = jnp.asarray(rng.normal(size=shape), jnp.float32)
    want = a
    for _ in range(T):
        prev, want = want, stencil_taps(lambda g: heat(g), want, 1, "zero")
    got, red = stencil2d_multistep(a, heat, k=1, T=T, combine="max",
                                   identity=-jnp.inf,
                                   measure=R.abs_delta,
                                   block=(32, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)
    want_red = float(jnp.max(jnp.abs(want - prev)))
    np.testing.assert_allclose(float(red), want_red, atol=1e-5)


def test_arithmetic_intensity_improves():
    """Analytic traffic model: ≥3× HBM reduction at T=8, bm=256."""
    bm = bn = 256
    k, T = 1, 8
    single = T * 2 * bm * bn                # read+write per sweep
    blocked = (bm + 2 * k * T) * (bn + 2 * k * T) + bm * bn
    assert single / blocked > 3.0
