"""Serve-tier failure semantics: deadlines, eviction, admission control.

All timing is DETERMINISTIC — the engine and batcher take a pluggable
``clock``, and these tests hand them a counting clock (one tick per
read), so deadline arithmetic replays identically on any machine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig
from repro.serve.batcher import Batcher, Request, Result
from repro.serve.engine import ContinuousEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def ticking_clock():
    ticks = [0]

    def clock():
        ticks[0] += 1
        return float(ticks[0])
    return clock


def collect():
    got = {}

    def sink(rid, toks, status):
        assert rid not in got, f"duplicate emission for {rid}"
        got[rid] = (np.asarray(toks), status)
    return got, sink


def never_eos(cfg, max_new):
    """eos outside the vocab: decode always runs to the token budget —
    segment counts become deterministic."""
    return GenerateConfig(max_new_tokens=max_new, eos_id=cfg.vocab_size,
                          temperature=0.0)


class TestEngineDeadlines:
    def test_expired_request_is_shed_at_admission(self, served, rng):
        cfg, params = served
        gcfg = never_eos(cfg, 4)
        eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                               cache_dtype=jnp.float32, segment=2)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        reqs = [Request(rid=0, prompt=prompt),
                Request(rid=1, prompt=prompt, deadline=-1.0),
                Request(rid=2, prompt=prompt)]
        got, sink = collect()
        n = eng.run(reqs, sink, clock=ticking_clock())
        assert n == 3
        assert got[1][1] == "timed_out" and len(got[1][0]) == 0
        assert got[0][1] == "ok" and len(got[0][0]) == 4
        assert got[2][1] == "ok" and len(got[2][0]) == 4
        assert eng.stats["shed"] == 1
        assert eng.stats["evicted"] == 0
        assert eng.stats["prefills"] == 2     # the shed one never lands

    def test_mid_decode_eviction_frees_the_slot(self, served, rng):
        """A slot whose occupant's deadline passes mid-decode emits its
        PARTIAL tokens and hands the KV slot to the next queued request
        through the ordinary refill path — the queue keeps draining."""
        cfg, params = served
        gcfg = never_eos(cfg, 12)
        eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                               cache_dtype=jnp.float32, segment=2)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        # the counting clock reads once per admission pull and once per
        # segment: rid1's deadline of 3.0 passes after the first
        # segment, long before its 12-token budget
        reqs = [Request(rid=0, prompt=prompt, max_new_tokens=6),
                Request(rid=1, prompt=prompt, deadline=3.0),
                Request(rid=2, prompt=prompt, max_new_tokens=4)]
        got, sink = collect()
        n = eng.run(reqs, sink, clock=ticking_clock())
        assert n == 3
        toks1, status1 = got[1]
        assert status1 == "timed_out"
        assert 0 < len(toks1) < 12            # partial, not empty
        assert got[0][1] == "ok" and len(got[0][0]) == 6
        assert got[2][1] == "ok" and len(got[2][0]) == 4
        assert eng.stats["evicted"] == 1
        assert eng.stats["shed"] == 0
        # one compilation still serves every segment and prefill
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["prefill_traces"] == 1

    def test_eviction_with_empty_queue_retires_the_slot(self, served,
                                                        rng):
        """No replacement queued: the evicted slot is retired in place
        (done-masked) — the stream ends instead of spinning it."""
        cfg, params = served
        gcfg = never_eos(cfg, 12)
        eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                               cache_dtype=jnp.float32, segment=2)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        reqs = [Request(rid=0, prompt=prompt, max_new_tokens=4),
                Request(rid=1, prompt=prompt, deadline=3.0)]
        got, sink = collect()
        assert eng.run(reqs, sink, clock=ticking_clock()) == 2
        assert got[1][1] == "timed_out"
        assert got[0][1] == "ok" and len(got[0][0]) == 4
        assert eng.stats["evicted"] == 1

    def test_healthy_requests_identical_under_degradation(self, served,
                                                          rng):
        """Greedy decode of the healthy requests is bit-identical
        whether or not doomed requests share the pool (an eviction must
        not perturb a neighbour slot's decode path)."""
        cfg, params = served
        gcfg = never_eos(cfg, 6)
        prompts = [np.asarray(rng.integers(2, cfg.vocab_size, 5),
                              np.int32) for _ in range(4)]
        healthy = [Request(rid=i, prompt=prompts[i]) for i in range(4)]
        doomed = [Request(rid=10, prompt=prompts[0], deadline=-1.0),
                  Request(rid=11, prompt=prompts[1], deadline=4.0)]

        def drive(reqs):
            eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                                   cache_dtype=jnp.float32, segment=2)
            got, sink = collect()
            eng.run(reqs, sink, clock=ticking_clock())
            return got

        ref = drive(healthy)
        mixed = drive([healthy[0], doomed[0], healthy[1], doomed[1],
                       healthy[2], healthy[3]])
        for i in range(4):
            assert mixed[i][1] == "ok"
            np.testing.assert_array_equal(mixed[i][0], ref[i][0])


class TestBatcherAdmission:
    def test_queue_bound_sheds_with_reason(self, served, rng):
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=4, eos_id=1)
        b = Batcher(cfg, params, gcfg, max_batch=2, max_queue=2)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        assert b.submit(Request(rid=0, prompt=prompt)) is None
        assert b.submit(Request(rid=1, prompt=prompt)) is None
        rej = b.submit(Request(rid=2, prompt=prompt))
        assert isinstance(rej, Result)
        assert rej.status == "shed" and "queue full" in rej.error
        assert len(rej.tokens) == 0
        assert b.stats["shed_queue_full"] == 1
        assert b.stats["accepted"] == 2

    def test_projected_delay_past_deadline_sheds(self, served, rng):
        """With est_service_time set, a deadline the queue cannot meet
        is refused at the door — before any device work is spent."""
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=4, eos_id=1)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    est_service_time=10.0, clock=ticking_clock())
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        # no deadline: always admitted, whatever the queue looks like
        for i in range(4):
            assert b.submit(Request(rid=i, prompt=prompt)) is None
        # 4 queued = 3 batch waves ahead at max_batch=2 → projected
        # ~30 ticks out; a deadline of 5 cannot be met
        rej = b.submit(Request(rid=9, prompt=prompt, deadline=5.0))
        assert rej is not None and rej.status == "shed"
        assert "deadline" in rej.error
        assert b.stats["shed_deadline"] == 1
        # a generous deadline is admitted
        assert b.submit(Request(rid=10, prompt=prompt,
                                deadline=1e6)) is None

    def test_shed_never_blocks_undeadlined_requests(self, served, rng):
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=3, eos_id=1)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    est_service_time=10.0, clock=ticking_clock())
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        assert b.submit(Request(rid=0, prompt=prompt)) is None
        res = b.run_all()
        assert len(res) == 1 and res[0].status == "ok"


class TestBatcherDegradation:
    def test_drain_failure_degrades_to_failed_results(self, served, rng):
        """A poisoned in-flight batch (device pull raises) yields one
        failed Result per request — results already drained and batches
        still queued are untouched."""
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=4, eos_id=1)
        b = Batcher(cfg, params, gcfg, max_batch=2)
        batch = [Request(rid=i, prompt=np.asarray(
            rng.integers(2, cfg.vocab_size, 5), np.int32))
            for i in range(2)]

        class Boom:
            def __array__(self, dtype=None, copy=None):
                raise RuntimeError("device buffer poisoned")

        out = [Result(rid=99, tokens=np.zeros((2,), np.int32))]
        b._drain((batch, Boom(), Boom()), out)
        assert len(out) == 3
        assert out[0].rid == 99                      # prior result kept
        for r in out[1:]:
            assert r.status == "failed"
            assert "poisoned" in r.error
            assert len(r.tokens) == 0

    def test_continuous_midstream_exception_degrades(self, served, rng,
                                                     monkeypatch):
        """An engine fault mid-stream: results emitted BEFORE the fault
        survive, the unemitted requests become failed Results with the
        error attached — nothing is silently lost, nothing raises
        through run_continuous."""
        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=3, eos_id=1)
        b = Batcher(cfg, params, gcfg, max_batch=2)
        prompts = [np.asarray(rng.integers(2, cfg.vocab_size, 5),
                              np.int32) for _ in range(4)]
        for i, p in enumerate(prompts):
            b.submit(Request(rid=i, prompt=p))

        real_run = ContinuousEngine.run
        state = {"emitted": 0}

        def flaky_run(self, requests, emit, **kw):
            def tripwire(rid, toks, status):
                emit(rid, toks, status)
                state["emitted"] += 1
                if state["emitted"] == 2:
                    raise RuntimeError("lost the accelerator")
            return real_run(self, requests, tripwire, **kw)

        monkeypatch.setattr(ContinuousEngine, "run", flaky_run)
        res = b.run_continuous()
        by_rid = {r.rid: r for r in res}
        assert sorted(by_rid) == [0, 1, 2, 3]
        oks = [r for r in res if r.status == "ok"]
        fails = [r for r in res if r.status == "failed"]
        assert len(oks) == 2 and len(fails) == 2
        assert all("lost the accelerator" in r.error for r in fails)
        assert b.stats["failed"] == 2

    def test_continuous_statuses_ride_results(self, served, rng):
        """Engine-level deadline outcomes surface as Result.status via
        the batcher, with the eviction counted in batcher stats."""
        cfg, params = served
        # budget 24 spans three of the engine's default segment=8
        # windows, so rid 1's deadline passes mid-decode
        gcfg = never_eos(cfg, 24)
        b = Batcher(cfg, params, gcfg, max_batch=2,
                    clock=ticking_clock())
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        b.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        b.submit(Request(rid=1, prompt=prompt, deadline=3.0))
        res = {r.rid: r for r in b.run_continuous()}
        assert res[0].status == "ok" and len(res[0].tokens) == 4
        assert res[1].status == "timed_out"
        assert len(res[1].tokens) < 24
        assert res[1].error is not None
        assert b.stats["evicted"] + b.stats["shed"] == 1
