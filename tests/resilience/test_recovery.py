"""Preemption-recovery suite: snapshots, WAL journal, elastic resume.

The contracts under kill (DESIGN.md §Recovery):

  exactly-once     — across any number of kills and restarts, every
                     stream item / request emits exactly one result
                     (the WAL journal suppresses re-emission; replay
                     re-delivers what the dead process already sank)
  bit-identity     — a preempted-and-resumed run's outputs equal an
                     uninterrupted run's, bit for bit, even at
                     temperature > 0 (PRNG keys ride in the snapshot)
  elasticity       — snapshots are logical (unsharded): a run killed at
                     lanes/slots = N resumes at any other N or mesh
  crash-atomicity  — a kill at ANY point leaves a loadable snapshot
                     and a replayable journal (rename-aside publish;
                     CRC-framed, torn-tail-tolerant journal lines)

Kill-at-random-segment subprocess tests use ``os._exit(PREEMPTED_EXIT)``
— no finally blocks, no flushing: the portable stand-in for a spot
reclaim.  The preempt hook is armed ONLY on the first launch (a resumed
process re-counts segments from its own start and would re-kill
forever otherwise).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FarmEngine, LoopOfStencilReduce
from repro.resilience import (FaultPlan, Journal, PreemptionError,
                              RecoveryConfig, load_snapshot,
                              run_to_completion, save_snapshot)
from repro.resilience.recovery import (fresh_tmp_dir, list_steps,
                                       publish_dir, sweep_strays)

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def countdown(get, *_):
    return get(0, 0) - 1.0


def mk_countdown(max_iters=64, backend="jnp"):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=max_iters, backend=backend,
        interpret=True, block=(32, 128))


def trip_items(trips, shape=(8, 128)):
    base = np.linspace(0.1, 0.9, shape[0] * shape[1],
                       dtype=np.float32).reshape(shape)
    return [base + float(t) - 1.0 for t in trips]


def collect():
    got = {}

    def sink(r):
        assert r.index not in got, f"duplicate emission for {r.index}"
        got[r.index] = r
    return got, sink


# ---------------------------------------------------------------------------
# atomic publish + checkpoint crash window
# ---------------------------------------------------------------------------


class TestAtomicPublish:
    def test_rename_aside_never_leaves_nothing(self, tmp_path):
        parent = str(tmp_path)
        final = os.path.join(parent, "step_1")
        for gen in ("first", "second"):
            tmp = fresh_tmp_dir(parent, "1")
            with open(os.path.join(tmp, "payload"), "w") as f:
                f.write(gen)
            publish_dir(tmp, final)
            with open(os.path.join(final, "payload")) as f:
                assert f.read() == gen
        assert not [d for d in os.listdir(parent) if d.startswith(".")]

    def test_orphaned_old_is_promoted(self, tmp_path):
        """Crash after rename-aside, before publish: the .old copy is
        the sole survivor and sweep promotes it back to final."""
        parent = str(tmp_path)
        os.makedirs(os.path.join(parent, ".old-step_7"))
        with open(os.path.join(parent, ".old-step_7", "payload"),
                  "w") as f:
            f.write("survivor")
        os.makedirs(os.path.join(parent, ".tmp-9"))
        sweep_strays(parent)
        assert os.path.exists(os.path.join(parent, "step_7", "payload"))
        assert not os.path.exists(os.path.join(parent, ".tmp-9"))
        assert list_steps(parent) == [7]

    def test_checkpoint_same_step_resave_crash_window(self, tmp_path,
                                                      monkeypatch):
        """Re-saving an existing checkpoint step must never pass through
        a state with no copy on disk: crash the publish at the moment
        the new dir would swap in and assert the OLD copy restores."""
        from repro.train import checkpoint

        ckpt = str(tmp_path / "ckpt")
        tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
        checkpoint.save(ckpt, 3, tree)

        real_replace = os.replace

        def exploding_replace(src, dst):
            if os.path.basename(src).startswith(".tmp-"):
                raise OSError("simulated crash mid-publish")
            return real_replace(src, dst)

        monkeypatch.setattr(os, "replace", exploding_replace)
        tree2 = {"w": tree["w"] + 100.0}
        with pytest.raises(OSError, match="simulated crash"):
            checkpoint.save(ckpt, 3, tree2)
        monkeypatch.setattr(os, "replace", real_replace)

        # the step dir was renamed aside, not destroyed: restore finds it
        restored, step, _ = checkpoint.restore(ckpt, tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert checkpoint.latest_step(ckpt) == 3

    def test_checkpoint_tolerates_stray_tmp(self, tmp_path):
        from repro.train import checkpoint

        ckpt = str(tmp_path / "ckpt")
        tree = {"w": jnp.ones((2,), jnp.bfloat16)}
        checkpoint.save(ckpt, 1, tree)
        os.makedirs(os.path.join(ckpt, ".tmp-999"))
        assert checkpoint.latest_step(ckpt) == 1
        restored, _, _ = checkpoint.restore(ckpt, tree)
        assert restored["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# journal + snapshot units
# ---------------------------------------------------------------------------


class TestJournal:
    def test_round_trip_with_arrays(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        j = Journal(path, fsync=False)
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        j.append({"index": 0, "a": a, "status": "ok", "err": None})
        j.append({"index": 1, "a": a.astype(jnp.bfloat16), "nested":
                  {"x": [1, 2.5, True]}})
        j.close()
        recs = list(Journal.replay(path))
        assert len(recs) == 2
        np.testing.assert_array_equal(recs[0]["a"], a)
        assert recs[1]["a"].dtype == jnp.bfloat16
        assert recs[1]["nested"]["x"] == [1, 2.5, True]

    def test_torn_tail_stops_replay(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        j = Journal(path, fsync=False)
        for i in range(3):
            j.append({"index": i})
        j.close()
        with open(path, "rb") as f:
            data = f.read()
        # crash mid-append: the last line loses its tail
        with open(path, "wb") as f:
            f.write(data[:-7])
        assert [r["index"] for r in Journal.replay(path)] == [0, 1]

    def test_corrupt_crc_stops_replay(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        j = Journal(path, fsync=False)
        for i in range(3):
            j.append({"index": i})
        j.close()
        lines = open(path, "rb").read().splitlines(keepends=True)
        lines[1] = b"deadbeef" + lines[1][8:]
        open(path, "wb").write(b"".join(lines))
        assert [r["index"] for r in Journal.replay(path)] == [0]

    def test_append_after_replay_extends(self, tmp_path):
        """The resume pattern: replay, then open in append mode — old
        records survive, new ones land after them."""
        path = str(tmp_path / "j.jsonl")
        Journal(path, fsync=False).append({"index": 0})
        assert len(list(Journal.replay(path))) == 1
        j = Journal(path, fsync=False)
        j.append({"index": 1})
        j.close()
        assert [r["index"] for r in Journal.replay(path)] == [0, 1]


class TestSnapshotTree:
    def test_dynamic_structure_round_trip(self, tmp_path):
        snap = str(tmp_path / "snaps")
        tree = {"kind": "farm", "version": 1, "complete": False,
                "occupants": [
                    {"index": 4, "item": np.ones((3, 5), np.float32),
                     "carry": (np.zeros((2,), jnp.bfloat16), 0.5, 7)},
                ],
                "retry": [], "none": None}
        save_snapshot(snap, 11, tree)
        out = load_snapshot(snap)
        assert out["kind"] == "farm" and out["none"] is None
        assert isinstance(out["occupants"][0]["carry"], tuple)
        assert out["occupants"][0]["carry"][0].dtype == jnp.bfloat16
        assert out["occupants"][0]["carry"][1:] == (0.5, 7)
        np.testing.assert_array_equal(out["occupants"][0]["item"],
                                      np.ones((3, 5), np.float32))
        assert out["retry"] == [] and out["complete"] is False

    def test_keep_prunes_and_latest_wins(self, tmp_path):
        snap = str(tmp_path / "snaps")
        for step in (1, 2, 3, 4):
            save_snapshot(snap, step, {"step": step}, keep=2)
        assert list_steps(snap) == [3, 4]
        assert load_snapshot(snap)["step"] == 4
        assert load_snapshot(snap, step=3)["step"] == 3

    def test_empty_dir_is_fresh_run(self, tmp_path):
        assert load_snapshot(str(tmp_path / "nothing")) is None


class TestSeededPreemptPlans:
    def test_seeded_draws_preempt_point(self):
        p1 = FaultPlan.seeded(5, lanes=4, preempt_within=6)
        p2 = FaultPlan.seeded(5, lanes=4, preempt_within=6)
        assert p1 == p2
        assert 1 <= p1.preempt_at_segment <= 6
        assert FaultPlan.seeded(5, lanes=4).preempt_at_segment is None

    def test_preempt_hook_fires_once(self):
        plan = FaultPlan(lanes=2, preempt_at_segment=3)
        hook = plan.preempt_hook(mode="raise")
        hook(1)
        hook(2)
        with pytest.raises(PreemptionError):
            hook(3)
        hook(4)        # already fired: a resumed in-process run survives
        assert FaultPlan(lanes=2).preempt_hook() is None


# ---------------------------------------------------------------------------
# farm: in-process elastic resume (raise-mode preemption)
# ---------------------------------------------------------------------------


def run_reference(items, lanes=4):
    eng = FarmEngine(loop=mk_countdown(), lanes=lanes, segment=2)
    got, sink = collect()
    eng.run(items, sink, continuous=True)
    return got


class TestFarmElasticResume:
    TRIPS = [3, 9, 5, 12, 7, 4, 10, 6]

    def _preempt_then_resume(self, tmp_path, lanes0, lanes1,
                             preempt_at=3):
        items = trip_items(self.TRIPS)
        ref = run_reference(items)
        rec = RecoveryConfig(dir=str(tmp_path), snapshot_every=1,
                             fsync=False)
        plan = FaultPlan(lanes=lanes0, preempt_at_segment=preempt_at)
        eng = FarmEngine(loop=mk_countdown(), lanes=lanes0, segment=2)
        got0, sink0 = collect()
        with pytest.raises(PreemptionError):
            eng.run(items, sink0, continuous=True, recovery=rec,
                    on_segment=plan.preempt_hook(mode="raise"))
        # resumed process: FRESH consumer, different lane count, hook
        # disarmed (first-launch-only arming)
        eng2 = FarmEngine(loop=mk_countdown(), lanes=lanes1, segment=2)
        got, sink = collect()
        n = eng2.run(items, sink, continuous=True, recovery=rec,
                     resume=True)
        assert n == len(items) and sorted(got) == list(range(len(items)))
        for i in range(len(items)):
            assert got[i].status == ref[i].status == "ok"
            np.testing.assert_array_equal(got[i].a, ref[i].a)
            assert got[i].iters == ref[i].iters
            assert got[i].reduced == ref[i].reduced
        assert eng2.stats["replayed_items"] == len(got0)
        return eng2

    def test_resume_fewer_lanes(self, tmp_path):
        eng2 = self._preempt_then_resume(tmp_path, lanes0=4, lanes1=2)
        assert eng2.stats["recovered_occupants"] > 0
        assert eng2.stats["recovery_seconds"] > 0

    def test_resume_more_lanes(self, tmp_path):
        self._preempt_then_resume(tmp_path, lanes0=2, lanes1=4)

    def test_second_resume_replays_complete_state(self, tmp_path):
        self._preempt_then_resume(tmp_path, lanes0=4, lanes1=2)
        items = trip_items(self.TRIPS)
        rec = RecoveryConfig(dir=str(tmp_path), snapshot_every=1,
                             fsync=False)
        eng3 = FarmEngine(loop=mk_countdown(), lanes=3, segment=2)
        got, sink = collect()
        n = eng3.run(items, sink, continuous=True, recovery=rec,
                     resume=True)
        assert n == len(items)
        assert eng3.stats["replayed_items"] == len(items)
        assert eng3.stats["segments"] > 0     # restored counter, no work
        ref = run_reference(items)
        for i in range(len(items)):
            np.testing.assert_array_equal(got[i].a, ref[i].a)

    def test_pallas_backend_resume(self, tmp_path):
        items = trip_items([3, 8, 5, 11], shape=(8, 128))
        ref_eng = FarmEngine(loop=mk_countdown(backend="pallas"),
                             lanes=2, segment=2)
        ref, ref_sink = collect()
        ref_eng.run(items, ref_sink, continuous=True)
        rec = RecoveryConfig(dir=str(tmp_path), snapshot_every=1,
                             fsync=False)
        plan = FaultPlan(lanes=2, preempt_at_segment=2)
        eng = FarmEngine(loop=mk_countdown(backend="pallas"), lanes=2,
                         segment=2)
        with pytest.raises(PreemptionError):
            eng.run(items, collect()[1], continuous=True, recovery=rec,
                    on_segment=plan.preempt_hook(mode="raise"))
        eng2 = FarmEngine(loop=mk_countdown(backend="pallas"), lanes=3,
                         segment=2)
        got, sink = collect()
        n = eng2.run(items, sink, continuous=True, recovery=rec,
                     resume=True)
        assert n == 4
        for i in range(4):
            np.testing.assert_array_equal(got[i].a, ref[i].a)

    def test_sink_exception_degrades_not_kills(self, tmp_path):
        """Satellite contract: a raising sink mid-stream degrades that
        ONE result to a failed StreamResult on dead_letter — the other
        in-flight items still emit ok."""
        items = trip_items([3, 6, 4, 8, 5, 7])
        eng = FarmEngine(loop=mk_countdown(), lanes=2, segment=2)
        got = {}

        def sink(r):
            if r.index == 1:
                raise IOError("disk full")
            got[r.index] = r
        n = eng.run(items, sink, continuous=True)
        assert n == 6
        assert eng.stats["sink_errors"] == 1
        assert sorted(got) == [0, 2, 3, 4, 5]
        assert all(r.status == "ok" for r in got.values())
        dead = {r.index: r for r in eng.dead_letter}
        assert dead[1].status == "failed"
        assert "disk full" in dead[1].error


# ---------------------------------------------------------------------------
# serve twin: in-process resume (raise-mode preemption)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    from repro.configs import get_reduced
    from repro.models import transformer as T

    cfg = get_reduced("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def serve_collect():
    got = {}

    def sink(rid, toks, status):
        assert rid not in got, f"duplicate emission for {rid}"
        got[rid] = (np.asarray(toks).copy(), status)
    return got, sink


class TestServeResume:
    def _requests(self, cfg, n=7):
        from repro.serve.batcher import Request

        rng = np.random.default_rng(0)
        return [Request(rid=i, prompt=np.asarray(
            rng.integers(2, cfg.vocab_size, 4 + (i % 3)), np.int32),
            max_new_tokens=4 + 2 * (i % 4)) for i in range(n)]

    def test_mid_generation_resume_elastic_sampled(self, served,
                                                   tmp_path):
        """Kill mid-decode at temperature > 0, resume on a SMALLER slot
        pool with an empty submitted queue: every request emits exactly
        once, token-identical to an uninterrupted run — the per-slot
        PRNG keys and the admission-key cursor both ride the snapshot."""
        from repro.serve import GenerateConfig
        from repro.serve.engine import ContinuousEngine

        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=10, eos_id=cfg.vocab_size,
                              temperature=0.7, seed=3)
        reqs = self._requests(cfg)
        ref_eng = ContinuousEngine(cfg, params, gcfg, slots=3,
                                   cache_dtype=jnp.float32, segment=2)
        ref, ref_sink = serve_collect()
        assert ref_eng.run(list(reqs), ref_sink) == 7

        rec = RecoveryConfig(dir=str(tmp_path), snapshot_every=1,
                             fsync=False)
        plan = FaultPlan(lanes=3, preempt_at_segment=3)
        eng = ContinuousEngine(cfg, params, gcfg, slots=3,
                               cache_dtype=jnp.float32, segment=2)
        got0, sink0 = serve_collect()
        with pytest.raises(PreemptionError):
            eng.run(list(reqs), sink0, recovery=rec,
                    on_segment=plan.preempt_hook(mode="raise"))

        eng2 = ContinuousEngine(cfg, params, gcfg, slots=2,
                                cache_dtype=jnp.float32, segment=2)
        got, sink = serve_collect()
        n = eng2.run([], sink, recovery=rec, resume=True)
        assert n == 7 and sorted(got) == list(range(7))
        assert eng2.stats["replayed_items"] == len(got0)
        assert eng2.stats["recovered_occupants"] > 0
        assert eng2.stats["recovery_seconds"] > 0
        for rid in range(7):
            assert got[rid][1] == ref[rid][1] == "ok"
            np.testing.assert_array_equal(got[rid][0], ref[rid][0])

    def test_deadline_reanchors_to_resumed_clock(self, served, tmp_path):
        """A deadline is stored as REMAINING time: a request with lots
        of slack survives a restart whose clock starts from zero, and
        one with no slack times out in the resumed process."""
        from repro.serve import GenerateConfig
        from repro.serve.batcher import Request
        from repro.serve.engine import ContinuousEngine

        cfg, params = served
        gcfg = GenerateConfig(max_new_tokens=8, eos_id=cfg.vocab_size,
                              temperature=0.0)
        rng = np.random.default_rng(2)
        prompt = np.asarray(rng.integers(2, cfg.vocab_size, 5), np.int32)
        # clock ticks once per read; deadline 1000 ticks out = never hit
        reqs = [Request(rid=0, prompt=prompt, deadline=1000.0),
                Request(rid=1, prompt=prompt),
                Request(rid=2, prompt=prompt)]

        def ticking(start=0.0):
            ticks = [start]

            def clock():
                ticks[0] += 1.0
                return ticks[0]
            return clock

        rec = RecoveryConfig(dir=str(tmp_path), snapshot_every=1,
                             fsync=False)
        plan = FaultPlan(lanes=2, preempt_at_segment=2)
        eng = ContinuousEngine(cfg, params, gcfg, slots=2,
                               cache_dtype=jnp.float32, segment=2)
        with pytest.raises(PreemptionError):
            eng.run(reqs, serve_collect()[1], recovery=rec,
                    clock=ticking(),
                    on_segment=plan.preempt_hook(mode="raise"))
        snap = load_snapshot(rec.snap_dir)
        occ = {e["rid"]: e for e in snap["occupants"]}
        assert occ[0]["deadline_remaining"] is not None
        assert occ[0]["deadline_remaining"] < 1000.0
        assert occ[1]["deadline_remaining"] is None

        # resumed process: its clock restarts near zero — the stored
        # remaining slack re-anchors, so rid 0 still finishes ok
        eng2 = ContinuousEngine(cfg, params, gcfg, slots=2,
                                cache_dtype=jnp.float32, segment=2)
        got, sink = serve_collect()
        n = eng2.run([], sink, recovery=rec, resume=True,
                     clock=ticking())
        assert n >= 3 and sorted(got) == [0, 1, 2]
        assert got[0][1] == "ok"
        assert got[1][1] == "ok" and got[2][1] == "ok"


# ---------------------------------------------------------------------------
# kill-at-random-segment chaos (subprocess, os._exit — the real thing)
# ---------------------------------------------------------------------------

_FARM_CHILD = """
import json, os, sys
import numpy as np
sys.path.insert(0, {src!r})
from repro.core import FarmEngine, LoopOfStencilReduce
from repro.resilience import FaultPlan, RecoveryConfig

def countdown(get, *_):
    return get(0, 0) - 1.0

loop = LoopOfStencilReduce(
    f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
    boundary="zero", max_iters=64, backend="jnp", interpret=True)
base = np.linspace(0.1, 0.9, 8 * 128, dtype=np.float32).reshape(8, 128)
items = [base + float(t) - 1.0 for t in {trips}]
rec = RecoveryConfig(dir={recdir!r}, snapshot_every=1)
resume = os.path.exists(rec.journal_path) or \
    os.path.isdir(rec.snap_dir)
# the seeded kill arms ONLY on first launch — a resumed process counts
# segments from its own start and would re-kill forever
hook = None if resume else FaultPlan.seeded(
    {seed}, lanes={lanes}, n_nan=0, n_stall=0,
    preempt_within={within}).preempt_hook()
eng = FarmEngine(loop=loop, lanes={lanes}, segment=2)
out = open({outpath!r}, "a")
def sink(r):
    out.write(json.dumps({{"index": int(r.index), "status": r.status,
                           "iters": int(r.iters),
                           "reduced": float(r.reduced),
                           "sum": float(np.asarray(r.a).sum()),
                           "a00": float(np.asarray(r.a)[0, 0])}}) + "\\n")
    out.flush()
n = eng.run(items, sink, continuous=True, recovery=rec, resume=resume,
            on_segment=hook)
out.close()
"""

_SERVE_CHILD = """
import json, os, sys
import numpy as np
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import GenerateConfig
from repro.serve.batcher import Batcher, Request
from repro.resilience import FaultPlan, RecoveryConfig

cfg = get_reduced("qwen3-1.7b")
params = T.init_params(cfg, jax.random.PRNGKey(0))
gcfg = GenerateConfig(max_new_tokens=8, eos_id=cfg.vocab_size,
                      temperature=0.6, seed=2)
rng = np.random.default_rng(1)
rec = RecoveryConfig(dir={recdir!r}, snapshot_every=1)
resume = os.path.exists(rec.journal_path) or \
    os.path.isdir(rec.snap_dir)
hook = None if resume else FaultPlan.seeded(
    {seed}, lanes={slots}, n_nan=0, n_stall=0,
    preempt_within={within}).preempt_hook()
b = Batcher(cfg, params, gcfg, max_batch={slots},
            cache_dtype=jnp.float32)
if not resume:
    for i in range(6):
        b.submit(Request(rid=i, prompt=np.asarray(
            rng.integers(2, cfg.vocab_size, 4 + (i % 3)), np.int32),
            max_new_tokens=3 + (i % 5)))
res = b.run_continuous(recovery=rec, resume=resume, on_segment=hook)
with open({outpath!r}, "a") as out:
    for r in res:
        out.write(json.dumps({{"rid": int(r.rid), "status": r.status,
                   "tokens": [int(x) for x in np.asarray(r.tokens)]}})
                  + "\\n")
"""


def _spawn_until_done(code, devices=1):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return run_to_completion([sys.executable, "-c", code], env=env,
                             max_restarts=10, timeout=600)


def _read_emissions(path):
    with open(path) as f:
        return [json.loads(line) for line in f]


@pytest.mark.slow
class TestKillAndRespawnFarm:
    TRIPS = [3, 9, 5, 12, 7, 4, 10, 6, 8, 11]

    @pytest.mark.parametrize("devices,lanes", [(1, 4), (8, 8)])
    def test_exactly_once_bit_identical(self, tmp_path, devices, lanes):
        ref = run_reference(trip_items(self.TRIPS), lanes=4)
        outpath = str(tmp_path / "emitted.jsonl")
        code = _FARM_CHILD.format(
            src=os.path.abspath(SRC), trips=self.TRIPS,
            recdir=str(tmp_path / "rec"), seed=3 + devices,
            lanes=lanes, within=6, outpath=outpath)
        restarts = _spawn_until_done(code, devices=devices)
        assert restarts >= 1, "the seeded kill never fired"
        recs = _read_emissions(outpath)
        # pre-kill emissions appear once live + once replayed; the
        # exactly-once contract is per process lifetime of the consumer
        final = {r["index"]: r for r in recs}
        assert sorted(final) == list(range(len(self.TRIPS)))
        for i, r in final.items():
            assert r["status"] == "ok"
            assert r["iters"] == int(ref[i].iters)
            assert r["reduced"] == float(ref[i].reduced)
            assert r["sum"] == float(np.asarray(ref[i].a).sum())
            assert r["a00"] == float(np.asarray(ref[i].a)[0, 0])
        # replays are verbatim journal copies of the live record
        for r in recs:
            assert r == final[r["index"]]


_COMPOSED_CHILD = """
import json, os, sys
import numpy as np
sys.path.insert(0, {src!r})
import jax
from repro.core import FarmEngine, GridPartition, LoopOfStencilReduce
from repro.resilience import FaultPlan, RecoveryConfig

def countdown(get, *_):
    return get(0, 0) - 1.0

mesh = jax.make_mesh(({lanes}, {shards}), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))
loop = LoopOfStencilReduce(
    f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
    boundary="zero", max_iters=32, backend="pallas-sharded",
    partition=part, interpret=True, block=(16, 128))
base = np.linspace(0.1, 0.9, 32 * 64, dtype=np.float32).reshape(32, 64)
items = [base + float(t) - 1.0 for t in {trips}]
rec = RecoveryConfig(dir={recdir!r}, snapshot_every=1)
resume = os.path.exists(rec.journal_path) or \
    os.path.isdir(rec.snap_dir)
hook = None if resume else FaultPlan(
    lanes={lanes}, preempt_at_segment={at}).preempt_hook()
eng = FarmEngine(loop=loop, lanes={lanes}, mesh=mesh, segment=2)
out = open({outpath!r}, "a")
def sink(r):
    out.write(json.dumps({{"index": int(r.index), "status": r.status,
                           "iters": int(r.iters),
                           "sum": float(np.asarray(r.a).sum())}}) + "\\n")
    out.flush()
eng.run(items, sink, continuous=True, recovery=rec, resume=resume,
        on_segment=hook)
out.close()
"""


@pytest.mark.slow
class TestKillAndRespawnComposed:
    TRIPS = [3, 9, 5, 7, 4, 6]

    def test_sharded_lanes_by_spatial_resume(self, tmp_path):
        """Composed farm (2 lanes × 4 spatial shards) killed mid-stream
        resumes onto the SAME mesh shape from a logical snapshot: the
        snapshotted interiors are unsharded, so the restore path is the
        ordinary sharded refill — exactly-once, bit-identical."""
        ref_eng = FarmEngine(
            loop=mk_countdown(max_iters=32),
            lanes=2, segment=2)
        ref, ref_sink = collect()
        ref_eng.run(trip_items(self.TRIPS, shape=(32, 64)), ref_sink,
                    continuous=True)

        outpath = str(tmp_path / "emitted.jsonl")
        code = _COMPOSED_CHILD.format(
            src=os.path.abspath(SRC), trips=self.TRIPS,
            recdir=str(tmp_path / "rec"), lanes=2, shards=4, at=2,
            outpath=outpath)
        restarts = _spawn_until_done(code, devices=8)
        assert restarts >= 1, "the seeded kill never fired"
        final = {r["index"]: r for r in _read_emissions(outpath)}
        assert sorted(final) == list(range(len(self.TRIPS)))
        for i, r in final.items():
            assert r["status"] == "ok"
            assert r["iters"] == int(ref[i].iters)
            assert r["sum"] == float(np.asarray(ref[i].a).sum())


@pytest.mark.slow
class TestKillAndRespawnServe:
    @pytest.mark.parametrize("devices", [1, 8])
    def test_batcher_drain_survives_kill(self, tmp_path, devices):
        from repro.configs import get_reduced
        from repro.models import transformer as T
        from repro.serve import GenerateConfig
        from repro.serve.batcher import Batcher, Request

        cfg = get_reduced("qwen3-1.7b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        gcfg = GenerateConfig(max_new_tokens=8, eos_id=cfg.vocab_size,
                              temperature=0.6, seed=2)
        rng = np.random.default_rng(1)
        b = Batcher(cfg, params, gcfg, max_batch=3,
                    cache_dtype=jnp.float32)
        for i in range(6):
            b.submit(Request(rid=i, prompt=np.asarray(
                rng.integers(2, cfg.vocab_size, 4 + (i % 3)), np.int32),
                max_new_tokens=3 + (i % 5)))
        ref = {r.rid: r for r in b.run_continuous()}

        outpath = str(tmp_path / "emitted.jsonl")
        code = _SERVE_CHILD.format(
            src=os.path.abspath(SRC), recdir=str(tmp_path / "rec"),
            seed=11, slots=3, within=5, outpath=outpath)
        restarts = _spawn_until_done(code, devices=devices)
        assert restarts >= 1, "the seeded kill never fired"
        final = {r["rid"]: r for r in _read_emissions(outpath)}
        assert sorted(final) == list(range(6))
        for rid, r in final.items():
            assert r["status"] == "ok"
            assert r["tokens"] == [int(x) for x in
                                   np.asarray(ref[rid].tokens)]
