"""Chaos suite — convergence sentinels, quarantine, retry, dead-letter.

Every fault here comes from a seeded :class:`repro.resilience.faults.
FaultPlan` (same schedule every run): a NaN-ed lane, a stalled lane and
corrupted stream items, driven through the SAME engines the happy-path
tests use.  The contracts under fault:

  exactly-once     — every stream index emits exactly one StreamResult,
                     whatever slots/retries it passed through
  containment      — healthy items finish ``status="ok"`` BIT-IDENTICAL
                     to a fault-free run (a fault never leaks across
                     lanes)
  loud failure     — every faulty item surfaces a non-ok status (and
                     the dead-letter list); nothing hangs, nothing
                     silently returns NaN
  waste dominance  — under faults, continuous-mode
                     ``wasted + quarantined`` lane steps stay strictly
                     below round mode's (the barrier burns the fault's
                     straggler shadow on every lane)
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FarmEngine, LoopOfStencilReduce
from repro.core.reduce import (HEALTH_CONVERGED, HEALTH_DIVERGED,
                               HEALTH_POISONED, Sentinel, health_status,
                               health_update)
from repro.core.streaming import NonFiniteItemError, item_status
from repro.resilience import FaultPlan


def countdown(get, *_):
    """max decrements by 1 per sweep — an item whose max is v converges
    in EXACTLY v sweeps (cond: max < 0.5): programmable trip counts."""
    return get(0, 0) - 1.0


def mk_countdown(max_iters=64, sentinel=None, backend="jnp"):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=max_iters, backend=backend,
        interpret=True, block=(32, 128), sentinel=sentinel)


def trip_items(trips, shape=(8, 128)):
    base = np.linspace(0.1, 0.9, shape[0] * shape[1],
                       dtype=np.float32).reshape(shape)
    return [base + float(t) - 1.0 for t in trips]


def stream(eng, items, **kw):
    got = {}

    def sink(r):
        assert r.index not in got, f"duplicate emission for {r.index}"
        got[r.index] = r
    n = eng.run(items, sink, **kw)
    assert n == len(got)
    return got


# ---------------------------------------------------------------------------
# Sentinel unit level
# ---------------------------------------------------------------------------


class TestSentinel:
    def test_health_word_bits_and_status(self):
        hw0 = jnp.zeros((4,), jnp.int32)
        live = jnp.ones((4,), bool)
        r_prev = jnp.asarray([1.0, 1.0, 1.0, 1.0], jnp.float32)
        r_new = jnp.asarray([0.1, jnp.nan, 2.0, 0.2], jnp.float32)
        conv = jnp.asarray([True, False, False, False])
        s = Sentinel(nan=True, patience=1)
        hw, quar = health_update(hw0, r_new, r_prev, live, conv,
                                 jnp.full((4,), 3, jnp.int32), s)
        hw = np.asarray(hw)
        assert hw[0] & HEALTH_CONVERGED
        assert hw[1] & HEALTH_POISONED
        assert hw[2] & HEALTH_DIVERGED       # 2.0 >= 1.0, patience 1
        assert not hw[3] & (HEALTH_POISONED | HEALTH_DIVERGED)
        assert list(np.asarray(quar)) == [False, True, True, False]
        assert health_status(hw[0]) == "ok"
        assert health_status(hw[1]) == "poisoned"
        assert health_status(hw[2]) == "nonconverged"
        assert health_status(hw[3]) == "nonconverged"
        # poison outranks a converged bit: a NaN result is never ok
        assert health_status(HEALTH_CONVERGED | HEALTH_POISONED) \
            == "poisoned"

    def test_item_status_taxonomy(self):
        assert item_status(HEALTH_CONVERGED, 7, 64) == "ok"
        assert item_status(HEALTH_POISONED, 7, 64) == "poisoned"
        assert item_status(HEALTH_DIVERGED, 7, 64) == "nonconverged"
        assert item_status(0, 64, 64) == "timed_out"
        assert item_status(0, 7, 64) == "nonconverged"

    def test_dead_lanes_frozen(self):
        """A retired lane's word never changes, whatever its reduce
        value reads (the frozen carry may hold stale garbage)."""
        hw0 = jnp.asarray([HEALTH_CONVERGED, 0], jnp.int32)
        live = jnp.asarray([False, True])
        r = jnp.asarray([jnp.nan, 0.3], jnp.float32)
        hw, quar = health_update(hw0, r, r, live,
                                 jnp.asarray([False, True]),
                                 jnp.full((2,), 5, jnp.int32),
                                 Sentinel(nan=True, patience=2))
        assert int(np.asarray(hw)[0]) == HEALTH_CONVERGED
        assert not bool(np.asarray(quar)[0])

    def test_patience_bounds_validated(self):
        with pytest.raises(ValueError, match="patience"):
            mk_countdown(sentinel=Sentinel(patience=-1))
        with pytest.raises(ValueError, match="patience"):
            mk_countdown(sentinel=Sentinel(patience=1 << 17))

    def test_sentinel_off_still_reports_converged(self):
        """health rides every run (sentinel or not): a plain loop's
        results decode 'ok' for free, in both modes."""
        eng = FarmEngine(mk_countdown(), lanes=2, segment=4)
        got = stream(eng, trip_items([3, 5]), continuous=True)
        assert all(r.status == "ok" for r in got.values())
        eng2 = FarmEngine(mk_countdown(), lanes=2)
        outs = []
        eng2.run(trip_items([3, 5]), outs.append)
        assert [health_status(r.health) for r in outs] == ["ok", "ok"]


class TestFaultPlan:
    def test_seeded_is_deterministic_and_bounded(self):
        a = FaultPlan.seeded(7, lanes=4, n_nan=1, n_stall=1,
                             n_corrupt=2, n_items=10)
        b = FaultPlan.seeded(7, lanes=4, n_nan=1, n_stall=1,
                             n_corrupt=2, n_items=10)
        assert a == b
        lanes = [l for l, _ in (*a.nan_events, *a.stall_events)]
        assert len(set(lanes)) == len(lanes)          # distinct victims
        assert len(lanes) <= 3                        # >=1 healthy lane
        assert FaultPlan.seeded(8, lanes=4).nan_events != a.nan_events \
            or FaultPlan.seeded(8, lanes=4).stall_events \
            != a.stall_events

    def test_lane_bounds_validated(self):
        with pytest.raises(ValueError, match="fault lane"):
            FaultPlan(lanes=2, nan_events=((2, 1),))

    def test_corrupt_stream_plants_nan_in_planned_items_only(self):
        plan = FaultPlan(lanes=2, corrupt_indices=(1,))
        items = trip_items([3, 4, 5])
        out = list(plan.corrupt_stream(items))
        assert not np.isfinite(out[1]).all()
        assert np.isfinite(out[0]).all() and np.isfinite(out[2]).all()
        assert np.isfinite(items[1]).all()            # original untouched


# ---------------------------------------------------------------------------
# Farm chaos — the acceptance fault plan through both modes
# ---------------------------------------------------------------------------

PLAN = FaultPlan(lanes=4, nan_events=((1, 2),), stall_events=((2, 1 << 20),))
TRIPS = [3, 9, 5, 7, 4, 6, 2, 8]


class TestFarmChaos:
    def _loops(self, max_iters=32):
        clean = mk_countdown(max_iters=max_iters,
                             sentinel=Sentinel(nan=True, patience=3))
        return clean, PLAN.instrument(clean)

    def test_exactly_once_and_statuses_no_retry(self):
        """max_attempts=1: the occupant of a faulted slot surfaces its
        non-ok status (poisoned / nonconverged) and lands on the
        dead-letter list; healthy-slot items are ok and bit-identical
        to the fault-free run; nothing hangs, nothing emits twice."""
        clean, faulty = self._loops()
        items = trip_items(TRIPS)
        ref = stream(FarmEngine(clean, lanes=4, segment=4), items,
                     continuous=True)
        eng = FarmEngine(faulty, lanes=4, segment=4)
        got = stream(eng, items, continuous=True)
        assert sorted(got) == list(range(len(items)))
        statuses = {i: got[i].status for i in got}
        assert "poisoned" in statuses.values()
        # the stalled lane diverges (patience) or exhausts its budget
        assert set(statuses.values()) <= {"ok", "poisoned",
                                          "nonconverged", "timed_out"}
        n_bad = sum(1 for s in statuses.values() if s != "ok")
        assert n_bad >= 2
        for i, r in got.items():
            if r.status == "ok":
                np.testing.assert_array_equal(r.a, ref[i].a)
                assert int(r.iters) == int(ref[i].iters)
                assert np.isfinite(r.a).all()
        assert sorted(d.index for d in eng.dead_letter) == sorted(
            i for i, s in statuses.items() if s != "ok")

    def test_retry_into_fresh_slot_recovers_everything(self):
        """The faults ride the SLOTS, so a retried item escapes into a
        fresh slot and converges — with enough attempts EVERY item ends
        ok and bit-identical, the failing slots rack up consecutive
        failures and are quarantined out of the rotation."""
        clean, faulty = self._loops()
        items = trip_items(TRIPS)
        ref = stream(FarmEngine(clean, lanes=4, segment=4), items,
                     continuous=True)
        eng = FarmEngine(faulty, lanes=4, segment=4, max_attempts=3,
                         slot_patience=2)
        got = stream(eng, items, continuous=True)
        assert all(r.status == "ok" for r in got.values()), {
            i: r.status for i, r in got.items()}
        for i, r in got.items():
            np.testing.assert_array_equal(r.a, ref[i].a)
        assert any(r.attempts > 1 for r in got.values())
        assert eng.stats["retries"] > 0
        assert 1 <= eng.stats["quarantined_slots"] <= 2   # both faulted
        assert eng.stats["quarantined_lane_steps"] > 0
        assert eng.dead_letter == []
        # one compilation still serves the whole faulted stream
        assert eng.stats["segment_traces"] == 1
        assert eng.stats["refill_traces"] == 1

    def test_round_mode_surfaces_statuses_too(self):
        """Round mode has no retry path, but the health word rides the
        stacked result: per-lane statuses decode from LoopResult."""
        _, faulty = self._loops()
        eng = FarmEngine(faulty, lanes=4)
        got = []
        eng.run(trip_items([3, 5, 4, 6]), got.append)
        statuses = [health_status(r.health) for r in got]
        assert statuses[1] == "poisoned"
        assert statuses[0] == "ok" and np.isfinite(got[0].a).all()
        assert statuses[2] != "ok"                    # stalled lane
        assert eng.quarantined_lane_steps > 0

    def test_waste_dominance_under_faults(self):
        """The acceptance inequality: under the SAME fault plan,
        continuous wasted+quarantined lane steps stay strictly below
        round mode's — the stalled lane becomes a straggler whose
        shadow the round barrier burns on every healthy lane."""
        _, faulty = self._loops()
        items = trip_items(TRIPS)
        eng_r = FarmEngine(faulty, lanes=4)
        eng_r.run(items, lambda r: None)
        eng_c = FarmEngine(faulty, lanes=4, segment=4)
        eng_c.run(items, lambda r: None, continuous=True)
        cost = lambda e: e.wasted_lane_steps + e.quarantined_lane_steps
        assert cost(eng_c) < cost(eng_r), (
            eng_c.stats, eng_r.stats)

    def test_quarantine_never_eats_the_last_slot(self):
        """lanes=1 degenerate: the only slot fails every occupant, yet
        is never retired — the stream still drains (non-ok, bounded
        attempts, no deadlock)."""
        plan = FaultPlan(lanes=1, stall_events=((0, 1 << 20),))
        loop = plan.instrument(mk_countdown(max_iters=8))
        eng = FarmEngine(loop, lanes=1, segment=4, max_attempts=2,
                         slot_patience=1)
        got = stream(eng, trip_items([3, 4]), continuous=True)
        assert all(r.status != "ok" for r in got.values())
        assert all(r.attempts == 2 for r in got.values())
        assert eng.stats["quarantined_slots"] == 0
        assert len(eng.dead_letter) == 2


# ---------------------------------------------------------------------------
# Prep-boundary corruption — the admission finite check
# ---------------------------------------------------------------------------


class TestAdmissionCheck:
    def test_round_mode_rejects_nonfinite_batch_loudly(self):
        eng = FarmEngine(mk_countdown(), lanes=2)
        eng.run(trip_items([2, 3]), lambda r: None)   # binds
        bad = trip_items([2, 3])
        bad[1][4, 7] = np.nan
        with pytest.raises(NonFiniteItemError, match="NaN/Inf"):
            eng.run(bad, lambda r: None)

    def test_continuous_mode_rejects_and_keeps_streaming(self):
        """A corrupted item is shed at the door — status='rejected',
        dead-lettered, slot never dirtied — and the stream continues;
        clean items are unaffected."""
        plan = FaultPlan(lanes=2, corrupt_indices=(1, 4))
        items = trip_items([3, 5, 4, 6, 2])
        eng = FarmEngine(mk_countdown(), lanes=2, segment=4)
        got = stream(eng, plan.corrupt_stream(items), continuous=True)
        assert {i: r.status for i, r in got.items()} == {
            0: "ok", 1: "rejected", 2: "ok", 3: "ok", 4: "rejected"}
        assert all(got[i].a is None for i in (1, 4))
        assert eng.stats["rejected"] == 2
        assert sorted(d.index for d in eng.dead_letter) == [1, 4]

    def test_env_leaves_checked_too(self):
        from repro.kernels import ref as R
        loop = LoopOfStencilReduce(
            f=R.restore_taps(2.0), k=1, combine="max",
            cond=lambda r: r < 1e-3, delta=R.abs_delta,
            boundary="reflect", max_iters=16, backend="jnp",
            interpret=True)
        a = trip_items([3])[0]
        mask = (a > 0.5).astype(np.float32)
        eng = FarmEngine(loop, lanes=2)
        eng.run([(a, a.copy(), mask)], lambda r: None)
        bad_mask = mask.copy()
        bad_mask[3, 9] = np.inf
        with pytest.raises(NonFiniteItemError, match="env"):
            eng.run([(a, a.copy(), bad_mask)], lambda r: None)

    def test_check_finite_off_defers_to_the_sentinel(self):
        """check_finite=False admits the poisoned item; the sentinel
        catches the NaN on device and quarantines the lane instead of
        spinning it to the iteration cap."""
        plan = FaultPlan(lanes=2, corrupt_indices=(1,))
        items = trip_items([3, 5, 4])
        eng = FarmEngine(
            mk_countdown(max_iters=32, sentinel=Sentinel(nan=True)),
            lanes=2, segment=4, check_finite=False)
        got = stream(eng, plan.corrupt_stream(items), continuous=True)
        assert got[1].status == "poisoned"
        assert int(got[1].iters) < 32                 # no spin to cap
        assert got[0].status == "ok" and got[2].status == "ok"
        assert np.isfinite(got[0].a).all()
        assert np.isfinite(got[2].a).all()


# ---------------------------------------------------------------------------
# Sharded NaN containment — 8 virtual devices, subprocess
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_multidevice(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
class TestShardedNaNContainment:
    def test_nan_frame_contained_to_its_lane(self):
        """Composed farm (2 lanes × 4 spatial shards): a NaN planted in
        ONE lane's frame spreads through THAT lane's ghost exchange
        only — the NaN-safe pmax re-propagation makes every spatial
        shard of the poisoned lane agree on the NaN reduce (uniform
        quarantine, no hang), while the neighbour lane's reductions
        stay finite and its results land bit-identical to a fault-free
        run."""
        out = run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import FarmEngine, GridPartition, LoopOfStencilReduce
from repro.core.reduce import Sentinel

def countdown(get, *_):
    return get(0, 0) - 1.0

def mk(part):
    return LoopOfStencilReduce(
        f=countdown, k=1, combine="max", cond=lambda r: r < 0.5,
        boundary="zero", max_iters=32, backend="pallas-sharded",
        partition=part, interpret=True, block=(16, 128),
        sentinel=Sentinel(nan=True))

def trip_items(trips, shape=(32, 64)):
    base = np.linspace(0.1, 0.9, shape[0] * shape[1],
                       dtype=np.float32).reshape(shape)
    return [base + float(t) - 1.0 for t in trips]

mesh = jax.make_mesh((2, 4), ("data", "model"))
part = GridPartition(mesh=mesh, axis_names=("model",), array_axes=(0,))

items = trip_items([3, 9, 5, 7, 4, 6])
bad = [it.copy() for it in items]
bad[1][20, 33] = np.nan          # one cell of one item's frame

def drive(items):
    eng = FarmEngine(mk(part), lanes=2, mesh=mesh, segment=4,
                     check_finite=False)
    got = {}
    n = eng.run(items, lambda r: got.setdefault(r.index, r),
                continuous=True)
    assert n == len(items) == len(got), (n, len(got))
    return got

ref = drive(items)
got = drive(bad)
assert got[1].status == "poisoned", got[1].status
assert int(got[1].iters) < 32     # quarantined, not spun to the cap
for i in got:
    if i == 1:
        continue
    assert got[i].status == "ok", (i, got[i].status)
    assert np.isfinite(np.asarray(got[i].a)).all(), i
    assert np.isfinite(np.asarray(got[i].reduced)).all(), i
    np.testing.assert_array_equal(np.asarray(got[i].a),
                                  np.asarray(ref[i].a))

# NaN-safe pmin: min-monoid convergence is untouched by the
# re-propagation guard when nothing is NaN
mn = LoopOfStencilReduce(
    f=lambda get, *_: get(0, 0) - 1.0, k=1, combine="min",
    cond=lambda r: r < -40.0, boundary="zero", max_iters=64,
    backend="pallas-sharded", partition=part, interpret=True,
    block=(16, 128))
res = mn.run(jnp.asarray(trip_items([5])[0]))
assert np.isfinite(float(res.reduced))
assert int(res.iters) < 64
print("OKCONTAIN")
""")
        assert "OKCONTAIN" in out
